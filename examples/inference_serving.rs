//! Inference serving through the PJRT runtime — the three-layer
//! composition on the request path.
//!
//! A minimal request loop: batches of synthetic MNIST images arrive, each
//! rank-0-style worker pushes its layer blocks through the **AOT-compiled
//! JAX/Pallas artifacts** (HLO text → PJRT CPU executable; Python is not
//! running), and latency/throughput are reported per batch. A native-CSR
//! pass validates every batch bit-for-bit (≤1e-5).
//!
//! Requires `make artifacts` (shapes must include 64x256, batch 16).
//!
//! Run: `cargo run --release --example inference_serving -- [--requests 8]`

use spdnn::data::synthetic_mnist;
use spdnn::dnn::Activation;
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::runtime::{artifacts_dir, PjrtLayerEngine};
use spdnn::util::{Args, Stopwatch};

fn main() {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 8);
    let batch = 16usize; // must match the AOT artifact batch width

    // N=256, 4 layers, P=4 → uniform 64×256 row blocks = the AOT shape.
    let net = generate(&RadixNetConfig::graph_challenge(256, 4).expect("cfg"));
    let ranks = 4usize;
    let part = random_partition(&net.layers, ranks, 5);
    let dir = artifacts_dir();
    let eng = PjrtLayerEngine::load(&dir, 64, 256, batch)
        .expect("artifacts missing — run `make artifacts` first");
    println!(
        "serving N=256 L=4 on {ranks} ranks via PJRT ({} platform), batch {batch}",
        "cpu"
    );

    // Pre-extract every rank's blocks + biases (startup cost, not hot path).
    let mut blocks = Vec::new();
    for rank in 0..ranks as u32 {
        let per_layer: Vec<_> = (0..net.depth())
            .map(|k| {
                let rows = part.rows_of(k, rank);
                let blk = net.layers[k].row_block(&rows);
                let bias: Vec<f32> =
                    rows.iter().map(|&r| net.biases[k][r as usize]).collect();
                (rows, blk, bias)
            })
            .collect();
        blocks.push(per_layer);
    }

    let data = synthetic_mnist(16, requests * batch, 8); // 16×16=256 inputs
    let mut total_edges = 0f64;
    let mut total_secs = 0f64;
    for req in 0..requests {
        let (x0, b) = data.pack_batch(req * batch, (req + 1) * batch);
        let sw = Stopwatch::start();
        // layer-by-layer: each rank's block through the PJRT artifact; the
        // full-width activation buffer plays the role of the fabric here
        // (single-host serving; the distributed variant is exercised by
        // `spdnn infer` / the e2e example).
        let mut cur = x0.clone();
        for k in 0..net.depth() {
            let mut next = vec![0f32; 256 * b];
            for rank in 0..ranks {
                let (rows, blk, bias) = &blocks[rank][k];
                let out = eng.forward_batch(blk, &cur, bias).expect("pjrt");
                for (i, &r) in rows.iter().enumerate() {
                    next[r as usize * b..(r as usize + 1) * b]
                        .copy_from_slice(&out[i * b..(i + 1) * b]);
                }
            }
            cur = next;
        }
        let secs = sw.elapsed_secs();

        // validate against the native engine
        let native = spdnn::dnn::inference::infer_batch(&net, &x0, b);
        let maxerr = cur
            .iter()
            .zip(native.iter())
            .map(|(a, c)| (a - c).abs())
            .fold(0f32, f32::max);
        assert!(maxerr < 1e-5, "request {req}: PJRT vs native {maxerr}");

        let edges = net.total_nnz() as f64 * b as f64;
        total_edges += edges;
        total_secs += secs;
        println!(
            "request {req:>2}: {b} images in {:.1} ms  ({:.2e} edges/s, maxerr {maxerr:.1e})",
            secs * 1e3,
            edges / secs
        );
    }
    println!(
        "served {requests} batches: {:.2e} edges/s aggregate — Python was never on this path",
        total_edges / total_secs
    );
    let _ = Activation::Sigmoid; // (used indirectly via artifacts)
}
