//! Inference serving on the **persistent rank pool** — the
//! heavy-traffic request path.
//!
//! The network is carved once into contiguous nnz-balanced row blocks
//! with a precomputed communication plan, and [`RankPool`] spawns one
//! long-lived OS thread per rank. Multiple concurrent client threads then
//! submit batches of synthetic MNIST images; the adaptive micro-batching
//! scheduler coalesces queued requests (every third client request is a
//! single image to exercise coalescing) into fused SpMM dispatches.
//! Every reply is validated against the serial engine (≤1e-5) and the
//! run ends with the pool's `ServingStats`: aggregate edges/s plus
//! p50/p95/p99 latency, also written as JSON for the CI smoke job.
//!
//! Run: `cargo run --release --example inference_serving -- \
//!        [--requests 8] [--clients 4] [--ranks 4] [--batch 64] \
//!        [--neurons 1024] [--layers 12] [--max-batch 128] \
//!        [--max-wait-us 500] [--mode pipelined|overlap|blocking] \
//!        [--codec f32|f16|int8] [--json BENCH_serving.json]`
//!
//! With a lossy `--codec` the replies are validated against the serial
//! engine under a codec-matched tolerance, and the final stats line
//! reports the live wire-compression ratio (raw vs encoded bytes).

use spdnn::comm::Codec;
use spdnn::coordinator::ExecMode;
use spdnn::data::synthetic_mnist;
use spdnn::dnn::inference::{classify_batch, infer_batch};
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::serving::{PoolConfig, RankPool};
use spdnn::util::{Args, Stopwatch};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 8); // per client
    let clients = args.get_usize("clients", 4);
    let ranks = args.get_usize("ranks", 4);
    let batch = args.get_usize("batch", 64);
    let neurons = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let max_batch = args.get_usize("max-batch", 2 * batch);
    let max_wait_us = args.get_u64("max-wait-us", 500);
    let json_path = args.get_str("json", "BENCH_serving.json");
    let mode = ExecMode::from_name(&args.get_str("mode", "pipelined"))
        .expect("unknown mode (expected pipelined | overlap | blocking)");
    let codec = Codec::parse(&args.get_str("codec", "f32"))
        .expect("unknown codec (expected f32 | f16 | int8)");
    // reply validation tolerance vs the serial engine, matched to the
    // codec's bounded activation error compounding across layers
    let tol: f32 = match codec {
        Codec::F32 => 1e-5,
        Codec::F16 => 2e-2,
        Codec::Int8 { .. } => 0.25,
    };

    let net = generate(
        &RadixNetConfig::graph_challenge(neurons, layers).expect("unsupported neuron count"),
    );
    let side = (net.input_dim() as f64).sqrt() as usize;
    println!(
        "serving N={} L={} ({} connections) on a {ranks}-rank pool: \
         {clients} clients × {requests} requests, batch {batch}, \
         max_batch {max_batch}, max_wait {max_wait_us}µs, \
         mode {mode:?}, codec {}",
        net.input_dim(),
        net.depth(),
        net.total_nnz(),
        codec.label()
    );

    // Partition, plan, rank states, and rank threads are all built once
    // here and reused for every request — only the fused SpMM dispatch is
    // on the per-request clock.
    let net = Arc::new(net);
    let pool = Arc::new(RankPool::start(
        (*net).clone(),
        PoolConfig {
            nranks: ranks,
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            adaptive: true,
            mode,
            codec,
            ..PoolConfig::default()
        },
    ));

    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let net = Arc::clone(&net);
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || {
                let data = synthetic_mnist(side, requests * batch, 8 + c as u64);
                for r in 0..requests {
                    // mixed sizes: every third request is a single image,
                    // exercising the coalescer
                    let b = if r % 3 == 0 { 1 } else { batch };
                    let (x0, b) = data.pack_batch(r * batch, r * batch + b);
                    let req_sw = Stopwatch::start();
                    let out = pool
                        .submit(x0.clone(), b)
                        .wait()
                        .unwrap_or_else(|f| panic!("client {c} request {r} failed: {f}"));
                    let secs = req_sw.elapsed_secs();

                    // validate against the serial engine
                    let serial = infer_batch(&net, &x0, b);
                    let maxerr = out
                        .iter()
                        .zip(serial.iter())
                        .map(|(a, s)| (a - s).abs())
                        .fold(0f32, f32::max);
                    assert!(maxerr < tol, "client {c} request {r}: maxerr {maxerr}");
                    let classes = classify_batch(&out, 10, b)
                        .into_iter()
                        .collect::<std::collections::HashSet<_>>()
                        .len();
                    println!(
                        "client {c} req {r:>2}: {b:>3} images in {:.2} ms \
                         (maxerr {maxerr:.1e}, {classes} distinct classes)",
                        secs * 1e3
                    );
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let wall = sw.elapsed_secs();

    let summary = pool.shutdown().expect("pool shutdown");
    assert!(
        summary.leaked_ranks.is_empty(),
        "message leak at shutdown: ranks {:?}",
        summary.leaked_ranks
    );
    let s = &summary.stats;
    println!("--- serving stats ({wall:.2}s wall) ---");
    println!("{}", s.render());
    println!(
        "latency p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms",
        s.p50_secs * 1e3,
        s.p95_secs * 1e3,
        s.p99_secs * 1e3
    );
    println!(
        "wire: {} B raw → {} B shipped ({:.2}x compression, codec {})",
        s.raw_bytes,
        s.wire_bytes,
        s.wire_compression(),
        codec.label()
    );
    std::fs::write(&json_path, s.to_json()).expect("write serving json");
    println!("wrote {json_path}");
}
