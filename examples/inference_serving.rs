//! Inference serving on the threaded rank-parallel engine — the
//! throughput-oriented request path.
//!
//! A minimal request loop: the network is carved once into contiguous
//! nnz-balanced row blocks with a precomputed communication plan, then
//! each arriving batch of synthetic MNIST images runs the batched fused
//! SpMM (`infer_with_plan`) on one OS thread per rank. Every batch is
//! validated against the serial engine (≤1e-5) and latency/throughput are
//! reported per batch and aggregate.
//!
//! Run: `cargo run --release --example inference_serving -- \
//!        [--requests 8] [--ranks 4] [--batch 64]`
//!
//! (The PJRT/AOT serving variant lives behind the `pjrt` feature; see
//! `rust/tests/pjrt_runtime.rs`.)

use spdnn::coordinator::sgd::infer_with_plan;
use spdnn::data::synthetic_mnist;
use spdnn::dnn::inference::{classify_batch, infer_batch};
use spdnn::partition::{contiguous_partition, CommPlan};
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::util::{Args, Stopwatch};

fn main() {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 8);
    let ranks = args.get_usize("ranks", 4);
    let batch = args.get_usize("batch", 64);

    // N=1024 neurons/layer (32×32 inputs), 12 layers — the small Graph
    // Challenge configuration.
    let net = generate(&RadixNetConfig::graph_challenge(1024, 12).expect("cfg"));
    println!(
        "serving N={} L={} ({} connections) on {ranks} ranks, batch {batch}",
        net.input_dim(),
        net.depth(),
        net.total_nnz()
    );

    // Partition + communication plan are computed once at startup and
    // reused across requests — only the per-request SpMM is on the clock.
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);

    let data = synthetic_mnist(32, requests * batch, 8);
    let mut total_edges = 0f64;
    let mut total_secs = 0f64;
    for req in 0..requests {
        let (x0, b) = data.pack_batch(req * batch, (req + 1) * batch);
        let sw = Stopwatch::start();
        let (out, _) = infer_with_plan(&net, &part, &plan, &x0, b);
        let secs = sw.elapsed_secs();

        // validate against the serial engine
        let serial = infer_batch(&net, &x0, b);
        let maxerr = out
            .iter()
            .zip(serial.iter())
            .map(|(a, c)| (a - c).abs())
            .fold(0f32, f32::max);
        assert!(maxerr < 1e-5, "request {req}: parallel vs serial {maxerr}");
        let preds = classify_batch(&out, 10, b);

        let edges = net.total_nnz() as f64 * b as f64;
        total_edges += edges;
        total_secs += secs;
        println!(
            "request {req:>2}: {b} images in {:.1} ms  ({:.2e} edges/s, maxerr {maxerr:.1e}, \
             {} distinct classes)",
            secs * 1e3,
            edges / secs,
            preds.iter().collect::<std::collections::HashSet<_>>().len()
        );
    }
    println!(
        "served {requests} batches on {ranks} ranks: {:.2e} edges/s aggregate",
        total_edges / total_secs
    );
}
