//! Train a sparse RadiX-Net classifier on synthetic MNIST and report
//! training-set accuracy — the paper's training workload (Section 6.1) at
//! laptop scale, with the H-vs-random partition comparison inline.
//!
//! Run: `cargo run --release --example train_mnist -- [--ranks 4] [--epochs 4]`

use spdnn::coordinator::sgd::train_distributed;
use spdnn::data::synthetic_mnist;
use spdnn::dnn::inference::infer;
use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::phases::{hypergraph_partition, PhaseConfig};
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::util::Args;

fn main() {
    let args = Args::from_env();
    let ranks = args.get_usize("ranks", 4);
    let epochs = args.get_usize("epochs", 30);
    let count = args.get_usize("samples", 30);
    let eta = args.get_f32("eta", 1.0);

    // 1024 neurons/layer = 32×32 MNIST scaling; 3 layers keeps the sigmoid
    // signal path short enough that the tiny synthetic task is learnable.
    let net = generate(&RadixNetConfig::graph_challenge(1024, 3).expect("cfg"));
    let data = synthetic_mnist(32, count, 3);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..count).map(|i| data.target(i, 1024)).collect();

    let h = hypergraph_partition(&net.layers, &PhaseConfig::new(ranks));
    let r = random_partition(&net.layers, ranks, 1);
    let mh = PartitionMetrics::compute(&net.layers, &h);
    let mr = PartitionMetrics::compute(&net.layers, &r);
    println!(
        "partitions over {ranks} ranks: H {:.1}K words/iter vs R {:.1}K ({:.2}x)",
        mh.avg_volume() / 1e3,
        mr.avg_volume() / 1e3,
        mr.avg_volume() / mh.avg_volume()
    );

    let run = train_distributed(&net, &h, &inputs, &targets, eta, epochs);
    for e in (0..epochs).step_by(5.max(epochs / 6)) {
        let lo = e * count;
        let avg: f32 = run.losses[lo..lo + count].iter().sum::<f32>() / count as f32;
        println!("epoch {e}: avg loss {avg:.5}");
    }
    let lo = (epochs - 1) * count;
    let last: f32 = run.losses[lo..].iter().sum::<f32>() / count as f32;
    println!("epoch {}: avg loss {last:.5}", epochs - 1);

    // training-set accuracy with the trained (merged) model
    let mut correct = 0usize;
    for (i, s) in data.samples.iter().enumerate() {
        let out = infer(&run.net, &s.pixels);
        let pred = (0..10)
            .max_by(|&a, &b| out[a].partial_cmp(&out[b]).unwrap())
            .unwrap();
        if pred == data.samples[i].label {
            correct += 1;
        }
    }
    println!(
        "training-set accuracy: {}/{} = {:.0}%",
        correct,
        count,
        100.0 * correct as f64 / count as f64
    );
}
