//! Quickstart: the whole stack in ~60 lines.
//!
//! Generates a small RadiX-Net sparse DNN, partitions it with the paper's
//! multi-phase hypergraph model, trains it distributed (4 simulated ranks)
//! on synthetic MNIST, and compares against the random-partition baseline.
//!
//! Run: `cargo run --release --example quickstart`

use spdnn::coordinator::sgd::train_distributed;
use spdnn::data::synthetic_mnist;
use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::phases::{hypergraph_partition, PhaseConfig};
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};

fn main() {
    // 1. A sparse DNN: 1024 neurons/layer (32×32 input images), 8 layers.
    let net = generate(&RadixNetConfig::graph_challenge(1024, 8).expect("config"));
    println!(
        "network: {} layers × {} neurons, {} connections",
        net.depth(),
        net.input_dim(),
        net.total_nnz()
    );

    // 2. Partition it two ways: the paper's hypergraph model vs random.
    let h = hypergraph_partition(&net.layers, &PhaseConfig::new(4));
    let r = random_partition(&net.layers, 4, 42);
    let mh = PartitionMetrics::compute(&net.layers, &h);
    let mr = PartitionMetrics::compute(&net.layers, &r);
    println!(
        "comm volume/iter: hypergraph {:.1}K words vs random {:.1}K words ({:.0}% saved)",
        mh.avg_volume() / 1e3,
        mr.avg_volume() / 1e3,
        100.0 * (1.0 - mh.avg_volume() / mr.avg_volume())
    );

    // 3. Distributed training on 4 simulated ranks (synthetic MNIST 32×32).
    let data = synthetic_mnist(32, 32, 7);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..32).map(|i| data.target(i, 1024)).collect();
    let run = train_distributed(&net, &h, &inputs, &targets, 0.05, 3);
    println!(
        "training: first-epoch loss {:.4} → last-epoch loss {:.4} over {} steps",
        run.losses[..32].iter().sum::<f32>() / 32.0,
        run.losses[run.losses.len() - 32..].iter().sum::<f32>() / 32.0,
        run.losses.len()
    );
    println!("live comm counters (words, msgs) per rank: {:?}", run.sent);
    println!("quickstart OK");
}
