//! Partition explorer: inspect what the multi-phase hypergraph model does —
//! per-layer cut/volume, fixed-vertex chaining, balance — and compare
//! against random and against independent (non-chained) partitioning.
//!
//! Run: `cargo run --release --example partition_explore -- [--neurons 1024] [--ranks 8]`

use spdnn::experiments::Table;
use spdnn::hypergraph::PartitionConfig;
use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::phases::{build_phase_hypergraph, hypergraph_partition, PhaseConfig};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::partition::DnnPartition;
use spdnn::radixnet::{generate_structure, RadixNetConfig};
use spdnn::util::Args;

fn main() {
    let args = Args::from_env();
    let neurons = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let ranks = args.get_usize("ranks", 8);

    let structure = generate_structure(
        &RadixNetConfig::graph_challenge(neurons, layers).expect("supported size"),
    );
    println!("N={neurons}, L={layers}, P={ranks}");

    // Three strategies: chained H (the paper), independent H (no fixed
    // vertices — the ablation), random.
    let chained = hypergraph_partition(&structure, &PhaseConfig::new(ranks));
    let mut layer_parts = Vec::new();
    for (k, w) in structure.iter().enumerate() {
        let hg = build_phase_hypergraph(w, None);
        let mut cfg = PartitionConfig::new(ranks);
        cfg.seed = 50 + k as u64;
        let parts = spdnn::hypergraph::partition(&hg, &cfg);
        layer_parts.push(parts[..w.nrows].to_vec());
    }
    let independent = DnnPartition {
        nparts: ranks,
        input_parts: chained.input_parts.clone(),
        layer_parts,
    };
    let random = random_partition(&structure, ranks, 1);

    let mut t = Table::new(&["strategy", "vol avg(K)", "vol max(K)", "msgs avg(K)", "imb"]);
    for (name, part) in [
        ("H chained (paper)", &chained),
        ("H independent", &independent),
        ("random", &random),
    ] {
        let m = PartitionMetrics::compute(&structure, part);
        t.row(vec![
            name.into(),
            format!("{:.1}", m.avg_volume() / 1e3),
            format!("{:.1}", m.max_volume() / 1e3),
            format!("{:.2}", m.avg_msgs() / 1e3),
            format!("{:.3}", m.comp_imbalance()),
        ]);
    }
    println!("{}", t.render());

    // Per-layer view for the chained partition: volume by layer (stage
    // structure of RadiX-Net shows through).
    let plan = CommPlan::build(&structure, &chained);
    let mut t = Table::new(&["layer", "stage", "volume (words)", "messages"]);
    for (k, lp) in plan.layers.iter().enumerate() {
        t.row(vec![
            k.to_string(),
            (k % 3).to_string(),
            lp.volume().to_string(),
            lp.message_count().to_string(),
        ]);
    }
    println!("{}", t.render());
}
