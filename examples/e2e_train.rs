//! End-to-end driver — the full system on a real (small) workload.
//!
//! Exercises every layer of the stack in one run:
//!   RadiX-Net generation → multi-phase hypergraph partitioning →
//!   comm-plan construction (Eqs. 8–9) → live distributed SGD on 8
//!   simulated ranks over the message-passing fabric → loss-curve logging →
//!   live-counter vs plan cross-check → replay-model projection to the
//!   paper's processor counts → PJRT artifact parity spot-check (the AOT
//!   JAX/Pallas path), proving all three layers compose.
//!
//! Run: `cargo run --release --example e2e_train` (after `make artifacts`).
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::coordinator::replay::{replay, ReplayConfig};
use spdnn::coordinator::sgd::train_distributed;
use spdnn::data::synthetic_mnist;
use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::phases::{hypergraph_partition, PhaseConfig};
use spdnn::partition::random::random_partition;
use spdnn::partition::CommPlan;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::util::Stopwatch;

fn main() {
    let neurons = 1024;
    let layers = 12;
    let ranks = 8;
    let steps = 300;
    let eta = 0.05f32;

    // ---- 1. the workload ------------------------------------------------
    let net = generate(&RadixNetConfig::graph_challenge(neurons, layers).expect("cfg"));
    println!(
        "[e2e] network N={neurons} L={layers}: {} connections",
        net.total_nnz()
    );
    let data = synthetic_mnist(32, steps, 2026);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..steps).map(|i| data.target(i, neurons)).collect();

    // ---- 2. partition (H) + plan ----------------------------------------
    let sw = Stopwatch::start();
    let part = hypergraph_partition(&net.layers, &PhaseConfig::new(ranks));
    println!("[e2e] hypergraph partitioning: {:.2}s", sw.elapsed_secs());
    let plan = CommPlan::build(&net.layers, &part);
    let metrics = PartitionMetrics::from_plan(&net.layers, &part, &plan);
    let rnd = random_partition(&net.layers, ranks, 9);
    let rnd_metrics = PartitionMetrics::compute(&net.layers, &rnd);
    println!(
        "[e2e] comm volume/iter: H {:.1}K vs R {:.1}K words ({:.2}x reduction), imb H {:.3} R {:.3}",
        metrics.avg_volume() / 1e3,
        rnd_metrics.avg_volume() / 1e3,
        rnd_metrics.avg_volume() / metrics.avg_volume(),
        metrics.comp_imbalance(),
        rnd_metrics.comp_imbalance()
    );

    // ---- 3. live distributed training ------------------------------------
    let sw = Stopwatch::start();
    let run = train_distributed(&net, &part, &inputs, &targets, eta, 1);
    let train_secs = sw.elapsed_secs();
    let window = 25;
    println!("[e2e] loss curve (window {window}):");
    for w in (0..steps).step_by(window) {
        let hi = (w + window).min(steps);
        let avg: f32 = run.losses[w..hi].iter().sum::<f32>() / (hi - w) as f32;
        println!("  steps {w:>4}-{:<4} avg loss {avg:.5}", hi - 1);
    }
    let first: f32 = run.losses[..window].iter().sum::<f32>() / window as f32;
    let last: f32 = run.losses[steps - window..].iter().sum::<f32>() / window as f32;
    println!(
        "[e2e] loss {first:.5} → {last:.5} ({:.1}% drop) in {train_secs:.2}s live on {ranks} ranks",
        100.0 * (1.0 - last / first)
    );
    assert!(last < first, "training must reduce the loss");

    // ---- 4. live counters == plan ----------------------------------------
    let fwd_send = plan.fwd_send_volume_per_rank();
    let fwd_recv = plan.fwd_recv_volume_per_rank();
    for r in 0..ranks {
        let expect = steps as u64 * (fwd_send[r] + fwd_recv[r]);
        assert_eq!(run.sent[r].0, expect, "rank {r} counter mismatch");
    }
    println!("[e2e] live comm counters match the precomputed plan on all ranks");

    // ---- 5. replay projection to the paper's scale -----------------------
    let comp = ComputeModel::calibrate();
    let cfg = ReplayConfig::training(comp);
    println!("[e2e] replay projection (calibrated rates, InfiniBand α-β):");
    for p in [32usize, 128, 512] {
        let hp = hypergraph_partition(&net.layers, &PhaseConfig::new(p));
        let rp = random_partition(&net.layers, p, 3);
        let th = replay(&net.layers, &hp, &CommPlan::build(&net.layers, &hp), &cfg);
        let tr = replay(&net.layers, &rp, &CommPlan::build(&net.layers, &rp), &cfg);
        println!(
            "  P={p:>3}: H-SGD {:.3e}s/input vs SGD {:.3e}s/input ({:.2}x)",
            th.total(),
            tr.total(),
            tr.total() / th.total()
        );
    }

    // ---- 6. PJRT parity: the AOT JAX/Pallas path serves a rank block -----
    pjrt_parity();

    println!("[e2e] OK");
}

#[cfg(feature = "pjrt")]
fn pjrt_parity() {
    use spdnn::dnn::Activation;
    use spdnn::runtime::{artifacts_dir, PjrtLayerEngine};

    let dir = artifacts_dir();
    if dir.join(spdnn::runtime::fwd_artifact(64, 256)).is_file() {
        let small = generate(&RadixNetConfig::graph_challenge(256, 2).expect("cfg"));
        let spart = random_partition(&small.layers, 4, 5);
        let eng = PjrtLayerEngine::load(&dir, 64, 256, 16).expect("artifacts");
        let rows = spart.rows_of(0, 0);
        let blk = small.layers[0].row_block(&rows);
        let bias: Vec<f32> = rows.iter().map(|&r| small.biases[0][r as usize]).collect();
        let x: Vec<f32> = (0..256).map(|i| (i % 3) as f32 * 0.5).collect();
        let pjrt = eng.forward(&blk, &x, &bias).expect("pjrt forward");
        let mut z = vec![0f32; blk.nrows];
        blk.spmv(&x, &mut z);
        for i in 0..blk.nrows {
            z[i] += bias[i];
        }
        Activation::Sigmoid.apply(&mut z);
        let maxerr = pjrt
            .iter()
            .zip(z.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(maxerr < 1e-5, "PJRT vs native max err {maxerr}");
        println!("[e2e] PJRT artifact parity: max |pjrt - native| = {maxerr:.2e}");
    } else {
        println!("[e2e] PJRT artifacts not found — run `make artifacts` for the full check");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_parity() {
    println!(
        "[e2e] PJRT feature disabled — vendor the `xla` crate into Cargo.toml and build \
         with `--features pjrt` for the artifact parity check"
    );
}
