//! Bench/regenerator for **Table 2**: inference throughput (edges/s),
//! H-SpFF (model-parallel) vs GB (data-parallel GraphBLAS-style baseline),
//! plus a **live** section measuring the threaded rank-parallel engine's
//! batched SpMM path at 1 vs 4 ranks on real OS threads.
//!
//! `cargo bench --bench table2_throughput` — `SPDNN_FULL=1` adds the
//! deeper (480/1920-layer) configurations of the paper.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::coordinator::sgd::infer_with_plan;
use spdnn::dnn::inference::infer_batch_parallel;
use spdnn::experiments::table2;
use spdnn::partition::{contiguous_partition, CommPlan};
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::serving::{PoolConfig, RankPool};
use spdnn::util::{Rng, Stopwatch};
use std::time::Duration;

/// Live threaded engine: edges/s of the batched fused-SpMM inference path
/// at `ranks`, with partition + plan built once (the serving setup cost is
/// off the clock, as in a real request loop).
fn live_parallel_eps(net: &spdnn::dnn::SparseNet, b: usize, inputs: usize, ranks: usize) -> f64 {
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);
    let d = net.input_dim();
    let mut rng = Rng::new(42);
    let x0: Vec<f32> = (0..d * b)
        .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
        .collect();
    // warm-up (thread spawn + caches)
    let _ = infer_with_plan(net, &part, &plan, &x0, b);
    let mut processed = 0usize;
    let sw = Stopwatch::start();
    while processed < inputs {
        let _ = infer_with_plan(net, &part, &plan, &x0, b);
        processed += b;
    }
    let secs = sw.elapsed_secs();
    net.total_nnz() as f64 * processed as f64 / secs
}

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    // (neurons, layers) grid; the paper runs L ∈ {120, 480, 1920} at each N
    let grid: Vec<(usize, usize)> = if full {
        let mut g = Vec::new();
        for &n in &[1024usize, 4096, 16384, 65536] {
            for &l in &[120usize, 480, 1920] {
                g.push((n, l));
            }
        }
        g
    } else {
        vec![(1024, 24), (1024, 96), (4096, 24), (4096, 96)]
    };
    let comp = ComputeModel::calibrate();
    let cfg = table2::Config {
        nparts: 128,
        batch: 64,
        inputs: if full { 60_000 } else { 4096 },
        gb_sample: if full { 256 } else { 64 },
    };
    println!("# Table 2 reproduction (H-SpFF P={}, full={full})", cfg.nparts);
    let mut rows = Vec::new();
    for (n, l) in grid {
        let sw = Stopwatch::start();
        let row = table2::run(n, l, &cfg, comp, 1);
        let secs = sw.elapsed_secs();
        println!(
            "[bench] N={n} L={l}: H-SpFF {:.2E} vs GB {:.2E} edges/s (speedup {:.2}) in {secs:.1}s",
            row.hspff_eps,
            row.gb_eps,
            row.speedup()
        );
        rows.push(row);
    }
    println!("\n{}", table2::render(&rows));

    // Live rank-parallel engine: real threads, batched fused SpMM. The
    // 4-rank figure must beat the 1-rank figure on any multi-core host.
    println!("# Live threaded engine (batched SpMM, contiguous blocks)");
    let (n, l, b) = (1024usize, 24usize, 64usize);
    let inputs = if full { 8192 } else { 1024 };
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let eps1 = live_parallel_eps(&net, b, inputs, 1);
    let eps4 = live_parallel_eps(&net, b, inputs, 4);
    println!(
        "[bench] live N={n} L={l} b={b}: 1 rank {eps1:.2E} edges/s, 4 ranks {eps4:.2E} edges/s \
         (speedup {:.2}x)",
        eps4 / eps1
    );

    // Persistent rank pool vs per-request respawn: the pool keeps rank
    // threads + states + plan alive across the stream, the one-shot path
    // rebuilds partition, plan, states, and threads on every request.
    // Acceptance bar: pool ≥ 1.3× edges/s at 4 ranks over ≥ 32 requests.
    println!("# Persistent pool vs one-shot respawn (sustained serving)");
    let (reqs, pb, pranks) = (if full { 128usize } else { 32 }, 16usize, 4usize);
    let mut rng = Rng::new(7);
    let x0: Vec<f32> = (0..net.input_dim() * pb)
        .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
        .collect();

    let _ = infer_batch_parallel(&net, &x0, pb, pranks); // warm-up
    let sw = Stopwatch::start();
    for _ in 0..reqs {
        let _ = infer_batch_parallel(&net, &x0, pb, pranks);
    }
    let oneshot_secs = sw.elapsed_secs();

    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: pranks,
            max_batch: 4 * pb,
            max_wait: Duration::ZERO,
            adaptive: false,
        },
    );
    let _ = pool.submit(x0.clone(), pb).wait().expect("warm-up"); // warm-up
    let sw = Stopwatch::start();
    let tickets: Vec<_> = (0..reqs).map(|_| pool.submit(x0.clone(), pb)).collect();
    for t in tickets {
        let _ = t.wait().expect("pool request failed");
    }
    let pool_secs = sw.elapsed_secs();
    let snap = pool.stats();
    let _ = pool.shutdown();

    let edges = net.total_nnz() as f64 * (reqs * pb) as f64;
    println!(
        "[bench] serving {reqs} requests × b={pb} at {pranks} ranks: \
         one-shot {:.2E} edges/s, pool {:.2E} edges/s (pool/one-shot {:.2}x)",
        edges / oneshot_secs,
        edges / pool_secs,
        oneshot_secs / pool_secs
    );
    println!(
        "[bench] pool latency p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms \
         (mean batch {:.1} cols over {} dispatches)",
        snap.p50_secs * 1e3,
        snap.p95_secs * 1e3,
        snap.p99_secs * 1e3,
        snap.mean_batch,
        snap.batches
    );
}
