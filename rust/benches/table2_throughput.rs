//! Bench/regenerator for **Table 2**: inference throughput (edges/s),
//! H-SpFF (model-parallel) vs GB (data-parallel GraphBLAS-style baseline),
//! plus **live** sections measuring the threaded rank-parallel engine's
//! batched SpMM path at 1 vs 4 ranks on real OS threads and the split-CSR
//! **overlap-vs-blocking** speedup on the bundled digits workload.
//!
//! `cargo bench --bench table2_throughput` — `SPDNN_FULL=1` adds the
//! deeper (480/1920-layer) configurations of the paper;
//! `SPDNN_SECTION=overlap` runs only the overlap-vs-blocking section,
//! `SPDNN_SECTION=pipeline` only the pipelined-vs-overlap section,
//! `SPDNN_SECTION=codec` only the wire-codec section,
//! `SPDNN_SECTION=graphchallenge` only the ≥1M-edge Graph Challenge
//! edges/sec sweep, `SPDNN_SECTION=obs` only the tracing-overhead
//! section, and `SPDNN_SECTION=replica` only the replica-group training
//! scaling sweep (the CI bench-smoke paths); `SPDNN_ENFORCE=1` fails
//! the run if the overlapped engine does not beat the blocking engine by
//! ≥ 1.15× at 4 ranks, the pipelined engine loses to the overlap
//! baseline, the f16 wire codec loses throughput / fails to ~halve
//! bytes-on-wire / shifts digits SGD loss by more than 1%, a Graph
//! Challenge engine reports no throughput, flight-recorder tracing
//! costs more than 3% of throughput (off-mode vs the plain build path,
//! and on-mode vs off-mode), or the replica-group bars break (R=2
//! training ≥ 1.5× one group when the cores exist, int8+EF gradient
//! exchange ≤ 0.35× the f32 bytes with tail loss within 1%). Schemas of
//! the emitted `BENCH_*.json` files are documented in
//! `docs/BENCHMARKS.md`.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::comm::Codec;
use spdnn::coordinator::sgd::infer_with_plan;
use spdnn::coordinator::{ExecMode, RankScratch, RankState};
use spdnn::data::synthetic_mnist;
use spdnn::dnn::inference::infer_batch_parallel;
use spdnn::experiments::{ablation, graphchallenge, replica as replica_bench, table2};
use spdnn::obs::{TraceMode, DEFAULT_TRACE_CAPACITY};
use spdnn::partition::{contiguous_partition, CommPlan};
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::runtime::parallel::run_ranks;
use spdnn::serving::{PoolConfig, RankPool};
use spdnn::util::{Rng, Stopwatch};
use std::time::Duration;

/// Acceptance bar for the overlapped engine at 4 ranks (enforced in the
/// CI bench-smoke job via `SPDNN_ENFORCE=1`).
const OVERLAP_BAR: f64 = 1.15;

/// Acceptance bar for the pipelined engine vs the overlap baseline at
/// 4 ranks: posting sends at boundary-row granularity must at minimum not
/// lose to the whole-layer send schedule (enforced only under
/// `SPDNN_ENFORCE=1` — repo convention, bars are unverifiable on dev
/// laptops).
const PIPELINE_BAR: f64 = 1.0;

/// Overlap-vs-blocking on the bundled digits workload: the same net,
/// partition, plan, and digit batch pushed through both engines; edges/s
/// of the better of `reps` passes per engine (alternating, so OS noise
/// hits both evenly). Writes `BENCH_overlap.json`.
fn overlap_section(full: bool, enforce: bool) {
    let (n, l, ranks) = (1024usize, 24usize, 4usize);
    let b = 16usize; // small batches keep the per-layer sync cost visible
    let passes = if full { 128usize } else { 48 };
    let reps = 3usize;
    println!("# Overlap vs blocking (split-CSR, digits workload, {ranks} ranks)");
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let side = (n as f64).sqrt() as usize;
    let data = synthetic_mnist(side, b, 42);
    let (x0, b) = data.pack_batch(0, b);
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);

    // Steady-state serving loop, like a pool generation: rank threads,
    // states, and scratch built once per engine, only the per-pass layer
    // schedule on the clock. Wall time = slowest rank's loop.
    let eps_of = |mode: ExecMode| -> f64 {
        let run = run_ranks(ranks, |rank, ep| {
            let mut state = RankState::build(&net, &part, &plan, rank as u32, mode);
            let mut scratch = RankScratch::new();
            let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch); // warm-up
            let sw = Stopwatch::start();
            for _ in 0..passes {
                let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch);
            }
            sw.elapsed_secs()
        })
        .expect("overlap bench run failed");
        let secs = run.outputs.into_iter().fold(0f64, f64::max);
        net.total_nnz() as f64 * (passes * b) as f64 / secs
    };
    let mut eps_block = 0f64;
    let mut eps_overlap = 0f64;
    for _ in 0..reps {
        eps_block = eps_block.max(eps_of(ExecMode::Blocking));
        eps_overlap = eps_overlap.max(eps_of(ExecMode::Overlap));
    }
    let speedup = eps_overlap / eps_block;
    println!(
        "[bench] overlap N={n} L={l} b={b} ranks={ranks}: blocking {eps_block:.2E} edges/s, \
         overlap {eps_overlap:.2E} edges/s (speedup {speedup:.2}x, bar {OVERLAP_BAR}x)"
    );
    let json = format!(
        "{{\"neurons\":{n},\"layers\":{l},\"batch\":{b},\"ranks\":{ranks},\
         \"passes\":{passes},\"blocking_eps\":{eps_block:.1},\
         \"overlap_eps\":{eps_overlap:.1},\"speedup\":{speedup:.4},\
         \"bar\":{OVERLAP_BAR}}}"
    );
    std::fs::write("BENCH_overlap.json", &json).expect("write BENCH_overlap.json");
    println!("wrote BENCH_overlap.json: {json}");
    if enforce {
        assert!(
            speedup >= OVERLAP_BAR,
            "overlap speedup {speedup:.3}x below the {OVERLAP_BAR}x bar"
        );
    }
}

/// Pipelined-vs-overlap on the bundled digits workload: the same net,
/// partition, plan, and digit batch pushed through the send-side
/// pipelined engine and the whole-layer-send overlap baseline; edges/s of
/// the better of `reps` passes per engine. Writes `BENCH_pipeline.json`.
fn pipeline_section(full: bool, enforce: bool) {
    let (n, l, ranks) = (1024usize, 24usize, 4usize);
    let b = 16usize; // small batches keep the per-layer sync cost visible
    let passes = if full { 128usize } else { 48 };
    let reps = 3usize;
    let chunk_acts = spdnn::coordinator::DEFAULT_CHUNK_ACTS;
    println!("# Pipelined vs overlap (send-side row-range pipelining, digits workload, {ranks} ranks)");
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let side = (n as f64).sqrt() as usize;
    let data = synthetic_mnist(side, b, 42);
    let (x0, b) = data.pack_batch(0, b);
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);

    let eps_of = |mode: ExecMode| -> f64 {
        let run = run_ranks(ranks, |rank, ep| {
            let mut state = RankState::build(&net, &part, &plan, rank as u32, mode);
            let mut scratch = RankScratch::new();
            let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch); // warm-up
            let sw = Stopwatch::start();
            for _ in 0..passes {
                let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch);
            }
            sw.elapsed_secs()
        })
        .expect("pipeline bench run failed");
        let secs = run.outputs.into_iter().fold(0f64, f64::max);
        net.total_nnz() as f64 * (passes * b) as f64 / secs
    };
    let mut eps_overlap = 0f64;
    let mut eps_pipeline = 0f64;
    for _ in 0..reps {
        eps_overlap = eps_overlap.max(eps_of(ExecMode::Overlap));
        eps_pipeline = eps_pipeline.max(eps_of(ExecMode::Pipelined { chunk_acts }));
    }
    let speedup = eps_pipeline / eps_overlap;
    println!(
        "[bench] pipeline N={n} L={l} b={b} ranks={ranks} chunk={chunk_acts}: \
         overlap {eps_overlap:.2E} edges/s, pipelined {eps_pipeline:.2E} edges/s \
         (speedup {speedup:.2}x, bar {PIPELINE_BAR}x)"
    );
    let json = format!(
        "{{\"neurons\":{n},\"layers\":{l},\"batch\":{b},\"ranks\":{ranks},\
         \"passes\":{passes},\"chunk_acts\":{chunk_acts},\
         \"overlap_eps\":{eps_overlap:.1},\"pipelined_eps\":{eps_pipeline:.1},\
         \"speedup\":{speedup:.4},\"bar\":{PIPELINE_BAR}}}"
    );
    std::fs::write("BENCH_pipeline.json", &json).expect("write BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json: {json}");
    if enforce {
        assert!(
            speedup >= PIPELINE_BAR,
            "pipelined speedup {speedup:.3}x below the {PIPELINE_BAR}x bar"
        );
    }
}

/// Acceptance bars for the wire-codec section (enforced only under
/// `SPDNN_ENFORCE=1`): f16 must not lose throughput to the raw-f32 wire
/// at 4 ranks, must at least ~halve the measured bytes-on-wire, and must
/// keep the digits SGD final loss within 1% of the f32 run.
const CODEC_EPS_BAR: f64 = 1.0;
const CODEC_BYTE_BAR: f64 = 0.55;
const CODEC_LOSS_BAR: f64 = 0.01;

/// Wire-codec section: the same digits workload pushed through the
/// overlapped engine with f32/f16/int8 fabric payloads — measured
/// bytes-on-wire and edges/s per codec, plus the digits SGD convergence
/// delta each codec costs. Writes `BENCH_codec.json`.
fn codec_section(full: bool, enforce: bool) {
    let (n, l, ranks) = (1024usize, 24usize, 4usize);
    let b = 16usize;
    let passes = if full { 128usize } else { 48 };
    let reps = 3usize;
    println!("# Wire codecs (f32 vs f16 vs int8 payloads, digits workload, {ranks} ranks)");
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let side = (n as f64).sqrt() as usize;
    let data = synthetic_mnist(side, b, 42);
    let (x0, b) = data.pack_batch(0, b);
    let part = contiguous_partition(&net.layers, ranks);

    // steady-state serving loop per codec (same harness as the overlap
    // section); bytes-on-wire measured from the live endpoint counters
    let measure = |codec: Codec| -> (f64, u64) {
        let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
        let mut best_eps = 0f64;
        let mut bytes_per_pass = 0u64;
        for _ in 0..reps {
            let run = run_ranks(ranks, |rank, ep| {
                let mut state =
                    RankState::build(&net, &part, &plan, rank as u32, ExecMode::Overlap);
                let mut scratch = RankScratch::new();
                let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch); // warm-up
                let sw = Stopwatch::start();
                for _ in 0..passes {
                    let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch);
                }
                sw.elapsed_secs()
            })
            .expect("codec bench run failed");
            let secs = run.outputs.into_iter().fold(0f64, f64::max);
            best_eps = best_eps.max(net.total_nnz() as f64 * (passes * b) as f64 / secs);
            // sent words count the warm-up pass too: passes + 1 in total
            let wire: u64 = 4 * run.sent.iter().map(|&(w, _)| w).sum::<u64>();
            bytes_per_pass = wire / (passes as u64 + 1);
        }
        (best_eps, bytes_per_pass)
    };

    // digits SGD convergence delta per codec (accuracy half of the table)
    let sgd_steps = if full { 400 } else { 150 };
    let sgd = ablation::codec_convergence(256, 8, ranks, sgd_steps, 0.1, 7);

    let codecs = [Codec::F32, Codec::F16, Codec::int8()];
    let mut eps = [0f64; 3];
    let mut bytes = [0u64; 3];
    for (i, &c) in codecs.iter().enumerate() {
        let (e, wb) = measure(c);
        eps[i] = e;
        bytes[i] = wb;
        println!(
            "[bench] codec {:>4}: {e:.2E} edges/s, {wb} B/pass on the wire, \
             SGD final loss {:.5} ({:+.3}% vs f32)",
            c.label(),
            sgd[i].final_loss,
            sgd[i].loss_delta * 100.0
        );
    }
    let f16_speedup = eps[1] / eps[0];
    let f16_byte_ratio = bytes[1] as f64 / bytes[0] as f64;
    println!(
        "[bench] f16 vs f32: {f16_speedup:.2}x throughput (bar {CODEC_EPS_BAR}x), \
         {f16_byte_ratio:.3} of the bytes (bar {CODEC_BYTE_BAR}), \
         SGD Δ {:+.3}% (bar ±{:.0}%)",
        sgd[1].loss_delta * 100.0,
        CODEC_LOSS_BAR * 100.0
    );
    let codec_rows: Vec<String> = (0..3)
        .map(|i| {
            format!(
                "{{\"codec\":\"{}\",\"eps\":{:.1},\"wire_bytes_per_pass\":{},\
                 \"sgd_final_loss\":{:.6},\"sgd_loss_delta\":{:.6}}}",
                codecs[i].label(),
                eps[i],
                bytes[i],
                sgd[i].final_loss,
                sgd[i].loss_delta
            )
        })
        .collect();
    let json = format!(
        "{{\"neurons\":{n},\"layers\":{l},\"batch\":{b},\"ranks\":{ranks},\
         \"passes\":{passes},\"codecs\":[{}],\"f16_speedup\":{f16_speedup:.4},\
         \"f16_byte_ratio\":{f16_byte_ratio:.4},\"eps_bar\":{CODEC_EPS_BAR},\
         \"byte_bar\":{CODEC_BYTE_BAR},\"loss_bar\":{CODEC_LOSS_BAR}}}",
        codec_rows.join(",")
    );
    std::fs::write("BENCH_codec.json", &json).expect("write BENCH_codec.json");
    println!("wrote BENCH_codec.json: {json}");
    if enforce {
        assert!(
            f16_byte_ratio <= CODEC_BYTE_BAR,
            "f16 shipped {f16_byte_ratio:.3} of the f32 bytes, above the {CODEC_BYTE_BAR} bar"
        );
        assert!(
            sgd[1].loss_delta.abs() <= CODEC_LOSS_BAR,
            "f16 digits SGD loss delta {:.4} outside the ±{CODEC_LOSS_BAR} bar",
            sgd[1].loss_delta
        );
        assert!(
            f16_speedup >= CODEC_EPS_BAR,
            "f16 throughput {f16_speedup:.3}x below the {CODEC_EPS_BAR}x bar"
        );
    }
}

/// Acceptance bar for the flight recorder (enforced only under
/// `SPDNN_ENFORCE=1`): the disabled tracer must keep ≥ 97% of the plain
/// build path's throughput, and tracing **on** must keep ≥ 97% of the
/// off-mode throughput.
const OBS_BAR: f64 = 0.97;

/// Tracing-overhead section: the digits workload pushed through the
/// overlapped engine three ways — the plain [`RankState::build`] path
/// (tracing resolved from the unset `SPDNN_TRACE`, i.e. the pre-recorder
/// hot path), an explicit [`TraceMode::Off`] build, and tracing on at the
/// default ring capacity. Edges/s of the better of `reps` passes per
/// variant. Writes `BENCH_obs.json`.
fn obs_section(full: bool, enforce: bool) {
    let (n, l, ranks) = (1024usize, 24usize, 4usize);
    let b = 16usize;
    let passes = if full { 128usize } else { 48 };
    let reps = 3usize;
    println!("# Flight-recorder overhead (off vs on, digits workload, {ranks} ranks)");
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let side = (n as f64).sqrt() as usize;
    let data = synthetic_mnist(side, b, 42);
    let (x0, b) = data.pack_batch(0, b);
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);

    let eps_of = |trace: Option<TraceMode>| -> f64 {
        let run = run_ranks(ranks, |rank, ep| {
            let mode = ExecMode::Overlap;
            let mut state = match trace {
                Some(t) => RankState::build_traced(&net, &part, &plan, rank as u32, mode, t),
                None => RankState::build(&net, &part, &plan, rank as u32, mode),
            };
            let mut scratch = RankScratch::new();
            let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch); // warm-up
            let sw = Stopwatch::start();
            for _ in 0..passes {
                let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch);
            }
            sw.elapsed_secs()
        })
        .expect("obs bench run failed");
        let secs = run.outputs.into_iter().fold(0f64, f64::max);
        net.total_nnz() as f64 * (passes * b) as f64 / secs
    };
    let mut eps_base = 0f64;
    let mut eps_off = 0f64;
    let mut eps_on = 0f64;
    for _ in 0..reps {
        eps_base = eps_base.max(eps_of(None));
        eps_off = eps_off.max(eps_of(Some(TraceMode::Off)));
        eps_on = eps_on.max(eps_of(Some(TraceMode::with_capacity(DEFAULT_TRACE_CAPACITY))));
    }
    let off_ratio = eps_off / eps_base;
    let on_ratio = eps_on / eps_off;
    println!(
        "[bench] obs N={n} L={l} b={b} ranks={ranks}: plain {eps_base:.2E} edges/s, \
         trace-off {eps_off:.2E} ({off_ratio:.3}x), trace-on {eps_on:.2E} \
         ({on_ratio:.3}x of off, bar {OBS_BAR}x)"
    );
    let json = format!(
        "{{\"neurons\":{n},\"layers\":{l},\"batch\":{b},\"ranks\":{ranks},\
         \"passes\":{passes},\"plain_eps\":{eps_base:.1},\"trace_off_eps\":{eps_off:.1},\
         \"trace_on_eps\":{eps_on:.1},\"off_ratio\":{off_ratio:.4},\
         \"on_ratio\":{on_ratio:.4},\"bar\":{OBS_BAR}}}"
    );
    std::fs::write("BENCH_obs.json", &json).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json: {json}");
    if enforce {
        assert!(
            off_ratio >= OBS_BAR,
            "disabled tracer kept only {off_ratio:.3}x of plain-path throughput, \
             below the {OBS_BAR}x bar"
        );
        assert!(
            on_ratio >= OBS_BAR,
            "enabled tracing kept only {on_ratio:.3}x of off-mode throughput, \
             below the {OBS_BAR}x bar"
        );
    }
}

/// Graph Challenge section: a ≥1M-edge RadixNet (N=1024, L=32, the
/// challenge's constant 1/16 weights, −0.3 bias, clipped ReLU) streamed
/// through all three engines plus the serving pool, on f32 and f16 wires
/// — edges/sec per combo into `BENCH_graphchallenge.json`. Category sets
/// are cross-checked against the serial reference inside the driver;
/// `SPDNN_ENFORCE=1` additionally requires the network to clear the
/// 1M-edge line and every combo to report nonzero throughput.
fn graphchallenge_section(full: bool, enforce: bool) {
    let cfg = graphchallenge::GcConfig {
        inputs: if full { 2048 } else { 128 },
        codecs: vec![Codec::F32, Codec::F16],
        ..graphchallenge::GcConfig::default()
    };
    println!(
        "# Graph Challenge edges/sec (RadixNet N={} L={}, {} ranks)",
        cfg.neurons, cfg.layers, cfg.ranks[0]
    );
    let rep = graphchallenge::run(&cfg);
    println!("{}", graphchallenge::render(&rep));
    let json = graphchallenge::to_json(&rep);
    std::fs::write("BENCH_graphchallenge.json", &json).expect("write BENCH_graphchallenge.json");
    println!("wrote BENCH_graphchallenge.json: {json}");
    if enforce {
        assert!(
            rep.edges >= 1_000_000,
            "Graph Challenge net has {} edges, below the 1M line",
            rep.edges
        );
        for r in &rep.rows {
            assert!(
                r.secs > 0.0 && r.edges_per_sec > 0.0,
                "{} engine (codec {}) reported no throughput",
                r.engine,
                r.codec
            );
        }
    }
}

/// Replica-group training section: the `experiments::replica` scaling
/// sweep (digits SGD at R ∈ {1, 2, 4} replica groups × engines ×
/// gradient codecs). Writes `BENCH_replica.json`; under
/// `SPDNN_ENFORCE=1` the scaling / compression / EF-loss bars are hard
/// failures (`replica::enforce`, which itself skips the speedup bar on
/// hosts without `2 × ranks` hardware threads).
fn replica_section(full: bool, enforce: bool) {
    let cfg = replica_bench::ReplicaBenchConfig {
        epochs: if full { 6 } else { 3 },
        ..replica_bench::ReplicaBenchConfig::default()
    };
    println!(
        "# Replica-group training (hybrid data x model parallelism, {} ranks/group)",
        cfg.ranks
    );
    let rep = replica_bench::run(&cfg);
    println!("{}", replica_bench::render(&rep));
    let json = replica_bench::to_json(&rep);
    std::fs::write("BENCH_replica.json", &json).expect("write BENCH_replica.json");
    println!("wrote BENCH_replica.json: {json}");
    if enforce {
        replica_bench::enforce(&rep);
    }
}

/// Live threaded engine: edges/s of the batched fused-SpMM inference path
/// at `ranks`, with partition + plan built once (the serving setup cost is
/// off the clock, as in a real request loop).
fn live_parallel_eps(net: &spdnn::dnn::SparseNet, b: usize, inputs: usize, ranks: usize) -> f64 {
    let part = contiguous_partition(&net.layers, ranks);
    let plan = CommPlan::build(&net.layers, &part);
    let d = net.input_dim();
    let mut rng = Rng::new(42);
    let x0: Vec<f32> = (0..d * b)
        .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
        .collect();
    // warm-up (thread spawn + caches)
    let _ = infer_with_plan(net, &part, &plan, &x0, b);
    let mut processed = 0usize;
    let sw = Stopwatch::start();
    while processed < inputs {
        let _ = infer_with_plan(net, &part, &plan, &x0, b);
        processed += b;
    }
    let secs = sw.elapsed_secs();
    net.total_nnz() as f64 * processed as f64 / secs
}

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let enforce = std::env::var("SPDNN_ENFORCE").is_ok();
    match std::env::var("SPDNN_SECTION").as_deref() {
        Ok("overlap") => {
            // CI bench-smoke path: just the overlap-vs-blocking bar
            overlap_section(full, enforce);
            return;
        }
        Ok("pipeline") => {
            // CI bench-smoke path: just the pipelined-vs-overlap bar
            pipeline_section(full, enforce);
            return;
        }
        Ok("codec") => {
            // CI bench-smoke path: wire-codec throughput/bytes/accuracy bars
            codec_section(full, enforce);
            return;
        }
        Ok("graphchallenge") => {
            // CI bench-smoke path: ≥1M-edge RadixNet edges/sec sweep
            graphchallenge_section(full, enforce);
            return;
        }
        Ok("obs") => {
            // CI bench-smoke path: flight-recorder overhead bars
            obs_section(full, enforce);
            return;
        }
        Ok("replica") => {
            // CI bench-smoke path: replica-group scaling/compression bars
            replica_section(full, enforce);
            return;
        }
        _ => {}
    }
    // (neurons, layers) grid; the paper runs L ∈ {120, 480, 1920} at each N
    let grid: Vec<(usize, usize)> = if full {
        let mut g = Vec::new();
        for &n in &[1024usize, 4096, 16384, 65536] {
            for &l in &[120usize, 480, 1920] {
                g.push((n, l));
            }
        }
        g
    } else {
        vec![(1024, 24), (1024, 96), (4096, 24), (4096, 96)]
    };
    let comp = ComputeModel::calibrate();
    let cfg = table2::Config {
        nparts: 128,
        batch: 64,
        inputs: if full { 60_000 } else { 4096 },
        gb_sample: if full { 256 } else { 64 },
    };
    println!("# Table 2 reproduction (H-SpFF P={}, full={full})", cfg.nparts);
    let mut rows = Vec::new();
    for (n, l) in grid {
        let sw = Stopwatch::start();
        let row = table2::run(n, l, &cfg, comp, 1);
        let secs = sw.elapsed_secs();
        println!(
            "[bench] N={n} L={l}: H-SpFF {:.2E} vs GB {:.2E} edges/s (speedup {:.2}) in {secs:.1}s",
            row.hspff_eps,
            row.gb_eps,
            row.speedup()
        );
        rows.push(row);
    }
    println!("\n{}", table2::render(&rows));

    // Live rank-parallel engine: real threads, batched fused SpMM. The
    // 4-rank figure must beat the 1-rank figure on any multi-core host.
    println!("# Live threaded engine (batched SpMM, contiguous blocks)");
    let (n, l, b) = (1024usize, 24usize, 64usize);
    let inputs = if full { 8192 } else { 1024 };
    let net = generate(&RadixNetConfig::graph_challenge(n, l).expect("cfg"));
    let eps1 = live_parallel_eps(&net, b, inputs, 1);
    let eps4 = live_parallel_eps(&net, b, inputs, 4);
    println!(
        "[bench] live N={n} L={l} b={b}: 1 rank {eps1:.2E} edges/s, 4 ranks {eps4:.2E} edges/s \
         (speedup {:.2}x)",
        eps4 / eps1
    );

    // Persistent rank pool vs per-request respawn: the pool keeps rank
    // threads + states + plan alive across the stream, the one-shot path
    // rebuilds partition, plan, states, and threads on every request.
    // Acceptance bar: pool ≥ 1.3× edges/s at 4 ranks over ≥ 32 requests.
    println!("# Persistent pool vs one-shot respawn (sustained serving)");
    let (reqs, pb, pranks) = (if full { 128usize } else { 32 }, 16usize, 4usize);
    let mut rng = Rng::new(7);
    let x0: Vec<f32> = (0..net.input_dim() * pb)
        .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
        .collect();

    let _ = infer_batch_parallel(&net, &x0, pb, pranks); // warm-up
    let sw = Stopwatch::start();
    for _ in 0..reqs {
        let _ = infer_batch_parallel(&net, &x0, pb, pranks);
    }
    let oneshot_secs = sw.elapsed_secs();

    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: pranks,
            max_batch: 4 * pb,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let _ = pool.submit(x0.clone(), pb).wait().expect("warm-up"); // warm-up
    let sw = Stopwatch::start();
    let tickets: Vec<_> = (0..reqs).map(|_| pool.submit(x0.clone(), pb)).collect();
    for t in tickets {
        let _ = t.wait().expect("pool request failed");
    }
    let pool_secs = sw.elapsed_secs();
    let snap = pool.stats();
    let _ = pool.shutdown();

    let edges = net.total_nnz() as f64 * (reqs * pb) as f64;
    println!(
        "[bench] serving {reqs} requests × b={pb} at {pranks} ranks: \
         one-shot {:.2E} edges/s, pool {:.2E} edges/s (pool/one-shot {:.2}x)",
        edges / oneshot_secs,
        edges / pool_secs,
        oneshot_secs / pool_secs
    );
    println!(
        "[bench] pool latency p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms \
         (mean batch {:.1} cols over {} dispatches)",
        snap.p50_secs * 1e3,
        snap.p95_secs * 1e3,
        snap.p99_secs * 1e3,
        snap.mean_batch,
        snap.batches
    );

    println!();
    overlap_section(full, enforce);
    println!();
    pipeline_section(full, enforce);
    println!();
    codec_section(full, enforce);
    println!();
    graphchallenge_section(full, enforce);
    println!();
    obs_section(full, enforce);
    println!();
    replica_section(full, enforce);
}
