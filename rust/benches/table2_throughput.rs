//! Bench/regenerator for **Table 2**: inference throughput (edges/s),
//! H-SpFF (model-parallel) vs GB (data-parallel GraphBLAS-style baseline).
//!
//! `cargo bench --bench table2_throughput` — `SPDNN_FULL=1` adds the
//! deeper (480/1920-layer) configurations of the paper.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::experiments::table2;
use spdnn::util::Stopwatch;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    // (neurons, layers) grid; the paper runs L ∈ {120, 480, 1920} at each N
    let grid: Vec<(usize, usize)> = if full {
        let mut g = Vec::new();
        for &n in &[1024usize, 4096, 16384, 65536] {
            for &l in &[120usize, 480, 1920] {
                g.push((n, l));
            }
        }
        g
    } else {
        vec![(1024, 24), (1024, 96), (4096, 24), (4096, 96)]
    };
    let comp = ComputeModel::calibrate();
    let cfg = table2::Config {
        nparts: 128,
        batch: 64,
        inputs: if full { 60_000 } else { 4096 },
        gb_sample: if full { 256 } else { 64 },
    };
    println!("# Table 2 reproduction (H-SpFF P={}, full={full})", cfg.nparts);
    let mut rows = Vec::new();
    for (n, l) in grid {
        let sw = Stopwatch::start();
        let row = table2::run(n, l, &cfg, comp, 1);
        let secs = sw.elapsed_secs();
        println!(
            "[bench] N={n} L={l}: H-SpFF {:.2E} vs GB {:.2E} edges/s (speedup {:.2}) in {secs:.1}s",
            row.hspff_eps,
            row.gb_eps,
            row.speedup()
        );
        rows.push(row);
    }
    println!("\n{}", table2::render(&rows));
}
