//! Bench/regenerator for the **fixed-vertex chaining ablation** (the design
//! choice DESIGN.md §5 isolates): chained multi-phase (paper) vs
//! independent per-layer partitioning vs random.
//!
//! `cargo bench --bench ablation_chaining` — `SPDNN_FULL=1` for larger N/P.

use spdnn::experiments::ablation;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (vec![1024, 4096], vec![32, 128], 120)
    } else {
        (vec![1024], vec![8, 32], 24)
    };
    println!("# Chaining ablation (L={layers}, full={full})");
    for n in ns {
        for &p in &ps {
            let rows = ablation::run(n, layers, p, 1);
            println!("{}", ablation::render(n, p, &rows));
        }
    }
}
