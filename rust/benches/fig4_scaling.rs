//! Bench/regenerator for **Figure 4**: strong scaling of SGD vs H-SGD
//! (simulated seconds/input over processor counts).
//!
//! `cargo bench --bench fig4_scaling` — `SPDNN_FULL=1` for the paper grid.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::experiments::fig4_scaling;
use spdnn::util::Stopwatch;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (
            vec![1024, 4096, 16384, 65536],
            vec![32, 64, 128, 256, 512],
            120,
        )
    } else {
        (vec![1024, 4096], vec![8, 16, 32, 64, 128], 24)
    };
    let comp = ComputeModel::calibrate();
    println!("# Figure 4 reproduction (L={layers}, full={full})");
    println!(
        "calibrated: spmv {:.2e}s/nnz, spmv_t {:.2e}s/nnz, update {:.2e}s/nnz",
        comp.spmv_per_nnz, comp.spmvt_per_nnz, comp.update_per_nnz
    );
    for n in ns {
        let sw = Stopwatch::start();
        let pts = fig4_scaling::run(n, layers, &ps, comp, 1);
        let secs = sw.elapsed_secs();
        println!("{}", fig4_scaling::render(n, &pts));
        println!("[bench] N={n}: computed in {secs:.2}s\n");
    }
}
