//! Bench/regenerator for **Table 3**: hypergraph partitioning
//! (preprocessing) times per network size and processor count.
//!
//! `cargo bench --bench table3_ptimes` — `SPDNN_FULL=1` for the paper grid.

use spdnn::experiments::table3;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (
            vec![1024, 4096, 16384, 65536],
            vec![32, 64, 128, 256, 512],
            120,
        )
    } else {
        (vec![1024, 4096], vec![8, 16, 32], 24)
    };
    println!("# Table 3 reproduction (L={layers}, full={full})");
    for n in ns {
        let rows = table3::run(n, layers, &ps, 1);
        println!("{}", table3::render(&rows));
    }
}
