//! Bench/regenerator for **Figure 5**: breakdown of running time into
//! SpMV / Updt / Comm components, H-SGD (solid) vs SGD (tiled).
//!
//! `cargo bench --bench fig5_breakdown` — `SPDNN_FULL=1` for the paper grid.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::experiments::fig5_breakdown;
use spdnn::util::Stopwatch;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (vec![16384, 65536], vec![32, 128, 512], 120)
    } else {
        (vec![1024, 4096], vec![8, 32, 128], 24)
    };
    let comp = ComputeModel::calibrate();
    println!("# Figure 5 reproduction (L={layers}, full={full})");
    for n in ns {
        let sw = Stopwatch::start();
        let bars = fig5_breakdown::run(n, layers, &ps, comp, 1);
        let secs = sw.elapsed_secs();
        println!("{}", fig5_breakdown::render(n, &bars));
        println!("[bench] N={n}: computed in {secs:.2}s\n");
    }

    // Live engines on real threads: how much of the blocking schedule's
    // receive stall does the split-CSR overlapped engine hide?
    println!("# Live blocking-vs-overlap training breakdown (real threads)");
    let (n, l, p, samples) = if full { (4096, 24, 8, 32) } else { (1024, 12, 4, 16) };
    let sw = Stopwatch::start();
    let live = fig5_breakdown::run_live(n, l, p, samples, 1);
    println!("{}", fig5_breakdown::render_live(&live));
    println!("[bench] live N={n} L={l} P={p}: measured in {:.2}s", sw.elapsed_secs());
}
