//! Bench/regenerator for **Figure 5**: breakdown of running time into
//! SpMV / Updt / Comm components, H-SGD (solid) vs SGD (tiled).
//!
//! `cargo bench --bench fig5_breakdown` — `SPDNN_FULL=1` for the paper grid.

use spdnn::comm::netmodel::ComputeModel;
use spdnn::experiments::fig5_breakdown;
use spdnn::util::Stopwatch;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (vec![16384, 65536], vec![32, 128, 512], 120)
    } else {
        (vec![1024, 4096], vec![8, 32, 128], 24)
    };
    let comp = ComputeModel::calibrate();
    println!("# Figure 5 reproduction (L={layers}, full={full})");
    for n in ns {
        let sw = Stopwatch::start();
        let bars = fig5_breakdown::run(n, layers, &ps, comp, 1);
        let secs = sw.elapsed_secs();
        println!("{}", fig5_breakdown::render(n, &bars));
        println!("[bench] N={n}: computed in {secs:.2}s\n");
    }
}
