//! Bench/regenerator for **Table 1**: communication volume, message
//! counts, and computational imbalance — H-SGD vs SGD(random).
//!
//! `cargo bench --bench table1_comm` — set `SPDNN_FULL=1` for the paper's
//! grid (N up to 65536, P up to 512, L=120; slow on one core).

use spdnn::experiments::table1;
use spdnn::util::Stopwatch;

fn main() {
    let full = std::env::var("SPDNN_FULL").is_ok();
    let (ns, ps, layers): (Vec<usize>, Vec<usize>, usize) = if full {
        (
            vec![1024, 4096, 16384, 65536],
            vec![32, 64, 128, 256, 512],
            120,
        )
    } else {
        (vec![1024, 4096], vec![4, 8, 16, 32], 24)
    };
    println!("# Table 1 reproduction (L={layers}, full={full})");
    for n in ns {
        let sw = Stopwatch::start();
        let rows = table1::run(n, layers, &ps, 1);
        let secs = sw.elapsed_secs();
        println!("{}", table1::render(&rows));
        println!("[bench] N={n}: computed in {secs:.2}s\n");
    }
}
