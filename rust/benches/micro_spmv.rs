//! Microbenchmark of the L3 hot-path kernels: CSR SpMV, transpose SpMV,
//! gradient update, batched SpMM — with a STREAM-style roofline estimate
//! for the §Perf target (EXPERIMENTS.md).
//!
//! `cargo bench --bench micro_spmv`

use spdnn::sparse::Coo;
use spdnn::util::{Rng, Stopwatch};

fn radix_like(n: usize, deg: usize, seed: u64) -> spdnn::sparse::Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * deg);
    for r in 0..n {
        for c in rng.sample_distinct(n, deg) {
            coo.push(r, c as usize, rng.gen_f32_range(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

fn bench<F: FnMut()>(label: &str, nnz: usize, reps: usize, mut f: F) -> f64 {
    // warm-up
    f();
    let sw = Stopwatch::start();
    for _ in 0..reps {
        f();
    }
    let secs = sw.elapsed_secs() / reps as f64;
    let per_nnz = secs / nnz as f64;
    let gflops = 2.0 * nnz as f64 / secs / 1e9;
    println!("{label:<28} {secs:>10.3e}s  {per_nnz:>8.2e}s/nnz  {gflops:>6.2} GFLOP/s");
    per_nnz
}

fn main() {
    println!("# micro_spmv — L3 hot-path kernel rates");
    let mut rng = Rng::new(7);
    for &(n, deg) in &[(1024usize, 32usize), (4096, 32), (16384, 27)] {
        let m = radix_like(n, deg, 1);
        let nnz = m.nnz();
        let x: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let mut y = vec![0f32; n];
        let reps = (20_000_000 / nnz).max(3);
        println!("\n== N={n} deg={deg} nnz={nnz} reps={reps}");
        bench(&format!("spmv {n}"), nnz, reps, || {
            m.spmv(&x, &mut y);
        });
        let mut s = vec![0f32; n];
        bench(&format!("spmv_t {n}"), nnz, reps, || {
            s.fill(0.0);
            m.spmv_t_add(&y, &mut s);
        });
        let mut mu = m.clone();
        bench(&format!("sgd_update {n}"), nnz, reps, || {
            mu.sgd_update(&y, &x, 1e-7);
        });
        let b = 16usize;
        let xb: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let mut yb = vec![0f32; n * b];
        let spmm_reps = (reps / b).max(2);
        bench(&format!("spmm b={b} {n}"), nnz * b, spmm_reps, || {
            m.spmm_rowmajor(&xb, &mut yb, b);
        });
    }

    // STREAM-style memory roofline: an SpMV of nnz entries moves ≥
    // nnz·(4B val + 4B idx) + vectors; time a pure streaming pass to bound
    // achievable bandwidth and report the SpMV efficiency against it.
    println!("\n== roofline estimate");
    let len = 32_000_000usize;
    let a: Vec<f32> = vec![1.0; len];
    // 8-way unrolled sum so the float dependency chain does not serialize
    // the loads — this measures bandwidth, not add latency.
    let sw = Stopwatch::start();
    let mut accs = [0f32; 8];
    for chunk in a.chunks_exact(8) {
        for i in 0..8 {
            accs[i] += chunk[i];
        }
    }
    let stream_secs = sw.elapsed_secs();
    std::hint::black_box(accs);
    let bw = (len * 4) as f64 / stream_secs / 1e9;
    println!("stream read bandwidth ≈ {bw:.1} GB/s");
    let m = radix_like(4096, 32, 2);
    let x: Vec<f32> = vec![1.0; 4096];
    let mut y = vec![0f32; 4096];
    let per_nnz = bench("spmv 4096 (roofline cmp)", m.nnz(), 100, || {
        m.spmv(&x, &mut y);
    });
    // bytes per nnz ≈ 8 (val+idx) + amortized vector traffic ≈ 9–12;
    // efficiency is capped at 100% (the matrix fits in cache at N=4096, so
    // the effective bandwidth can exceed DRAM stream bandwidth).
    let bound = 9.0 / (bw * 1e9);
    println!(
        "memory-bound minimum ≈ {bound:.2e}s/nnz → SpMV roofline efficiency ≈ {:.0}%",
        (100.0 * bound / per_nnz).min(100.0)
    );
}
