//! Chaos soak: the serving pool under a sustained seeded fault stream —
//! injected panics, stalls, dropped sends, and payload bit-flips — across
//! several rank counts. The assertions are structural, not fault-exact
//! (which faults land depends on scheduling): the pool must never
//! deadlock, every ticket must resolve to `Ok` or a typed error, every
//! `Ok` must match the serial engine, respawns must stay bounded by the
//! injected-fault budget, and once the stream is disarmed the pool must
//! serve cleanly again.

use spdnn::coordinator::ExecMode;
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::SparseNet;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::runtime::{FaultPlan, FaultSpec};
use spdnn::serving::{PoolConfig, RankPool, RecoveryConfig, ServeError, Ticket};
use spdnn::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll a ticket to resolution with a hard deadline: a ticket that never
/// resolves means the pool deadlocked — exactly the failure mode the
/// watchdog/poisoning machinery exists to prevent.
fn resolve(t: &Ticket, deadline: Duration, ctx: &str) -> Result<Vec<f32>, ServeError> {
    let start = Instant::now();
    loop {
        if let Some(reply) = t.poll() {
            return reply;
        }
        assert!(
            start.elapsed() < deadline,
            "{ctx}: ticket unresolved after {deadline:?} — the pool deadlocked"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn random_input(rng: &mut Rng, n: usize, b: usize) -> Vec<f32> {
    (0..n * b)
        .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
        .collect()
}

fn soak(nranks: usize, requests: usize, seed: u64) {
    let net: SparseNet = generate(&RadixNetConfig::graph_challenge(64, 3).expect("cfg"));
    let plan = FaultPlan::new(FaultSpec {
        seed,
        delay_p: 0.05,
        delay_us: 100,
        panic_p: 0.02,
        stall_p: 0.01,
        stall_ms: 300,
        flip_p: 0.01,
        drop_p: 0.01,
        watchdog_ms: 120,
        budget: 6,
        ..FaultSpec::default()
    });
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks,
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            adaptive: true,
            mode: ExecMode::pipelined(),
            faults: Some(Arc::clone(&plan)),
            recovery: RecoveryConfig {
                retry_budget: 3,
                backoff_base: Duration::from_millis(2),
                backoff_cap: Duration::from_millis(20),
                // the soak exercises requeue/respawn, not the breaker
                // (tested in serving_pool.rs): keep it from opening
                breaker_threshold: 64,
                breaker_cooldown: Duration::from_millis(100),
            },
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut inflight: Vec<(Vec<f32>, usize, Ticket)> = Vec::with_capacity(requests);
    for r in 0..requests {
        let b = 1 + (r % 4);
        let x0 = random_input(&mut rng, 64, b);
        let t = pool.submit(x0.clone(), b);
        inflight.push((x0, b, t));
    }
    let deadline = Duration::from_secs(60);
    let (mut ok, mut failed) = (0u64, 0u64);
    for (r, (x0, b, t)) in inflight.iter().enumerate() {
        let ctx = format!("soak r{nranks} req {r}");
        match resolve(t, deadline, &ctx) {
            Ok(out) => {
                ok += 1;
                let serial = infer_batch(&net, x0, *b);
                assert_eq!(out.len(), serial.len(), "{ctx}: shape");
                for (a, s) in out.iter().zip(serial.iter()) {
                    assert!((a - s).abs() < 1e-5, "{ctx}: {a} vs serial {s}");
                }
            }
            Err(e) => {
                failed += 1;
                assert!(
                    e.rank_failure().is_some() || e.is_unavailable(),
                    "{ctx}: unexpected error class: {e}"
                );
            }
        }
    }
    assert_eq!(ok + failed, requests as u64, "every ticket resolved");

    // the fault stream stops: the pool must serve cleanly again
    plan.disarm();
    for r in 0..10 {
        let b = 1 + (r % 3);
        let x0 = random_input(&mut rng, 64, b);
        let t = pool.submit(x0.clone(), b);
        let out = resolve(&t, deadline, &format!("clean tail req {r}"))
            .unwrap_or_else(|e| panic!("clean tail req {r} failed after disarm: {e}"));
        let serial = infer_batch(&net, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-5, "clean tail req {r}");
        }
    }

    let summary = pool.shutdown().expect("shutdown");
    let s = &summary.stats;
    assert!(
        summary.leaked_ranks.is_empty(),
        "messages leaked after chaos: ranks {:?}",
        summary.leaked_ranks
    );
    assert_eq!(s.requests, ok + 10, "stats agree with observed outcomes");
    assert_eq!(s.failed_requests, failed);
    assert!(
        s.generations_respawned <= plan.injected(),
        "every respawn must trace back to a budgeted fault: {} respawns, {} injected",
        s.generations_respawned,
        plan.injected()
    );
    assert!(
        plan.injected() <= 6,
        "the fault budget is a hard bound: {}",
        plan.injected()
    );
}

#[test]
fn chaos_soak_two_ranks() {
    soak(2, 70, 1001);
}

#[test]
fn chaos_soak_four_ranks() {
    soak(4, 70, 2002);
}

#[test]
fn chaos_soak_eight_ranks() {
    soak(8, 70, 3003);
}
