//! Chaos soak: the serving pool under a sustained seeded fault stream —
//! injected panics, stalls, dropped sends, and payload bit-flips — across
//! several rank counts. The assertions are structural, not fault-exact
//! (which faults land depends on scheduling): the pool must never
//! deadlock, every ticket must resolve to `Ok` or a typed error, every
//! `Ok` must match the serial engine, respawns must stay bounded by the
//! injected-fault budget, and once the stream is disarmed the pool must
//! serve cleanly again.

use spdnn::comm::{Codec, Phase};
use spdnn::coordinator::ExecMode;
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::SparseNet;
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::replica::{replica_serial_reference, train_replicas, ReplicaConfig};
use spdnn::runtime::{fault, run_groups, FaultPlan, FaultScope, FaultSpec};
use spdnn::serving::{PoolConfig, RankPool, RecoveryConfig, ServeError, Ticket};
use spdnn::util::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll a ticket to resolution with a hard deadline: a ticket that never
/// resolves means the pool deadlocked — exactly the failure mode the
/// watchdog/poisoning machinery exists to prevent.
fn resolve(t: &Ticket, deadline: Duration, ctx: &str) -> Result<Vec<f32>, ServeError> {
    let start = Instant::now();
    loop {
        if let Some(reply) = t.poll() {
            return reply;
        }
        assert!(
            start.elapsed() < deadline,
            "{ctx}: ticket unresolved after {deadline:?} — the pool deadlocked"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn random_input(rng: &mut Rng, n: usize, b: usize) -> Vec<f32> {
    (0..n * b)
        .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
        .collect()
}

fn soak(nranks: usize, requests: usize, seed: u64) {
    let net: SparseNet = generate(&RadixNetConfig::graph_challenge(64, 3).expect("cfg"));
    let plan = FaultPlan::new(FaultSpec {
        seed,
        delay_p: 0.05,
        delay_us: 100,
        panic_p: 0.02,
        stall_p: 0.01,
        stall_ms: 300,
        flip_p: 0.01,
        drop_p: 0.01,
        watchdog_ms: 120,
        budget: 6,
        ..FaultSpec::default()
    });
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks,
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            adaptive: true,
            mode: ExecMode::pipelined(),
            faults: Some(Arc::clone(&plan)),
            recovery: RecoveryConfig {
                retry_budget: 3,
                backoff_base: Duration::from_millis(2),
                backoff_cap: Duration::from_millis(20),
                // the soak exercises requeue/respawn, not the breaker
                // (tested in serving_pool.rs): keep it from opening
                breaker_threshold: 64,
                breaker_cooldown: Duration::from_millis(100),
            },
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut inflight: Vec<(Vec<f32>, usize, Ticket)> = Vec::with_capacity(requests);
    for r in 0..requests {
        let b = 1 + (r % 4);
        let x0 = random_input(&mut rng, 64, b);
        let t = pool.submit(x0.clone(), b);
        inflight.push((x0, b, t));
    }
    let deadline = Duration::from_secs(60);
    let (mut ok, mut failed) = (0u64, 0u64);
    for (r, (x0, b, t)) in inflight.iter().enumerate() {
        let ctx = format!("soak r{nranks} req {r}");
        match resolve(t, deadline, &ctx) {
            Ok(out) => {
                ok += 1;
                let serial = infer_batch(&net, x0, *b);
                assert_eq!(out.len(), serial.len(), "{ctx}: shape");
                for (a, s) in out.iter().zip(serial.iter()) {
                    assert!((a - s).abs() < 1e-5, "{ctx}: {a} vs serial {s}");
                }
            }
            Err(e) => {
                failed += 1;
                assert!(
                    e.rank_failure().is_some() || e.is_unavailable(),
                    "{ctx}: unexpected error class: {e}"
                );
            }
        }
    }
    assert_eq!(ok + failed, requests as u64, "every ticket resolved");

    // the fault stream stops: the pool must serve cleanly again
    plan.disarm();
    for r in 0..10 {
        let b = 1 + (r % 3);
        let x0 = random_input(&mut rng, 64, b);
        let t = pool.submit(x0.clone(), b);
        let out = resolve(&t, deadline, &format!("clean tail req {r}"))
            .unwrap_or_else(|e| panic!("clean tail req {r} failed after disarm: {e}"));
        let serial = infer_batch(&net, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-5, "clean tail req {r}");
        }
    }

    let summary = pool.shutdown().expect("shutdown");
    let s = &summary.stats;
    assert!(
        summary.leaked_ranks.is_empty(),
        "messages leaked after chaos: ranks {:?}",
        summary.leaked_ranks
    );
    assert_eq!(s.requests, ok + 10, "stats agree with observed outcomes");
    assert_eq!(s.failed_requests, failed);
    assert!(
        s.generations_respawned <= plan.injected(),
        "every respawn must trace back to a budgeted fault: {} respawns, {} injected",
        s.generations_respawned,
        plan.injected()
    );
    assert!(
        plan.injected() <= 6,
        "the fault budget is a hard bound: {}",
        plan.injected()
    );
}

#[test]
fn chaos_soak_two_ranks() {
    soak(2, 70, 1001);
}

#[test]
fn chaos_soak_four_ranks() {
    soak(4, 70, 2002);
}

#[test]
fn chaos_soak_eight_ranks() {
    soak(8, 70, 3003);
}

/// Replica-group chaos: the `SPDNN_FAULT` plan armed with a deterministic
/// message-drop schedule, scoped to replica group 0 only via
/// [`FaultScope::Group`]. Three phases share the process-wide plan (the
/// `SPDNN_FAULT` OnceLock makes this one test — the env var must be set
/// before the first `fault::from_env` call, and the soak tests above
/// never make one):
///
/// 1. group-independent workloads — the healthy groups' threads must all
///    finish while the armed group fails with the typed drop cause;
/// 2. a live replica training run — group 0's fault must propagate
///    through poisoning (no deadlock on the inter-group all-reduce ring)
///    and still triage to group 0 as the root cause;
/// 3. the stream disarms — the same topology trains cleanly under
///    [`FaultScope::Env`] and matches the single-thread replica
///    reference.
#[test]
fn replica_chaos_confines_faults_to_the_scoped_group() {
    std::env::set_var("SPDNN_FAULT", "seed=12,drop=1.0,budget=64,watchdog_ms=3000");
    let plan = fault::from_env().expect("SPDNN_FAULT parses");
    assert!(plan.armed());

    // Phase 1: no inter-group traffic at all — a fault campaign against
    // group 0 must leave every other group finishing cleanly.
    let (groups, nranks) = (3usize, 2usize);
    let done = AtomicU32::new(0);
    let err = run_groups(groups, nranks, FaultScope::Group(0), |g, j, intra, _inter| {
        for to in 0..nranks as u32 {
            if to != j as u32 {
                intra.send(to, 0, Phase::Forward, j as u32, vec![g as f32]);
            }
        }
        for from in 0..nranks as u32 {
            if from != j as u32 {
                intra.recv(from, 0, Phase::Forward, from);
            }
        }
        done.fetch_or(1 << (g * nranks + j), Ordering::Relaxed);
    })
    .expect_err("the armed group must fail");
    assert_eq!(err.group, 0, "fault escaped its scope: {err}");
    assert!(
        err.message.contains("dropped send"),
        "root cause must be the injected drop: {}",
        err.message
    );
    let finished = done.load(Ordering::Relaxed);
    for g in 1..groups {
        for j in 0..nranks {
            assert!(
                finished & (1 << (g * nranks + j)) != 0,
                "healthy group {g} rank {j} did not finish"
            );
        }
    }

    // Phase 2: a live replica training run with the same scope. Group 0's
    // first armed intra-group send drops; its thread poisons both of its
    // fabrics, so model-parallel peers and all-reduce partners unwind
    // instead of hanging, and the driver's failure panic names group 0.
    let net: SparseNet = generate(&RadixNetConfig {
        radices: vec![4, 4],
        layers: 4,
        seed: 17,
        ..RadixNetConfig::default()
    });
    let mut rng = Rng::new(5);
    let inputs: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            (0..16)
                .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let targets: Vec<Vec<f32>> = (0..8)
        .map(|i| {
            let mut y = vec![0f32; 16];
            y[i % 16] = 1.0;
            y
        })
        .collect();
    let part = random_partition(&net.layers, 2, 7);
    let cfg = ReplicaConfig {
        groups: 2,
        batch: 2,
        eta: 0.3,
        epochs: 1,
        mode: ExecMode::Overlap,
        codec: Codec::F32,
        scope: FaultScope::Group(0),
    };
    let payload = catch_unwind(AssertUnwindSafe(|| {
        train_replicas(&net, &part, &inputs, &targets, &cfg)
    }))
    .err()
    .expect("training with an armed group must fail");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string panic>".to_string());
    assert!(msg.contains("group 0"), "failure must name group 0: {msg}");
    assert!(
        msg.contains("dropped send"),
        "failure must carry the injected cause: {msg}"
    );
    let injected_during_chaos = plan.injected();
    assert!(
        injected_during_chaos >= 2,
        "both phases consumed budget: {injected_during_chaos}"
    );

    // Phase 3: faults stop. The identical topology trains cleanly under
    // the env scope (the installed plan is disarmed) and matches the
    // serial replica semantics.
    plan.disarm();
    let clean = ReplicaConfig {
        scope: FaultScope::Env,
        ..cfg
    };
    let run = train_replicas(&net, &part, &inputs, &targets, &clean);
    let (_, expect_losses) = replica_serial_reference(&net, &inputs, &targets, 2, 0.3, 1, 2);
    assert_eq!(run.losses.len(), expect_losses.len());
    for (a, e) in run.losses.iter().zip(expect_losses.iter()) {
        assert!((a - e).abs() < 1e-4, "clean run after disarm: {a} vs {e}");
    }
    assert_eq!(
        plan.injected(),
        injected_during_chaos,
        "a disarmed plan must not spend budget"
    );
}
