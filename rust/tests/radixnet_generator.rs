//! RadixNet generator acceptance tests: seeded determinism, topology
//! invariants against the radix spec, bit-identity of the streamed CSR
//! build vs a COO-built reference (the historical path), and serial ≡
//! distributed inference on a generated Graph Challenge network.

use spdnn::coordinator::sgd::infer_with_plan_mode;
use spdnn::coordinator::ExecMode;
use spdnn::dnn::inference::infer_batch;
use spdnn::partition::{contiguous_partition, CommPlan};
use spdnn::radixnet::topology::{stage_degree, stage_pattern};
use spdnn::radixnet::{
    categories, gc_input_batch, generate, generate_structure, RadixNetConfig,
};
use spdnn::sparse::{Coo, Csr};
use spdnn::util::Rng;

/// The pre-streaming reference build: materialize each layer's (row, col)
/// pairs, push them into a COO with the same RNG draw order the streamed
/// generator uses, and counting-sort to CSR.
fn coo_reference_layers(cfg: &RadixNetConfig) -> Vec<Csr> {
    let n = cfg.neurons();
    let d = cfg.radices.len();
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.layers)
        .map(|k| {
            let mut pairs = stage_pattern(&cfg.radices, k % d);
            if cfg.permute {
                let perm = rng.permutation(n);
                for (_, i) in pairs.iter_mut() {
                    *i = perm[*i as usize];
                }
            }
            let mut coo = Coo::with_capacity(n, n, pairs.len());
            for (j, i) in pairs {
                coo.push(j as usize, i as usize, cfg.weights.draw(&mut rng));
            }
            coo.to_csr()
        })
        .collect()
}

#[test]
fn same_seed_is_bit_identical() {
    for cfg in [
        RadixNetConfig::graph_challenge(256, 5).unwrap(),
        RadixNetConfig::graph_challenge_inference(64, 8).unwrap(),
        RadixNetConfig {
            radices: vec![4, 8],
            layers: 6,
            seed: 99,
            permute: true,
            ..RadixNetConfig::default()
        },
    ] {
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.layers.len(), b.layers.len());
        for (wa, wb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(wa.indptr, wb.indptr);
            assert_eq!(wa.indices, wb.indices);
            assert_eq!(
                wa.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                wb.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        assert_eq!(a.biases, b.biases);
    }
}

#[test]
fn streamed_build_matches_coo_reference() {
    // the tentpole guarantee: the no-COO streaming path is bit-identical
    // to the historical COO build, permuted or not
    for permute in [false, true] {
        let cfg = RadixNetConfig {
            radices: vec![4, 4, 4],
            layers: 7,
            seed: 0x5EED,
            permute,
            ..RadixNetConfig::default()
        };
        let streamed = generate(&cfg);
        let reference = coo_reference_layers(&cfg);
        assert_eq!(streamed.layers.len(), reference.len());
        for (k, (s, r)) in streamed.layers.iter().zip(reference.iter()).enumerate() {
            assert_eq!(s.indptr, r.indptr, "layer {k} indptr (permute {permute})");
            assert_eq!(s.indices, r.indices, "layer {k} indices (permute {permute})");
            assert_eq!(
                s.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "layer {k} values (permute {permute})"
            );
        }
    }
}

#[test]
fn column_degrees_match_radix_spec_and_no_empty_layers() {
    let cfg = RadixNetConfig {
        radices: vec![4, 8, 2],
        layers: 7,
        seed: 5,
        ..RadixNetConfig::default()
    };
    for permute in [false, true] {
        let mut c = cfg.clone();
        c.permute = permute;
        let pats = generate_structure(&c);
        assert_eq!(pats.len(), c.layers);
        let n = c.neurons();
        for (k, w) in pats.iter().enumerate() {
            let r = stage_degree(&c.radices, k);
            assert!(w.nnz() > 0, "layer {k} empty");
            let mut col_deg = vec![0usize; n];
            for row in 0..n {
                assert_eq!(w.row_nnz(row), r, "layer {k} row {row} degree");
                let (cols, _) = w.row(row);
                for &col in cols {
                    col_deg[col as usize] += 1;
                }
            }
            // the butterfly is degree-regular on both sides, and a
            // permutation only relabels columns
            assert!(
                col_deg.iter().all(|&d| d == r),
                "layer {k} column degrees != {r} (permute {permute})"
            );
            w.validate().unwrap();
        }
    }
}

#[test]
fn serial_matches_every_engine_on_gc_network() {
    let cfg = RadixNetConfig::graph_challenge_inference(64, 6).unwrap();
    let net = generate(&cfg);
    let b = 8;
    let mut x0 = gc_input_batch(net.input_dim(), b, 3);
    // pin the category outcome: an all-zero input must die (every neuron
    // sits at the negative bias), an all-one input saturates and survives
    for r in 0..net.input_dim() {
        x0[r * b] = 0.0;
        x0[r * b + 1] = 1.0;
    }
    let serial = infer_batch(&net, &x0, b);
    let nl = net.output_dim();
    let serial_cats = categories(&serial, nl, b, 0.0);
    // non-trivial by construction, so the equivalence below has teeth
    assert!(!serial_cats.contains(&0));
    assert!(serial_cats.contains(&1));

    let part = contiguous_partition(&net.layers, 4);
    let plan = CommPlan::build(&net.layers, &part);
    for mode in [ExecMode::Blocking, ExecMode::Overlap, ExecMode::pipelined()] {
        let (out, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
        assert_eq!(out.len(), serial.len());
        let max_diff = out
            .iter()
            .zip(serial.iter())
            .map(|(a, s)| (a - s).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "{} engine off by {max_diff}", mode.label());
        assert_eq!(
            categories(&out, nl, b, 0.0),
            serial_cats,
            "{} engine category set",
            mode.label()
        );
    }
}
