//! Integration: failure handling and degenerate inputs — invalid
//! partitions are rejected, extreme partitions still run correctly, and
//! malformed structures are caught by validation rather than corrupting a
//! run.

use spdnn::coordinator::sgd::train_distributed;
use spdnn::dnn::{sgd_serial, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::partition::DnnPartition;
use spdnn::radixnet::{generate, generate_structure, RadixNetConfig};
use spdnn::sparse::Csr;
use spdnn::util::Rng;

fn net64() -> SparseNet {
    generate(&RadixNetConfig::graph_challenge(64, 3).unwrap())
}

fn data(n: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(1);
    (
        (0..n)
            .map(|_| (0..64).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect())
            .collect(),
        (0..n)
            .map(|i| {
                let mut y = vec![0f32; 64];
                y[i % 10] = 1.0;
                y
            })
            .collect(),
    )
}

#[test]
#[should_panic(expected = "invalid partition")]
fn wrong_layer_count_rejected() {
    let net = net64();
    let bad = DnnPartition {
        nparts: 2,
        input_parts: vec![0; 64],
        layer_parts: vec![vec![0; 64]; 2], // net has 3 layers
    };
    let (inputs, targets) = data(1);
    let _ = train_distributed(&net, &bad, &inputs, &targets, 0.1, 1);
}

#[test]
#[should_panic(expected = "invalid partition")]
fn out_of_range_rank_rejected() {
    let net = net64();
    let mut part = random_partition(&net.layers, 2, 1);
    part.layer_parts[1][5] = 7; // rank 7 with nparts=2
    let (inputs, targets) = data(1);
    let _ = train_distributed(&net, &part, &inputs, &targets, 0.1, 1);
}

#[test]
fn all_rows_on_one_rank_still_correct() {
    // Degenerate partition: rank 0 owns everything, rank 1 owns only input
    // entries → communication happens only at layer 0, and results must
    // still match serial.
    let net = net64();
    let part = DnnPartition {
        nparts: 2,
        input_parts: (0..64).map(|j| (j % 2) as u32).collect(),
        layer_parts: vec![vec![0u32; 64]; 3],
    };
    let (inputs, targets) = data(3);
    let run = train_distributed(&net, &part, &inputs, &targets, 0.2, 1);
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.2, 1);
    for (a, b) in run.losses.iter().zip(sl.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
    // plan says only layer-0 forward transfers exist (bwd mirrors fwd)
    let plan = CommPlan::build(&net.layers, &part);
    assert!(plan.layers[0].message_count() > 0);
    assert_eq!(plan.layers[1].message_count(), 0);
    assert_eq!(plan.layers[2].message_count(), 0);
}

#[test]
fn empty_rank_is_tolerated() {
    // nparts=4 but rows dealt only to ranks 0..3 minus rank 3 for layers;
    // rank 3 owns nothing anywhere and must simply idle without deadlock.
    let net = net64();
    let part = DnnPartition {
        nparts: 4,
        input_parts: (0..64).map(|j| (j % 3) as u32).collect(),
        layer_parts: (0..3)
            .map(|_| (0..64).map(|r| (r % 3) as u32).collect())
            .collect(),
    };
    let (inputs, targets) = data(2);
    let run = train_distributed(&net, &part, &inputs, &targets, 0.2, 1);
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.2, 1);
    for (a, b) in run.losses.iter().zip(sl.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn structure_with_empty_rows_and_columns() {
    // A layer with an unused neuron (empty row) and an unread activation
    // (empty column) must flow through plan building and training.
    let mut rng = Rng::new(5);
    let mut layers: Vec<Csr> = Vec::new();
    for _ in 0..2 {
        let mut coo = spdnn::sparse::Coo::new(16, 16);
        for r in 0..15 {
            // row 15 left empty
            for c in 0..15 {
                // column 15 never referenced
                if rng.gen_bool(0.3) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                }
            }
            coo.push(r, r, 0.5); // keep connected
        }
        layers.push(coo.to_csr());
    }
    let net = SparseNet::new(layers, spdnn::dnn::Activation::Sigmoid);
    let part = random_partition(&net.layers, 3, 2);
    let inputs = vec![vec![1.0f32; 16]];
    let targets = vec![vec![0.5f32; 16]];
    let run = train_distributed(&net, &part, &inputs, &targets, 0.1, 1);
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.1, 1);
    assert!((run.losses[0] - sl[0]).abs() < 1e-4);
}

#[test]
fn csr_validation_rejects_corruption() {
    let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 2).unwrap());
    let mut bad = structure[0].clone();
    bad.indices[0] = 9999;
    assert!(bad.validate().is_err());
    let mut bad2 = structure[0].clone();
    bad2.indptr[1] = bad2.indptr[2] + 1;
    assert!(bad2.validate().is_err());
}

#[test]
fn plan_on_partition_with_unbalanced_inputs() {
    // all input entries on one rank: layer-0 volume is maximal but exact
    let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 2).unwrap());
    let part = DnnPartition {
        nparts: 4,
        input_parts: vec![0u32; 64],
        layer_parts: (0..2)
            .map(|_| (0..64).map(|r| (r % 4) as u32).collect())
            .collect(),
    };
    let plan = CommPlan::build(&structure, &part);
    // rank 0 sends to ranks 1..3 in layer 0; others send nothing
    let sends = plan.fwd_send_volume_per_rank();
    assert!(sends[0] > 0);
    let l0: u64 = plan.layers[0]
        .transfers
        .iter()
        .filter(|t| t.from != 0)
        .count() as u64;
    assert_eq!(l0, 0, "only rank 0 owns inputs");
}
