//! Property tests for the threaded rank-parallel execution engine:
//! activations and gradients produced by concurrently-running ranks match
//! the serial Algorithm-1 oracle within 1e-5 across random partitions with
//! 2–8 ranks, and rank failures surface as errors instead of deadlocks.

use spdnn::comm::Phase;
use spdnn::coordinator::sgd::train_distributed;
use spdnn::coordinator::{ExecMode, RankState};
use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::runtime::parallel::run_ranks;
use spdnn::sparse::Coo;
use spdnn::util::{prop, Rng};

/// Random sparse net with every neuron connected (gradients flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

#[test]
fn threaded_forward_activations_match_serial_within_1e5() {
    prop::check_seeded(0xAC75, 12, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 2 + rng.gen_range(7); // 2..=8 ranks
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let x0: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();

        let serial = sgd_serial::feedforward(&net, &x0);

        // the blocking engine's full-width forward (the overlapped engine's
        // compact mirror is covered by tests/overlap_correctness.rs)
        let run = run_ranks(nparts, |rank, ep| {
            let mut state = RankState::build(&net, &part, &plan, rank as u32, ExecMode::Blocking);
            let acts = state.forward(ep, &plan, &x0);
            (state.rows.clone(), acts)
        })
        .expect("threaded forward failed");

        // merge: each rank contributes the activation entries it owns
        for (rows, acts) in &run.outputs {
            assert_eq!(acts.len(), layers + 1);
            for k in 0..layers {
                for &r in &rows[k] {
                    let got = acts[k + 1][r as usize];
                    let want = serial[k + 1][r as usize];
                    assert!(
                        (got - want).abs() < 1e-5,
                        "P={nparts} layer {} row {r}: {got} vs {want}",
                        k + 1
                    );
                }
            }
        }
    });
}

#[test]
fn threaded_gradients_match_serial_within_1e5() {
    // One SGD step: the weight/bias deltas (eta * gradient) of the merged
    // distributed model equal the serial oracle's within 1e-5.
    prop::check_seeded(0x6AD5, 10, |rng| {
        let n = 8 + rng.gen_range(12);
        let layers = 2 + rng.gen_range(2);
        let nparts = 2 + rng.gen_range(7); // 2..=8 ranks
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let inputs = vec![(0..n).map(|_| rng.gen_f32()).collect::<Vec<f32>>()];
        let targets = vec![(0..n)
            .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
            .collect::<Vec<f32>>()];

        let run = train_distributed(&net, &part, &inputs, &targets, 0.5, 1);
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.5, 1);

        assert!((run.losses[0] - sl[0]).abs() < 1e-5, "loss mismatch");
        for k in 0..net.depth() {
            for (idx, (a, b)) in run.net.layers[k]
                .vals
                .iter()
                .zip(serial.layers[k].vals.iter())
                .enumerate()
            {
                assert!(
                    (a - b).abs() < 1e-5,
                    "P={nparts} layer {k} nnz {idx}: {a} vs {b}"
                );
            }
            for (a, b) in run.net.biases[k].iter().zip(serial.biases[k].iter()) {
                assert!((a - b).abs() < 1e-5, "P={nparts} layer {k} bias");
            }
        }
    });
}

#[test]
fn engine_reports_rank_panic_with_many_blocked_peers() {
    // 8 ranks all waiting on rank 3, which dies: the engine must poison
    // the fabric, unwind every peer, and report rank 3 as the root cause.
    let err = run_ranks(8, |rank, ep| {
        if rank == 3 {
            panic!("rank 3 exploded");
        }
        ep.recv(3, 0, Phase::Forward, 0);
    })
    .expect_err("engine must surface the failure");
    assert_eq!(err.rank, 3);
    assert!(err.message.contains("exploded"), "{}", err.message);
}

#[test]
fn engine_counters_match_plan_under_concurrency() {
    // The live counters of a concurrent inference run equal the plan —
    // the schedule is exact regardless of thread interleaving.
    let mut rng = Rng::new(77);
    let net = random_net(&mut rng, 24, 3, 0.2);
    let part = random_partition(&net.layers, 5, 9);
    let plan = CommPlan::build(&net.layers, &part);
    let b = 4usize;
    let x0: Vec<f32> = (0..24 * b).map(|_| rng.gen_f32()).collect();
    let (_, sent) = spdnn::coordinator::sgd::infer_with_plan(&net, &part, &plan, &x0, b);
    // inference is forward-only: a rank's sends are exactly its planned
    // forward sends, scaled by the batch width
    let fs = plan.fwd_send_volume_per_rank();
    let fm = plan.fwd_send_msgs_per_rank();
    for r in 0..5 {
        assert_eq!(sent[r].0, fs[r] * b as u64, "rank {r} words");
        assert_eq!(sent[r].1, fm[r], "rank {r} msgs");
    }
}
