//! Integration: numerical equivalence of the replica-group training
//! drivers. With the lossless codec the hybrid data×model run must match
//! the single-thread replica semantics ([`replica_serial_reference`])
//! across the full R × k × chunk_acts grid; at R = 1 it must degenerate
//! to the plain minibatch driver on every engine; and on the bundled
//! digits workload the int8+EF gradient exchange must stay within 1%
//! tail loss of the f32 exchange (the enforced `REPLICA_LOSS_BAR`).

use spdnn::comm::{Codec, FabricStats};
use spdnn::coordinator::minibatch::train_minibatch_with_plan;
use spdnn::coordinator::{ExecMode, DEFAULT_CHUNK_ACTS};
use spdnn::dnn::SparseNet;
use spdnn::partition::random::random_partition;
use spdnn::partition::CommPlan;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::replica::{replica_serial_reference, train_replicas_with_plan, ReplicaConfig};
use spdnn::runtime::FaultScope;
use spdnn::util::Rng;

fn small_net() -> SparseNet {
    let cfg = RadixNetConfig {
        radices: vec![4, 4],
        layers: 4,
        seed: 17,
        ..RadixNetConfig::default()
    };
    generate(&cfg)
}

fn dataset(n: usize, dim: usize, out: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(5);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            (0..dim)
                .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let targets: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let mut y = vec![0f32; out];
            y[i % out] = 1.0;
            y
        })
        .collect();
    (inputs, targets)
}

/// The tentpole equivalence grid: every replica-group count × rank count
/// × pipelined chunk size, lossless codec, against the single-thread
/// replica reference. `chunk_acts = 0` is the unchunked sender, `1` the
/// pathological one-entry-per-message extreme, and the default the tuned
/// middle — the all-reduce must be oblivious to all of them.
#[test]
fn f32_grid_matches_the_serial_reference() {
    let net = small_net();
    let (inputs, targets) = dataset(8, 16, 16);
    let (b, eta, epochs) = (2usize, 0.3f32, 1usize);
    for groups in [1usize, 2, 4] {
        let (expect_net, expect_losses) =
            replica_serial_reference(&net, &inputs, &targets, b, eta, epochs, groups);
        for ranks in [1usize, 2, 4] {
            let part = random_partition(&net.layers, ranks, 7 + ranks as u64);
            let plan = CommPlan::build(&net.layers, &part);
            for chunk_acts in [0usize, 1, DEFAULT_CHUNK_ACTS] {
                let cfg = ReplicaConfig {
                    groups,
                    batch: b,
                    eta,
                    epochs,
                    mode: ExecMode::Pipelined { chunk_acts },
                    codec: Codec::F32,
                    scope: FaultScope::Off,
                };
                let run = train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &cfg);
                let ctx = format!("R={groups} k={ranks} chunk={chunk_acts}");
                assert_eq!(run.losses.len(), expect_losses.len(), "{ctx}: steps");
                for (a, e) in run.losses.iter().zip(expect_losses.iter()) {
                    assert!((a - e).abs() < 1e-5, "{ctx}: loss {a} vs {e}");
                }
                for k in 0..net.depth() {
                    for (a, e) in run.net.layers[k]
                        .vals
                        .iter()
                        .zip(expect_net.layers[k].vals.iter())
                    {
                        assert!((a - e).abs() < 1e-5, "{ctx} layer {k}: {a} vs {e}");
                    }
                    for (a, e) in run.net.biases[k].iter().zip(expect_net.biases[k].iter()) {
                        assert!((a - e).abs() < 1e-5, "{ctx} layer {k} bias: {a} vs {e}");
                    }
                }
                if groups == 1 {
                    // the degenerate ring is message-free
                    assert!(
                        run.inter.iter().flatten().all(|st| st.sent_msgs == 0),
                        "{ctx}: R=1 shipped inter-group messages"
                    );
                }
            }
        }
    }
}

/// R = 1 is plain model parallelism: same batches, same order, on every
/// engine — the replica driver must reproduce the minibatch driver bit
/// for bit up to the deferred-update reassociation.
#[test]
fn one_group_degenerates_to_the_minibatch_driver() {
    let net = small_net();
    let (inputs, targets) = dataset(8, 16, 16);
    let part = random_partition(&net.layers, 2, 13);
    let plan = CommPlan::build(&net.layers, &part);
    let reference = train_minibatch_with_plan(&net, &part, &plan, &inputs, &targets, 2, 0.25, 2);
    for mode in [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::Pipelined { chunk_acts: 0 },
        ExecMode::Pipelined { chunk_acts: 1 },
        ExecMode::pipelined(),
    ] {
        let cfg = ReplicaConfig {
            groups: 1,
            batch: 2,
            eta: 0.25,
            epochs: 2,
            mode,
            codec: Codec::F32,
            scope: FaultScope::Off,
        };
        let run = train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &cfg);
        assert_eq!(run.losses.len(), reference.losses.len(), "{mode:?}");
        for (a, e) in run.losses.iter().zip(reference.losses.iter()) {
            assert!((a - e).abs() < 1e-5, "{mode:?}: loss {a} vs {e}");
        }
        for k in 0..net.depth() {
            for (a, e) in run.net.layers[k]
                .vals
                .iter()
                .zip(reference.net.layers[k].vals.iter())
            {
                assert!((a - e).abs() < 1e-5, "{mode:?} layer {k}: {a} vs {e}");
            }
            for (a, e) in run.net.biases[k].iter().zip(reference.net.biases[k].iter()) {
                assert!((a - e).abs() < 1e-5, "{mode:?} layer {k} bias");
            }
        }
    }
}

/// The enforced compression bar at test scale: on the digits workload
/// (the `spdnn replica` default shape) the int8+EF run's tail loss stays
/// within 1% of the f32 run's, while actually shipping fewer wire bytes.
#[test]
fn int8_ef_digits_loss_stays_within_one_percent_of_f32() {
    let (neurons, layers, side, samples) = (256usize, 8usize, 16usize, 48usize);
    let net = generate(&RadixNetConfig::graph_challenge(neurons, layers).expect("cfg"));
    let part = random_partition(&net.layers, 2, 21);
    let plan = CommPlan::build(&net.layers, &part);
    let data = spdnn::data::synthetic_mnist(side, samples, 11);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..samples).map(|i| data.target(i, neurons)).collect();

    let run_with = |codec: Codec| {
        let cfg = ReplicaConfig {
            groups: 2,
            batch: 4,
            eta: 0.2,
            epochs: 3,
            mode: ExecMode::Overlap,
            codec,
            scope: FaultScope::Off,
        };
        train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &cfg)
    };
    let f = run_with(Codec::F32);
    let q = run_with(Codec::int8());

    let tail = |losses: &[f32]| -> f64 {
        let t = (losses.len() / 10).max(1);
        losses[losses.len() - t..]
            .iter()
            .map(|&l| l as f64)
            .sum::<f64>()
            / t as f64
    };
    let (lf, lq) = (tail(&f.losses), tail(&q.losses));
    assert!(lf > 0.0 && lq > 0.0, "degenerate losses: f32 {lf}, int8 {lq}");
    let delta = ((lq - lf) / lf).abs();
    assert!(
        delta < 0.01,
        "int8+EF tail loss {lq:.6} vs f32 {lf:.6} — Δ {:.3}% breaches the 1% bar",
        delta * 100.0
    );

    let wire = |fabrics: &Vec<Vec<FabricStats>>| -> u64 {
        fabrics.iter().flatten().map(|st| st.sent_wire_bytes).sum()
    };
    assert!(
        wire(&q.inter) < wire(&f.inter),
        "int8 must compress the gradient exchange: {} vs {}",
        wire(&q.inter),
        wire(&f.inter)
    );
}
