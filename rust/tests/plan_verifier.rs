//! Integration tests for the static plan verifier (`spdnn::analysis`).
//!
//! Positive direction: the full built-in configuration matrix (the one
//! `spdnn check` and CI run) must come back clean, and the live engines
//! must only emit documented trace spans. Negative direction: seeded
//! mutations of a valid plan — one per violation class in
//! `docs/ANALYSIS.md` — must each surface their diagnostic code.

use spdnn::analysis::{self, check_state_codecs, schedule, taxonomy, CheckReport, Code};
use spdnn::comm::Codec;
use spdnn::coordinator::{ExecMode, RankState};
use spdnn::partition::random::random_partition;
use spdnn::partition::{CommPlan, DnnPartition};
use spdnn::radixnet::{generate, generate_structure, RadixNetConfig};
use spdnn::sparse::Csr;

/// A small Graph Challenge net on 3 ranks with real cross-rank traffic.
fn fixture() -> (Vec<Csr>, DnnPartition, CommPlan) {
    let cfg = RadixNetConfig::graph_challenge(64, 3).expect("built-in GC size");
    let structure = generate_structure(&cfg);
    let part = random_partition(&structure, 3, 11);
    let plan = CommPlan::build(&structure, &part);
    (structure, part, plan)
}

fn codes(report: &CheckReport) -> Vec<&'static str> {
    report.violations.iter().map(|v| v.code.as_str()).collect()
}

/// First (layer >= 1, transfer) pair with a non-empty index list — layer
/// >= 1 so `owner_of_activation` resolves through `layer_parts`.
fn pick_transfer(plan: &CommPlan) -> (usize, usize) {
    for (k, lp) in plan.layers.iter().enumerate().skip(1) {
        for (tid, t) in lp.transfers.iter().enumerate() {
            if !t.indices.is_empty() {
                return (k, tid);
            }
        }
    }
    panic!("fixture has no usable transfer in layers >= 1");
}

#[test]
fn builtin_matrix_is_clean() {
    let reports = analysis::check_builtin_matrix(7);
    assert!(
        reports.len() > 200,
        "matrix unexpectedly small: {} configs",
        reports.len()
    );
    for r in &reports {
        assert!(r.ok(), "unexpected violations:\n{}", r.render());
    }
}

#[test]
fn replica_ring_matrix_is_clean() {
    // the R0xx gate: every (R, codec, envelope) combination of the
    // cross-group gradient all-reduce must verify deadlock-free with
    // exact wire accounting
    let reports = analysis::check_replica_matrix();
    assert!(reports.len() >= 40, "{} configs", reports.len());
    for r in &reports {
        assert!(r.ok(), "replica ring violations:\n{}", r.render());
    }
}

#[test]
fn taxonomy_matches_observability_doc() {
    let mut out = Vec::new();
    taxonomy::check_doc(&mut out);
    assert!(out.is_empty(), "doc drift: {out:?}");
}

#[test]
fn live_engine_spans_stay_inside_taxonomy() {
    let mut out = Vec::new();
    taxonomy::check_live_spans(&mut out);
    assert!(out.is_empty(), "undocumented live spans: {out:?}");
}

#[test]
fn fixture_plan_is_clean_in_every_mode() {
    let (structure, part, plan) = fixture();
    for mode in [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::Pipelined { chunk_acts: 3 },
        ExecMode::Pipelined { chunk_acts: 0 },
    ] {
        let r = analysis::check_plan(&structure, &part, &plan, mode, 2);
        assert!(r.ok(), "{}", r.render());
    }
}

// ---- negative direction: one seeded mutation per violation class ----

#[test]
fn dropped_recv_view_entry_starves_and_orphans() {
    let (structure, part, mut plan) = fixture();
    let (k, tid) = pick_transfer(&plan);
    let to = plan.layers[k].transfers[tid].to as usize;
    plan.layers[k].recv_of[to].retain(|&t| t as usize != tid);
    let r = analysis::check_plan(&structure, &part, &plan, ExecMode::Overlap, 1);
    let c = codes(&r);
    assert!(c.contains(&"S001"), "want orphan send:\n{}", r.render());
    assert!(c.contains(&"S002"), "want starved receive:\n{}", r.render());
    assert!(c.contains(&"S007"), "want view mismatch:\n{}", r.render());
    assert!(c.contains(&"P025"), "want coverage hole:\n{}", r.render());
}

#[test]
fn dangling_views_after_dropped_transfer_are_flagged() {
    let (structure, part, mut plan) = fixture();
    let (k, _) = pick_transfer(&plan);
    // Drop the last transfer object; the send/recv views still name it.
    plan.layers[k].transfers.pop();
    let r = analysis::check_plan(&structure, &part, &plan, ExecMode::Overlap, 1);
    let c = codes(&r);
    assert!(c.contains(&"S007"), "want dangling view:\n{}", r.render());
    assert!(c.contains(&"P025"), "want coverage hole:\n{}", r.render());
}

#[test]
fn duplicated_row_owner_is_foreign_send_and_double_delivery() {
    let (structure, part, plan) = fixture();
    let (k, tid) = pick_transfer(&plan);
    let t = &plan.layers[k].transfers[tid];
    let (to, j) = (t.to, t.indices[0] as usize);
    // Hand row j of layer k-1 to the transfer's receiver: the sender now
    // ships an activation it does not own, and the receiver gets it twice
    // (owned and delivered).
    let mut part2 = part.clone();
    part2.layer_parts[k - 1][j] = to;
    let r = analysis::check_plan(&structure, &part2, &plan, ExecMode::Blocking, 1);
    let c = codes(&r);
    assert!(c.contains(&"P020"), "want foreign send:\n{}", r.render());
    assert!(c.contains(&"P021"), "want double delivery:\n{}", r.render());
}

#[test]
fn skewed_chunk_schedule_deadlocks_symbolically() {
    let (_structure, _part, plan) = fixture();
    let mode = ExecMode::Pipelined { chunk_acts: 3 };
    let sends = schedule::sends_of(&plan, mode, true);
    let mut recvs = schedule::recvs_of(&plan, mode, true);
    assert!(!sends.is_empty() && !recvs.is_empty());
    // One receiver waits on a chunk id nobody posts: its wait starves and
    // the matching posted chunk goes unclaimed.
    recvs[0].chunk += 999;
    let mut out = Vec::new();
    schedule::match_schedule(&sends, &recvs, &mut out);
    let c: Vec<_> = out.iter().map(|v| v.code.as_str()).collect();
    assert!(c.contains(&"S001"), "want orphan send: {out:?}");
    assert!(c.contains(&"S002"), "want starved receive: {out:?}");
}

#[test]
fn self_send_is_flagged() {
    let (structure, part, mut plan) = fixture();
    let (k, tid) = pick_transfer(&plan);
    plan.layers[k].transfers[tid].to = plan.layers[k].transfers[tid].from;
    let r = analysis::check_plan(&structure, &part, &plan, ExecMode::Overlap, 1);
    assert!(codes(&r).contains(&"S005"), "{}", r.render());
}

#[test]
fn duplicated_send_view_entry_is_a_tag_collision() {
    let (structure, part, mut plan) = fixture();
    let (k, tid) = pick_transfer(&plan);
    let from = plan.layers[k].transfers[tid].from as usize;
    plan.layers[k].send_of[from].push(tid as u32);
    let r = analysis::check_plan(&structure, &part, &plan, ExecMode::Overlap, 1);
    let c = codes(&r);
    assert!(c.contains(&"S003"), "want duplicate send tag:\n{}", r.render());
    assert!(c.contains(&"S007"), "want view mismatch:\n{}", r.render());
}

#[test]
fn unsorted_and_empty_transfers_are_flagged() {
    let (structure, part, plan) = fixture();
    let (k, tid) = pick_transfer(&plan);

    let mut unsorted = plan.clone();
    unsorted.layers[k].transfers[tid].indices = vec![1, 0];
    let r = analysis::check_plan(&structure, &part, &unsorted, ExecMode::Overlap, 1);
    assert!(codes(&r).contains(&"P023"), "{}", r.render());

    let mut empty = plan.clone();
    empty.layers[k].transfers[tid].indices.clear();
    let r = analysis::check_plan(&structure, &part, &empty, ExecMode::Overlap, 1);
    let c = codes(&r);
    assert!(c.contains(&"P024"), "want empty transfer:\n{}", r.render());
    assert!(c.contains(&"P025"), "want coverage hole:\n{}", r.render());
}

#[test]
fn rank_state_codec_mismatch_is_detected() {
    let cfg = RadixNetConfig::graph_challenge(64, 3).expect("built-in GC size");
    let net = generate(&cfg);
    let part = random_partition(&net.layers, 2, 5);
    let plan = CommPlan::build(&net.layers, &part);
    let state = RankState::build(&net, &part, &plan, 0, ExecMode::Overlap);
    assert!(check_state_codecs(&state, &plan).is_empty());

    let mut skewed = plan.clone();
    skewed.set_codec(Codec::F16, Codec::F16);
    let v = check_state_codecs(&state, &skewed);
    assert!(!v.is_empty(), "codec skew went undetected");
    assert!(v.iter().all(|v| v.code == Code::StateCodecMismatch), "{v:?}");
}

#[test]
fn report_renders_and_serializes() {
    let (structure, part, plan) = fixture();
    let r = analysis::check_plan(&structure, &part, &plan, ExecMode::pipelined(), 4);
    assert!(r.ok());
    assert!(r.render().starts_with("[ok  ]"), "{}", r.render());
    let json = r.to_json();
    assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
    assert!(json.contains("\"ok\":true"), "{json}");

    let mut bad = plan.clone();
    let (k, tid) = pick_transfer(&bad);
    bad.layers[k].transfers[tid].indices.clear();
    let r = analysis::check_plan(&structure, &part, &bad, ExecMode::Overlap, 1);
    assert!(!r.ok());
    assert!(r.render().contains("P024"), "{}", r.render());
    assert!(r.to_json().contains("\"code\":\"P024\""), "{}", r.to_json());
}
