//! Flight-recorder integration tests: tracing must never change results
//! (serial ≡ blocking ≡ overlap ≡ pipelined with tracing off AND on),
//! recorded spans must stay within the documented taxonomy, the Chrome
//! export must be well-formed, and the pipelined engine's spans must
//! reconstruct its boundary-first schedule — every outbound `post` lands
//! before the same layer's interior epilogue.

use spdnn::coordinator::{infer_with_plan_mode_traced, run_with_plan_mode_traced, ExecMode};
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::obs::{chrome_trace_json, Span, TraceMode};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::sparse::Coo;
use spdnn::util::Rng;

/// Random sparse net with every neuron connected (so values flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

/// Every span name any engine may record, per `docs/OBSERVABILITY.md`.
fn taxonomy() -> &'static [&'static str] {
    &[
        "send",
        "wait",
        "spmv",
        "spmv.local",
        "spmv.seg",
        "spmv.boundary",
        "spmv.interior",
        "post",
        "epilogue",
        "epilogue.boundary",
        "epilogue.interior",
        "spmvt",
        "spmvt.seg",
        "updt",
        "pass",
    ]
}

fn assert_taxonomy(spans: &[Span], cats: &[&str], ctx: &str) {
    for sp in spans {
        assert!(
            taxonomy().contains(&sp.name),
            "{ctx}: span name '{}' not in the documented taxonomy",
            sp.name
        );
        assert!(
            cats.contains(&sp.cat),
            "{ctx}: span '{}' has unexpected category '{}'",
            sp.name,
            sp.cat
        );
    }
}

/// THE acceptance property: all three engines match the serial oracle
/// with tracing off AND on, and the traced runs actually record spans
/// while the off runs record none (and allocate nothing).
#[test]
fn engines_match_serial_with_tracing_off_and_on() {
    let mut rng = Rng::new(0x0B5);
    let n = 24usize;
    let b = 5usize;
    let net = random_net(&mut rng, n, 4, 0.2);
    let part = random_partition(&net.layers, 4, rng.next_u64());
    let plan = CommPlan::build(&net.layers, &part);
    let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
    let serial = infer_batch(&net, &x0, b);

    let modes = [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::Pipelined { chunk_acts: 2 },
    ];
    for mode in modes {
        for trace in [TraceMode::Off, TraceMode::with_capacity(8192)] {
            let (out, _, tracers) =
                infer_with_plan_mode_traced(&net, &part, &plan, &x0, b, mode, trace);
            assert_eq!(out.len(), serial.len(), "{mode:?}: shape");
            for (i, (o, s)) in out.iter().zip(serial.iter()).enumerate() {
                assert!(
                    (o - s).abs() < 1e-5,
                    "{mode:?} trace={:?} entry {i}: {o} vs serial {s}",
                    trace.is_on()
                );
            }
            assert_eq!(tracers.len(), 4);
            for t in &tracers {
                if trace.is_on() {
                    assert!(t.enabled(), "{mode:?}: tracer should be on");
                    assert!(!t.spans().is_empty(), "{mode:?}: no spans recorded");
                    assert_taxonomy(&t.spans(), &["fwd"], &format!("{mode:?} rank {}", t.rank()));
                } else {
                    assert!(!t.enabled(), "{mode:?}: tracer should be off");
                    assert!(t.spans().is_empty(), "{mode:?}: off-mode spans");
                    assert_eq!(t.buffer_capacity(), 0, "{mode:?}: off-mode ring allocated");
                }
            }
        }
    }
}

/// Traced training matches the serial oracle in every mode and records
/// backward-pass spans alongside the forward ones.
#[test]
fn traced_training_matches_serial_and_records_bwd_spans() {
    let mut rng = Rng::new(0x7E57);
    let n = 16usize;
    let net = random_net(&mut rng, n, 3, 0.25);
    let part = random_partition(&net.layers, 3, rng.next_u64());
    let plan = CommPlan::build(&net.layers, &part);
    let inputs: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..n).map(|_| rng.gen_f32()).collect())
        .collect();
    let targets: Vec<Vec<f32>> = (0..3)
        .map(|_| {
            (0..n)
                .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.4, 2);

    let modes = [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::Pipelined { chunk_acts: 2 },
    ];
    for mode in modes {
        let (run, tracers) = run_with_plan_mode_traced(
            &net,
            &part,
            &plan,
            &inputs,
            &targets,
            0.4,
            2,
            mode,
            TraceMode::with_capacity(16384),
        );
        for (i, (a, s)) in run.losses.iter().zip(sl.iter()).enumerate() {
            assert!((a - s).abs() < 1e-4, "{mode:?} step {i}: loss {a} vs {s}");
        }
        for k in 0..net.depth() {
            for (a, s) in run.net.layers[k].vals.iter().zip(serial.layers[k].vals.iter()) {
                assert!((a - s).abs() < 1e-4, "{mode:?} layer {k}: {a} vs {s}");
            }
        }
        let mut saw_bwd = false;
        for t in &tracers {
            let spans = t.spans();
            assert_taxonomy(&spans, &["fwd", "bwd"], &format!("{mode:?} rank {}", t.rank()));
            saw_bwd |= spans.iter().any(|sp| sp.cat == "bwd");
        }
        assert!(saw_bwd, "{mode:?}: no backward-pass spans recorded");
    }
}

/// The Chrome exporter emits one track per rank and only well-formed
/// complete ("X") events, on a shared timeline.
#[test]
fn chrome_export_is_well_formed() {
    let mut rng = Rng::new(0xC42);
    let n = 20usize;
    let b = 4usize;
    let net = random_net(&mut rng, n, 3, 0.2);
    let part = random_partition(&net.layers, 3, rng.next_u64());
    let plan = CommPlan::build(&net.layers, &part);
    let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
    let (_, _, tracers) = infer_with_plan_mode_traced(
        &net,
        &part,
        &plan,
        &x0,
        b,
        ExecMode::Overlap,
        TraceMode::with_capacity(8192),
    );
    let tracks: Vec<(String, Vec<Span>)> = tracers
        .iter()
        .map(|t| (format!("rank {}", t.rank()), t.spans()))
        .collect();
    let json = chrome_trace_json(&tracks);
    assert!(json.starts_with('{') && json.ends_with('}'));
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"ph\":\"X\""));
    assert!(json.contains("\"ph\":\"M\""), "missing thread_name metadata");
    for (name, _) in &tracks {
        assert!(json.contains(name.as_str()), "missing track '{name}'");
    }
}

/// The pipelined schedule is visible in the trace: on every rank and
/// layer that posted outbound payloads, the first `post` span starts
/// before that layer's interior epilogue — boundary-first rows really
/// went on the wire ahead of the interior compute finishing.
#[test]
fn pipelined_trace_shows_posts_before_interior_epilogue() {
    let mut rng = Rng::new(0x91E);
    let n = 28usize;
    let b = 6usize;
    let net = random_net(&mut rng, n, 4, 0.3);
    let part = random_partition(&net.layers, 4, rng.next_u64());
    let plan = CommPlan::build(&net.layers, &part);
    let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
    let (_, _, tracers) = infer_with_plan_mode_traced(
        &net,
        &part,
        &plan,
        &x0,
        b,
        ExecMode::Pipelined { chunk_acts: 2 },
        TraceMode::with_capacity(16384),
    );
    let mut checked = 0usize;
    for t in &tracers {
        let spans = t.spans();
        for k in 0..net.depth() as u32 {
            let first_post = spans
                .iter()
                .filter(|sp| sp.name == "post" && sp.layer == k)
                .map(|sp| sp.start_ns)
                .min();
            let interior = spans
                .iter()
                .filter(|sp| sp.name == "epilogue.interior" && sp.layer == k)
                .map(|sp| sp.start_ns)
                .min();
            if let (Some(post), Some(epi)) = (first_post, interior) {
                assert!(
                    post <= epi,
                    "rank {} layer {k}: post at {post}ns after interior epilogue at {epi}ns",
                    t.rank()
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "no (post, interior-epilogue) pairs to check");
}
