//! Integration: the partitioning pipeline on real RadiX-Net structures —
//! validity, balance, the volume==cutsize identity, plan duality, and the
//! headline H-beats-random property of Table 1.

use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::phases::{build_phase_hypergraph, hypergraph_partition, PhaseConfig};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate_structure, RadixNetConfig};

/// Debug builds (plain `cargo test`) shrink the instances ~4x so the
/// unoptimized partitioner stays fast; release runs the full sizes.
fn scale(n_rel: usize, n_dbg: usize) -> usize {
    if cfg!(debug_assertions) {
        n_dbg
    } else {
        n_rel
    }
}

#[test]
fn h_beats_random_across_processor_counts() {
    let structure =
        generate_structure(&RadixNetConfig::graph_challenge(1024, scale(12, 4)).unwrap());
    for &p in &[4usize, 8, 16, 32] {
        let h = hypergraph_partition(&structure, &PhaseConfig::new(p));
        let r = random_partition(&structure, p, p as u64);
        h.validate(&structure).unwrap();
        let mh = PartitionMetrics::compute(&structure, &h);
        let mr = PartitionMetrics::compute(&structure, &r);
        assert!(
            mh.avg_volume() < mr.avg_volume() * 0.75,
            "P={p}: H avg volume {} not well below R {}",
            mh.avg_volume(),
            mr.avg_volume()
        );
        assert!(
            mh.comp_imbalance() <= mr.comp_imbalance() + 0.1,
            "P={p}: H imbalance {} vs R {}",
            mh.comp_imbalance(),
            mr.comp_imbalance()
        );
    }
}

#[test]
fn volume_equals_total_cutsize_on_radixnet() {
    // Eq. Vol(k) == connectivity-1 cutsize with cost 2, on the real
    // benchmark structure with the real H partition.
    let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 6).unwrap());
    let part = hypergraph_partition(&structure, &PhaseConfig::new(8));
    let plan = CommPlan::build(&structure, &part);
    let mut total_cut = 0u64;
    for (k, w) in structure.iter().enumerate() {
        let prev: Vec<u32> = (0..w.ncols)
            .map(|j| part.owner_of_activation(k, j))
            .collect();
        let hg = build_phase_hypergraph(w, Some(&prev));
        let mut pv = vec![0u32; hg.nv];
        for r in 0..w.nrows {
            pv[r] = part.layer_parts[k][r];
        }
        for j in 0..w.ncols {
            pv[w.nrows + j] = prev[j];
        }
        total_cut += hg.cutsize(&pv, part.nparts);
    }
    assert_eq!(total_cut, plan.total_volume());
}

#[test]
fn plan_duality_fwd_recv_equals_bwd_send() {
    // The mirror argument of §4.2: per rank, forward receives == backward
    // sends, both in words and message counts (we verify on plan level).
    let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap());
    let part = random_partition(&structure, 16, 3);
    let plan = CommPlan::build(&structure, &part);
    // by construction the backward plan is the transpose; verify the
    // transpose is consistent: total send == total recv, per layer
    for (k, l) in plan.layers.iter().enumerate() {
        let sends: u64 = (0..16).map(|r| l.send_of[r].len() as u64).sum();
        let recvs: u64 = (0..16).map(|r| l.recv_of[r].len() as u64).sum();
        assert_eq!(sends, recvs, "layer {k}");
        assert_eq!(sends, l.transfers.len() as u64);
        for t in &l.transfers {
            assert_ne!(t.from, t.to);
        }
    }
}

#[test]
fn balance_honored_at_paper_epsilon() {
    let structure = generate_structure(&RadixNetConfig::graph_challenge(1024, 6).unwrap());
    let mut cfg = PhaseConfig::new(8);
    cfg.epsilon = 0.01;
    let part = hypergraph_partition(&structure, &cfg);
    let m = PartitionMetrics::compute(&structure, &part);
    // recursive bisection can slightly exceed ε per level; the paper's
    // observed aggregate for H-SGD is 1.01–1.05 — require ≤ 1.10
    assert!(
        m.comp_imbalance() <= 1.10,
        "imbalance {}",
        m.comp_imbalance()
    );
}

#[test]
fn fixed_vertex_chaining_reduces_inter_layer_traffic() {
    // Ablation of the paper's key idea: partitioning each layer
    // independently (no fixed vertices) must communicate more than the
    // multi-phase chained model.
    let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap());
    let chained = hypergraph_partition(&structure, &PhaseConfig::new(8));
    // independent: partition each layer with no knowledge of the previous
    let mut layer_parts = Vec::new();
    for (k, w) in structure.iter().enumerate() {
        let hg = build_phase_hypergraph(w, None);
        let mut pcfg = spdnn::hypergraph::PartitionConfig::new(8);
        pcfg.seed = 77 + k as u64;
        let parts = spdnn::hypergraph::partition(&hg, &pcfg);
        layer_parts.push(parts[..w.nrows].to_vec());
    }
    let independent = spdnn::partition::DnnPartition {
        nparts: 8,
        input_parts: chained.input_parts.clone(),
        layer_parts,
    };
    let mc = PartitionMetrics::compute(&structure, &chained);
    let mi = PartitionMetrics::compute(&structure, &independent);
    assert!(
        mc.total_volume() < mi.total_volume(),
        "chained {} not below independent {}",
        mc.total_volume(),
        mi.total_volume()
    );
}

#[test]
fn partitioning_scales_to_bigger_configs() {
    // smoke: N=1024 partitions in reasonable time and stays valid
    let structure =
        generate_structure(&RadixNetConfig::graph_challenge(1024, scale(24, 6)).unwrap());
    let part = hypergraph_partition(&structure, &PhaseConfig::new(16));
    part.validate(&structure).unwrap();
    let m = PartitionMetrics::compute(&structure, &part);
    assert!(m.comp_imbalance() < 1.2);
    assert!(m.total_volume() > 0);
}
