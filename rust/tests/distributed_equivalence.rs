//! Integration: the distributed coordinator (any P, any partition) is
//! numerically equivalent to the serial Algorithm-1 oracle — the paper's
//! correctness premise for all of Section 4.

use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::coordinator::sgd::{infer_distributed, train_distributed};
use spdnn::partition::phases::{hypergraph_partition, PhaseConfig};
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::util::Rng;

fn net(n: usize, layers: usize, seed: u64) -> SparseNet {
    let mut cfg = RadixNetConfig::graph_challenge(n, layers).unwrap();
    cfg.seed = seed;
    generate(&cfg)
}

fn dataset(count: usize, dim: usize, out: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let inputs = (0..count)
        .map(|_| {
            (0..dim)
                .map(|_| if rng.gen_bool(0.25) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let targets = (0..count)
        .map(|i| {
            let mut y = vec![0f32; out];
            y[i % 10.min(out)] = 1.0;
            y
        })
        .collect();
    (inputs, targets)
}

fn assert_nets_close(a: &SparseNet, b: &SparseNet, tol: f32, label: &str) {
    for k in 0..a.depth() {
        for (x, y) in a.layers[k].vals.iter().zip(b.layers[k].vals.iter()) {
            assert!((x - y).abs() < tol, "{label}: layer {k} weight {x} vs {y}");
        }
        for (x, y) in a.biases[k].iter().zip(b.biases[k].iter()) {
            assert!((x - y).abs() < tol, "{label}: layer {k} bias {x} vs {y}");
        }
    }
}

#[test]
fn equivalence_on_deeper_radixnet_many_ranks() {
    let net = net(64, 6, 11);
    let (inputs, targets) = dataset(5, 64, 64, 3);
    let mut serial = net.clone();
    let serial_losses = sgd_serial::train(&mut serial, &inputs, &targets, 0.2, 3);

    for &p in &[2usize, 5, 8, 16] {
        let part = random_partition(&net.layers, p, 100 + p as u64);
        let run = train_distributed(&net, &part, &inputs, &targets, 0.2, 3);
        for (i, (a, b)) in run.losses.iter().zip(serial_losses.iter()).enumerate() {
            assert!(
                (a - b).abs() < 2e-3,
                "P={p} step {i}: loss {a} vs serial {b}"
            );
        }
        assert_nets_close(&run.net, &serial, 2e-3, &format!("P={p}"));
    }
}

#[test]
fn equivalence_under_hypergraph_partition_256() {
    let net = net(256, 5, 12);
    let (inputs, targets) = dataset(3, 256, 256, 4);
    let part = hypergraph_partition(&net.layers, &PhaseConfig::new(8));
    let run = train_distributed(&net, &part, &inputs, &targets, 0.4, 1);
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.4, 1);
    for (a, b) in run.losses.iter().zip(sl.iter()) {
        assert!((a - b).abs() < 2e-3);
    }
    assert_nets_close(&run.net, &serial, 2e-3, "hypergraph P=8");
}

#[test]
fn inference_parity_large_batch() {
    let net = net(64, 6, 13);
    let b = 32;
    let mut rng = Rng::new(7);
    let x0: Vec<f32> = (0..64 * b)
        .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
        .collect();
    let serial = spdnn::dnn::inference::infer_batch(&net, &x0, b);
    for &p in &[3usize, 8] {
        let part = hypergraph_partition(&net.layers, &PhaseConfig::new(p));
        let (out, sent) = infer_distributed(&net, &part, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-4, "P={p}");
        }
        // batched comm: every word count is a multiple of the batch width
        for (words, _) in &sent {
            assert_eq!(words % b as u64, 0, "P={p}");
        }
    }
}

#[test]
fn permuted_radixnet_still_equivalent() {
    // inter-layer permutations change the comm pattern drastically; the
    // schedule must still be exact.
    let mut cfg = RadixNetConfig::graph_challenge(64, 4).unwrap();
    cfg.permute = true;
    cfg.seed = 21;
    let net = spdnn::radixnet::generate(&cfg);
    let (inputs, targets) = dataset(4, 64, 64, 9);
    let part = random_partition(&net.layers, 6, 2);
    let run = train_distributed(&net, &part, &inputs, &targets, 0.3, 2);
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.3, 2);
    for (a, b) in run.losses.iter().zip(sl.iter()) {
        assert!((a - b).abs() < 2e-3);
    }
    assert_nets_close(&run.net, &serial, 2e-3, "permuted");
}

#[test]
fn activation_relu_equivalence() {
    // ReLU subgradients are sharp; exercise the non-sigmoid path too.
    let mut base = net(64, 3, 31);
    base.activation = Activation::Relu;
    // shrink weights so activations stay bounded under ReLU
    for w in &mut base.layers {
        for v in &mut w.vals {
            *v *= 0.2;
        }
    }
    let (inputs, targets) = dataset(3, 64, 64, 5);
    let part = random_partition(&base.layers, 4, 8);
    let run = train_distributed(&base, &part, &inputs, &targets, 0.05, 1);
    let mut serial = base.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.05, 1);
    for (a, b) in run.losses.iter().zip(sl.iter()) {
        assert!((a - b).abs() < 2e-3);
    }
    assert_nets_close(&run.net, &serial, 2e-3, "relu");
}
