//! Integration: the AOT round-trip — JAX/Pallas (L1+L2, build time) → HLO
//! text → PJRT CPU client (L3 runtime) — produces the same numbers as the
//! native Rust engine. The feature always compiles (CI builds
//! `--all-features` against the vendored stub client), but *executing*
//! requires a real vendored `xla` crate plus `make artifacts` (shapes
//! 64x256 and 8x16); each test skips itself with a message when either
//! is absent.
#![cfg(feature = "pjrt")]

use spdnn::dnn::{Activation, SparseNet};
use spdnn::partition::random::random_partition;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::runtime::{artifacts_dir, PjrtLayerEngine, PjrtRuntime};
use spdnn::sparse::Coo;
use spdnn::util::Rng;

fn artifacts_present(m: usize, k: usize) -> bool {
    artifacts_dir().join(spdnn::runtime::fwd_artifact(m, k)).is_file()
}

/// `true` (after logging why) when the round-trip cannot execute here:
/// the build is backed by the vendored stub, or the AOT artifacts for
/// this shape were never produced.
fn skip(m: usize, k: usize) -> bool {
    if PjrtRuntime::vendored_stub() {
        eprintln!(
            "skipping: vendored xla stub cannot execute HLO \
             (vendor the real crate — see rust/src/runtime/xla_stub.rs)"
        );
        return true;
    }
    if !artifacts_present(m, k) {
        eprintln!("skipping: artifacts for {m}x{k} missing — run `make artifacts` first");
        return true;
    }
    false
}

#[test]
fn pjrt_forward_matches_native_small() {
    if skip(8, 16) {
        return;
    }
    let eng = PjrtLayerEngine::load(&artifacts_dir(), 8, 16, 16).expect("load artifacts");
    let mut rng = Rng::new(1);
    // random sparse block 5x16 (padded to 8 inside the engine)
    let mut coo = Coo::new(5, 16);
    for r in 0..5 {
        for c in 0..16 {
            if rng.gen_bool(0.3) {
                coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
            }
        }
    }
    let blk = coo.to_csr();
    let x: Vec<f32> = (0..16).map(|_| rng.gen_f32()).collect();
    let bias: Vec<f32> = (0..5).map(|_| rng.gen_f32_range(-0.5, 0.5)).collect();

    let got = eng.forward(&blk, &x, &bias).expect("pjrt forward");

    // native reference
    let mut z = vec![0f32; 5];
    blk.spmv(&x, &mut z);
    for i in 0..5 {
        z[i] += bias[i];
    }
    Activation::Sigmoid.apply(&mut z);
    assert_eq!(got.len(), 5);
    for (a, b) in got.iter().zip(z.iter()) {
        assert!((a - b).abs() < 1e-5, "pjrt {a} vs native {b}");
    }
}

#[test]
fn pjrt_backward_matches_native() {
    if skip(8, 16) {
        return;
    }
    let eng = PjrtLayerEngine::load(&artifacts_dir(), 8, 16, 0).expect("load artifacts");
    let mut rng = Rng::new(2);
    let mut coo = Coo::new(8, 16);
    for r in 0..8 {
        for c in 0..16 {
            if rng.gen_bool(0.4) {
                coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
            }
        }
    }
    let blk = coo.to_csr();
    let delta: Vec<f32> = (0..8).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    let got = eng.backward(&blk, &delta).expect("pjrt backward");
    let mut s = vec![0f32; 16];
    blk.spmv_t_add(&delta, &mut s);
    for (a, b) in got.iter().zip(s.iter()) {
        assert!((a - b).abs() < 1e-5, "pjrt {a} vs native {b}");
    }
}

#[test]
fn pjrt_batched_forward_matches_native() {
    if skip(8, 16) {
        return;
    }
    let eng = PjrtLayerEngine::load(&artifacts_dir(), 8, 16, 16).expect("load artifacts");
    let mut rng = Rng::new(3);
    let mut coo = Coo::new(8, 16);
    for r in 0..8 {
        for c in 0..16 {
            if rng.gen_bool(0.4) {
                coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
            }
        }
    }
    let blk = coo.to_csr();
    let b = 16usize;
    let x: Vec<f32> = (0..16 * b).map(|_| rng.gen_f32()).collect();
    let bias: Vec<f32> = (0..8).map(|_| rng.gen_f32_range(-0.2, 0.2)).collect();
    let got = eng.forward_batch(&blk, &x, &bias).expect("pjrt batch fwd");

    let mut z = vec![0f32; 8 * b];
    blk.spmm_rowmajor(&x, &mut z, b);
    for r in 0..8 {
        let row = &mut z[r * b..(r + 1) * b];
        for v in row.iter_mut() {
            *v += bias[r];
        }
        Activation::Sigmoid.apply(row);
    }
    for (a, bb) in got.iter().zip(z.iter()) {
        assert!((a - bb).abs() < 1e-5);
    }
}

/// Whole-layer parity on a realistic RadiX-Net block: one rank's serving
/// path (P=4 over N=256) through the 64x256 artifact.
#[test]
fn pjrt_serves_radixnet_rank_block() {
    if skip(64, 256) {
        return;
    }
    let net: SparseNet = generate(&RadixNetConfig::graph_challenge(256, 4).unwrap());
    let part = random_partition(&net.layers, 4, 9);
    let eng = PjrtLayerEngine::load(&artifacts_dir(), 64, 256, 16).expect("load");
    let mut rng = Rng::new(4);
    let x: Vec<f32> = (0..256).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect();

    for rank in 0..4u32 {
        let rows = part.rows_of(0, rank);
        let blk = net.layers[0].row_block(&rows);
        let bias: Vec<f32> = rows.iter().map(|&r| net.biases[0][r as usize]).collect();
        let got = eng.forward(&blk, &x, &bias).unwrap();
        let mut z = vec![0f32; blk.nrows];
        blk.spmv(&x, &mut z);
        for i in 0..blk.nrows {
            z[i] += bias[i];
        }
        Activation::Sigmoid.apply(&mut z);
        for (a, b) in got.iter().zip(z.iter()) {
            assert!((a - b).abs() < 1e-5, "rank {rank}");
        }
    }
}
