//! Property tests for the split-CSR **overlapped** engine: across random
//! nets, random partitions, 1–8 ranks, and batch sizes including the
//! degenerate b = 0 and b = 1, the overlapped path matches the serial
//! engine within 1e-5, agrees with the blocking engine, and trains to the
//! same weights.

use spdnn::coordinator::sgd::{infer_with_plan_mode, run_with_plan_mode};
use spdnn::coordinator::{ExecMode, RankState};
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::partition::DnnPartition;
use spdnn::runtime::parallel::run_ranks;
use spdnn::sparse::Coo;
use spdnn::util::{prop, Rng};

/// Random sparse net with every neuron connected (so values flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

/// THE satellite property: overlapped batched inference equals the serial
/// engine within 1e-5 for random partitions, 1–8 ranks, and batch sizes
/// including b = 0 and b = 1.
#[test]
fn overlap_inference_matches_serial_any_partition_rank_batch() {
    prop::check_seeded(0x0E21, 14, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 1 + rng.gen_range(8); // 1..=8 ranks
        let b = match rng.gen_range(4) {
            0 => 0usize, // degenerate: empty batch
            1 => 1,      // single column
            _ => 2 + rng.gen_range(7),
        };
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();

        let serial = infer_batch(&net, &x0, b);
        let (overlap, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Overlap);
        assert_eq!(overlap.len(), serial.len(), "P={nparts} b={b}: shape");
        for (i, (o, s)) in overlap.iter().zip(serial.iter()).enumerate() {
            assert!(
                (o - s).abs() < 1e-5,
                "P={nparts} b={b} entry {i}: overlap {o} vs serial {s}"
            );
        }

        let (blocking, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Blocking);
        for (i, (o, bl)) in overlap.iter().zip(blocking.iter()).enumerate() {
            assert!(
                (o - bl).abs() < 1e-5,
                "P={nparts} b={b} entry {i}: overlap {o} vs blocking {bl}"
            );
        }
    });
}

/// Training under the overlapped engine converges to the same weights as
/// the blocking engine and the serial oracle.
#[test]
fn overlap_training_matches_blocking_and_serial() {
    prop::check_seeded(0x7A11, 6, |rng| {
        let n = 8 + rng.gen_range(10);
        let layers = 2 + rng.gen_range(2);
        let nparts = 1 + rng.gen_range(8);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let samples = 3usize;
        let inputs: Vec<Vec<f32>> = (0..samples)
            .map(|_| (0..n).map(|_| rng.gen_f32()).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..samples)
            .map(|_| {
                (0..n)
                    .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();

        let ov = run_with_plan_mode(
            &net, &part, &plan, &inputs, &targets, 0.4, 2, ExecMode::Overlap,
        );
        let bl = run_with_plan_mode(
            &net, &part, &plan, &inputs, &targets, 0.4, 2, ExecMode::Blocking,
        );
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.4, 2);

        for (i, (a, s)) in ov.losses.iter().zip(sl.iter()).enumerate() {
            assert!((a - s).abs() < 1e-4, "P={nparts} step {i}: loss {a} vs {s}");
        }
        for k in 0..net.depth() {
            for (i, ((o, b), s)) in ov.net.layers[k]
                .vals
                .iter()
                .zip(bl.net.layers[k].vals.iter())
                .zip(serial.layers[k].vals.iter())
                .enumerate()
            {
                assert!((o - b).abs() < 1e-4, "P={nparts} layer {k} nnz {i}: {o} vs blocking {b}");
                assert!((o - s).abs() < 1e-4, "P={nparts} layer {k} nnz {i}: {o} vs serial {s}");
            }
            for ((o, b), s) in ov.net.biases[k]
                .iter()
                .zip(bl.net.biases[k].iter())
                .zip(serial.biases[k].iter())
            {
                assert!((o - b).abs() < 1e-4 && (o - s).abs() < 1e-4, "P={nparts} bias layer {k}");
            }
        }
    });
}

/// Minibatch steps agree between the two engines (the overlapped engine's
/// compact batch-mean SpBP mirrors the full-width one).
#[test]
fn minibatch_overlap_matches_blocking() {
    prop::check_seeded(0x3B1C, 5, |rng| {
        let n = 8 + rng.gen_range(10);
        let layers = 2 + rng.gen_range(2);
        let nparts = 2 + rng.gen_range(5);
        let b = 1 + rng.gen_range(4);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        // one packed batch, row-major [n × b]
        let x: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let y: Vec<f32> = (0..n * b)
            .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
            .collect();

        let trained = |mode: ExecMode| -> (SparseNet, f32) {
            let run = run_ranks(part.nparts, |rank, ep| {
                let mut st = RankState::build(&net, &part, &plan, rank as u32, mode);
                let loss = st.train_step_minibatch(ep, &plan, &x, &y, b, 0.3);
                (st, loss)
            })
            .expect("minibatch run");
            let mut out = net.clone();
            let mut loss = 0f32;
            for (st, l) in run.outputs {
                st.merge_into(&mut out);
                loss += l;
            }
            (out, loss)
        };
        let (ov, ov_loss) = trained(ExecMode::Overlap);
        let (bl, bl_loss) = trained(ExecMode::Blocking);
        assert!(
            (ov_loss - bl_loss).abs() < 1e-4,
            "P={nparts} b={b}: loss {ov_loss} vs {bl_loss}"
        );
        for k in 0..net.depth() {
            for (i, (o, bv)) in ov.layers[k]
                .vals
                .iter()
                .zip(bl.layers[k].vals.iter())
                .enumerate()
            {
                assert!(
                    (o - bv).abs() < 1e-4,
                    "P={nparts} b={b} layer {k} nnz {i}: {o} vs {bv}"
                );
            }
        }
    });
}

/// The merge of a split-mode state reconstructs the exact original weights
/// when nothing was trained — the split/merge round-trip is lossless.
#[test]
fn split_merge_roundtrip_is_lossless() {
    prop::check_seeded(0x90FD, 10, |rng| {
        let n = 8 + rng.gen_range(12);
        let layers = 2 + rng.gen_range(3);
        let nparts = 1 + rng.gen_range(8);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let mut merged = net.clone();
        // zero out to prove the merge rewrites every value
        for w in merged.layers.iter_mut() {
            w.vals.iter_mut().for_each(|v| *v = 0.0);
        }
        for rank in 0..nparts as u32 {
            let st = RankState::build(&net, &part, &plan, rank, ExecMode::Overlap);
            st.merge_into(&mut merged);
        }
        for k in 0..net.depth() {
            assert_eq!(
                merged.layers[k].vals, net.layers[k].vals,
                "P={nparts} layer {k}: split→merge changed values"
            );
        }
    });
}

/// Contiguous serving partitions (the pool default) run the overlapped
/// engine correctly too — the exact configuration the benches measure.
#[test]
fn overlap_matches_serial_on_contiguous_partition() {
    let mut rng = Rng::new(1234);
    let net = random_net(&mut rng, 32, 4, 0.2);
    for nparts in [1usize, 2, 4, 8] {
        let part: DnnPartition = spdnn::partition::contiguous_partition(&net.layers, nparts);
        let plan = CommPlan::build(&net.layers, &part);
        for b in [0usize, 1, 5, 16] {
            let x0: Vec<f32> = (0..32 * b).map(|_| rng.gen_f32()).collect();
            let serial = infer_batch(&net, &x0, b);
            let (out, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Overlap);
            assert_eq!(out.len(), serial.len());
            for (o, s) in out.iter().zip(serial.iter()) {
                assert!((o - s).abs() < 1e-5, "P={nparts} b={b}");
            }
        }
    }
}
