//! Property tests for the split-CSR **overlapped** and **pipelined**
//! engines: across random nets, random partitions, 1–8 ranks, and batch
//! sizes including the degenerate b = 0 and b = 1, both compact paths
//! match the serial engine within 1e-5, agree with the blocking engine,
//! and train to the same weights — including ranks that own zero rows in
//! some layer and destinations whose boundary row range is empty.

use spdnn::coordinator::sgd::{infer_with_plan_mode, run_with_plan_mode};
use spdnn::coordinator::{ExecMode, RankState};
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::partition::DnnPartition;
use spdnn::runtime::parallel::run_ranks;
use spdnn::sparse::Coo;
use spdnn::util::{prop, Rng};

/// Random sparse net with every neuron connected (so values flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

/// THE satellite property: overlapped AND pipelined batched inference
/// equal the serial engine within 1e-5 for random partitions, 1–8 ranks,
/// and batch sizes including b = 0 and b = 1. Tiny chunk sizes force
/// multi-chunk sub-transfers through the pipelined schedule.
#[test]
fn overlap_and_pipelined_inference_match_serial_any_partition_rank_batch() {
    prop::check_seeded(0x0E21, 14, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 1 + rng.gen_range(8); // 1..=8 ranks
        let b = match rng.gen_range(4) {
            0 => 0usize, // degenerate: empty batch
            1 => 1,      // single column
            _ => 2 + rng.gen_range(7),
        };
        let chunk_acts = 1 + rng.gen_range(5); // 1..=5 entries per chunk
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();

        let serial = infer_batch(&net, &x0, b);
        let (overlap, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Overlap);
        assert_eq!(overlap.len(), serial.len(), "P={nparts} b={b}: shape");
        for (i, (o, s)) in overlap.iter().zip(serial.iter()).enumerate() {
            assert!(
                (o - s).abs() < 1e-5,
                "P={nparts} b={b} entry {i}: overlap {o} vs serial {s}"
            );
        }

        let (blocking, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Blocking);
        for (i, (o, bl)) in overlap.iter().zip(blocking.iter()).enumerate() {
            assert!(
                (o - bl).abs() < 1e-5,
                "P={nparts} b={b} entry {i}: overlap {o} vs blocking {bl}"
            );
        }

        let (piped, _) = infer_with_plan_mode(
            &net,
            &part,
            &plan,
            &x0,
            b,
            ExecMode::Pipelined { chunk_acts },
        );
        for (i, (p, s)) in piped.iter().zip(serial.iter()).enumerate() {
            assert!(
                (p - s).abs() < 1e-5,
                "P={nparts} b={b} chunk={chunk_acts} entry {i}: pipelined {p} vs serial {s}"
            );
        }
    });
}

/// Training under the overlapped AND pipelined engines converges to the
/// same weights as the blocking engine and the serial oracle — the
/// pipelined backward posts partial-gradient chunks before the update
/// window and must still produce identical updates.
#[test]
fn overlap_and_pipelined_training_match_blocking_and_serial() {
    prop::check_seeded(0x7A11, 6, |rng| {
        let n = 8 + rng.gen_range(10);
        let layers = 2 + rng.gen_range(2);
        let nparts = 1 + rng.gen_range(8);
        let chunk_acts = 1 + rng.gen_range(4);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let samples = 3usize;
        let inputs: Vec<Vec<f32>> = (0..samples)
            .map(|_| (0..n).map(|_| rng.gen_f32()).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..samples)
            .map(|_| {
                (0..n)
                    .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();

        let ov = run_with_plan_mode(
            &net, &part, &plan, &inputs, &targets, 0.4, 2, ExecMode::Overlap,
        );
        let bl = run_with_plan_mode(
            &net, &part, &plan, &inputs, &targets, 0.4, 2, ExecMode::Blocking,
        );
        let pi = run_with_plan_mode(
            &net,
            &part,
            &plan,
            &inputs,
            &targets,
            0.4,
            2,
            ExecMode::Pipelined { chunk_acts },
        );
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.4, 2);

        for (i, ((a, p), s)) in ov.losses.iter().zip(pi.losses.iter()).zip(sl.iter()).enumerate() {
            assert!((a - s).abs() < 1e-4, "P={nparts} step {i}: loss {a} vs {s}");
            assert!((p - s).abs() < 1e-4, "P={nparts} step {i}: pipelined loss {p} vs {s}");
        }
        for k in 0..net.depth() {
            for (i, (((o, b), p), s)) in ov.net.layers[k]
                .vals
                .iter()
                .zip(bl.net.layers[k].vals.iter())
                .zip(pi.net.layers[k].vals.iter())
                .zip(serial.layers[k].vals.iter())
                .enumerate()
            {
                assert!((o - b).abs() < 1e-4, "P={nparts} layer {k} nnz {i}: {o} vs blocking {b}");
                assert!((o - s).abs() < 1e-4, "P={nparts} layer {k} nnz {i}: {o} vs serial {s}");
                assert!(
                    (p - s).abs() < 1e-4,
                    "P={nparts} chunk={chunk_acts} layer {k} nnz {i}: pipelined {p} vs serial {s}"
                );
            }
            for (((o, b), p), s) in ov.net.biases[k]
                .iter()
                .zip(bl.net.biases[k].iter())
                .zip(pi.net.biases[k].iter())
                .zip(serial.biases[k].iter())
            {
                assert!((o - b).abs() < 1e-4 && (o - s).abs() < 1e-4, "P={nparts} bias layer {k}");
                assert!((p - s).abs() < 1e-4, "P={nparts} pipelined bias layer {k}");
            }
        }
    });
}

/// Minibatch steps agree between the two engines (the overlapped engine's
/// compact batch-mean SpBP mirrors the full-width one).
#[test]
fn minibatch_overlap_matches_blocking() {
    prop::check_seeded(0x3B1C, 5, |rng| {
        let n = 8 + rng.gen_range(10);
        let layers = 2 + rng.gen_range(2);
        let nparts = 2 + rng.gen_range(5);
        let b = 1 + rng.gen_range(4);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        // one packed batch, row-major [n × b]
        let x: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let y: Vec<f32> = (0..n * b)
            .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
            .collect();

        let trained = |mode: ExecMode| -> (SparseNet, f32) {
            let run = run_ranks(part.nparts, |rank, ep| {
                let mut st = RankState::build(&net, &part, &plan, rank as u32, mode);
                let loss = st.train_step_minibatch(ep, &plan, &x, &y, b, 0.3);
                (st, loss)
            })
            .expect("minibatch run");
            let mut out = net.clone();
            let mut loss = 0f32;
            for (st, l) in run.outputs {
                st.merge_into(&mut out);
                loss += l;
            }
            (out, loss)
        };
        let (ov, ov_loss) = trained(ExecMode::Overlap);
        let (bl, bl_loss) = trained(ExecMode::Blocking);
        let (pi, pi_loss) = trained(ExecMode::Pipelined { chunk_acts: 1 + b % 4 });
        assert!(
            (ov_loss - bl_loss).abs() < 1e-4,
            "P={nparts} b={b}: loss {ov_loss} vs {bl_loss}"
        );
        assert!(
            (pi_loss - bl_loss).abs() < 1e-4,
            "P={nparts} b={b}: pipelined loss {pi_loss} vs {bl_loss}"
        );
        for k in 0..net.depth() {
            for (i, ((o, bv), p)) in ov.layers[k]
                .vals
                .iter()
                .zip(bl.layers[k].vals.iter())
                .zip(pi.layers[k].vals.iter())
                .enumerate()
            {
                assert!(
                    (o - bv).abs() < 1e-4,
                    "P={nparts} b={b} layer {k} nnz {i}: {o} vs {bv}"
                );
                assert!(
                    (p - bv).abs() < 1e-4,
                    "P={nparts} b={b} layer {k} nnz {i}: pipelined {p} vs {bv}"
                );
            }
        }
    });
}

/// The merge of a split-mode state reconstructs the exact original weights
/// when nothing was trained — the split/merge round-trip is lossless, in
/// both the overlap layout and the pipelined boundary-first row layout.
#[test]
fn split_merge_roundtrip_is_lossless() {
    prop::check_seeded(0x90FD, 10, |rng| {
        let n = 8 + rng.gen_range(12);
        let layers = 2 + rng.gen_range(3);
        let nparts = 1 + rng.gen_range(8);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        for mode in [ExecMode::Overlap, ExecMode::Pipelined { chunk_acts: 2 }] {
            let mut merged = net.clone();
            // zero out to prove the merge rewrites every value
            for w in merged.layers.iter_mut() {
                w.vals.iter_mut().for_each(|v| *v = 0.0);
            }
            for rank in 0..nparts as u32 {
                let st = RankState::build(&net, &part, &plan, rank, mode);
                st.merge_into(&mut merged);
            }
            for k in 0..net.depth() {
                assert_eq!(
                    merged.layers[k].vals, net.layers[k].vals,
                    "P={nparts} layer {k} ({mode:?}): split→merge changed values"
                );
            }
        }
    });
}

/// Contiguous serving partitions (the pool default) run the overlapped
/// and pipelined engines correctly too — the exact configurations the
/// benches measure.
#[test]
fn overlap_and_pipelined_match_serial_on_contiguous_partition() {
    let mut rng = Rng::new(1234);
    let net = random_net(&mut rng, 32, 4, 0.2);
    for nparts in [1usize, 2, 4, 8] {
        let part: DnnPartition = spdnn::partition::contiguous_partition(&net.layers, nparts);
        let plan = CommPlan::build(&net.layers, &part);
        for b in [0usize, 1, 5, 16] {
            let x0: Vec<f32> = (0..32 * b).map(|_| rng.gen_f32()).collect();
            let serial = infer_batch(&net, &x0, b);
            for mode in [
                ExecMode::Overlap,
                ExecMode::pipelined(),
                ExecMode::Pipelined { chunk_acts: 3 },
            ] {
                let (out, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
                assert_eq!(out.len(), serial.len());
                for (o, s) in out.iter().zip(serial.iter()) {
                    assert!((o - s).abs() < 1e-5, "P={nparts} b={b} {mode:?}");
                }
            }
        }
    }
}

/// A rank that owns ZERO rows in some layer (empty local segment, no
/// outbound transfers from that layer) must flow through both compact
/// engines, forward and backward.
#[test]
fn zero_row_rank_layers_are_correct_in_all_modes() {
    let n = 6;
    let mut rng = Rng::new(77);
    let mut ws = Vec::new();
    for _ in 0..3 {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(0.5) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, r, 1.0);
            }
        }
        ws.push(coo.to_csr());
    }
    let net = SparseNet::new(ws, Activation::Sigmoid);
    // rank 1 owns nothing in layer 1; rank 2 owns nothing in layer 0
    let part = DnnPartition {
        nparts: 3,
        input_parts: vec![0, 0, 1, 1, 2, 2],
        layer_parts: vec![
            vec![0, 0, 1, 1, 0, 1],
            vec![0, 0, 0, 2, 2, 2],
            vec![0, 1, 1, 2, 0, 1],
        ],
    };
    part.validate(&net.layers).expect("valid partition");
    let plan = CommPlan::build(&net.layers, &part);
    let mut rng = Rng::new(5);
    for b in [0usize, 1, 4] {
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let serial = infer_batch(&net, &x0, b);
        for mode in [
            ExecMode::Overlap,
            ExecMode::Pipelined { chunk_acts: 1 },
            ExecMode::Pipelined { chunk_acts: 0 },
        ] {
            let (out, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
            for (o, s) in out.iter().zip(serial.iter()) {
                assert!((o - s).abs() < 1e-5, "b={b} {mode:?}");
            }
        }
    }
    // backward too: one epoch of training matches the serial oracle
    let inputs: Vec<Vec<f32>> = (0..3).map(|_| (0..n).map(|_| rng.gen_f32()).collect()).collect();
    let targets: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..n).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect())
        .collect();
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.4, 1);
    for mode in [ExecMode::Overlap, ExecMode::Pipelined { chunk_acts: 2 }] {
        let run = run_with_plan_mode(&net, &part, &plan, &inputs, &targets, 0.4, 1, mode);
        for (a, s) in run.losses.iter().zip(sl.iter()) {
            assert!((a - s).abs() < 1e-4, "{mode:?}: loss {a} vs {s}");
        }
        for k in 0..net.depth() {
            for (a, s) in run.net.layers[k].vals.iter().zip(serial.layers[k].vals.iter()) {
                assert!((a - s).abs() < 1e-4, "{mode:?} layer {k}");
            }
        }
    }
}

/// Two destinations that need the SAME boundary rows: the second group's
/// boundary row range is empty (all rows claimed by the first), and its
/// payload must still post correctly.
#[test]
fn empty_boundary_range_destination_is_correct() {
    // W^0 is diagonal (no layer-0 transfers). In W^1, the rows owned by
    // ranks 1 and 2 read exactly the two activation columns owned by
    // rank 0 — two outbound transfers from rank 0 with identical index
    // sets {0, 1}.
    let mut w0 = Coo::new(4, 4);
    for r in 0..4 {
        w0.push(r, r, 0.5 + r as f32 * 0.1);
    }
    let mut w1 = Coo::new(4, 4);
    w1.push(0, 0, 1.0);
    w1.push(0, 1, -0.5);
    w1.push(1, 0, 0.3);
    w1.push(1, 1, 0.7);
    w1.push(2, 0, -0.2);
    w1.push(2, 1, 0.9);
    w1.push(3, 0, 0.4);
    w1.push(3, 1, -0.8);
    let net = SparseNet::new(vec![w0.to_csr(), w1.to_csr()], Activation::Sigmoid);
    let part = DnnPartition {
        nparts: 3,
        input_parts: vec![0, 0, 1, 2],
        layer_parts: vec![vec![0, 0, 1, 2], vec![0, 0, 1, 2]],
    };
    part.validate(&net.layers).expect("valid partition");
    let plan = CommPlan::build(&net.layers, &part);
    // sanity: the two transfers of layer 1 really share their index set
    let out0 = plan.layers[1].outbound_of(0);
    assert_eq!(out0.len(), 2, "rank 0 must feed two destinations");
    assert_eq!(out0[0].2, out0[1].2, "identical boundary rows");
    let mut rng = Rng::new(9);
    for b in [0usize, 1, 3] {
        let x0: Vec<f32> = (0..4 * b).map(|_| rng.gen_f32()).collect();
        let serial = infer_batch(&net, &x0, b);
        for chunk_acts in [0usize, 1, 2] {
            let (out, _) = infer_with_plan_mode(
                &net,
                &part,
                &plan,
                &x0,
                b,
                ExecMode::Pipelined { chunk_acts },
            );
            for (o, s) in out.iter().zip(serial.iter()) {
                assert!((o - s).abs() < 1e-5, "b={b} chunk={chunk_acts}");
            }
        }
    }
    // and the backward mirror over the duplicated-destination transfers
    let inputs = vec![vec![0.4, 0.9, 0.1, 0.7]];
    let targets = vec![vec![1.0, 0.0, 0.0, 1.0]];
    let mut serial = net.clone();
    let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.5, 2);
    let run = run_with_plan_mode(
        &net,
        &part,
        &plan,
        &inputs,
        &targets,
        0.5,
        2,
        ExecMode::Pipelined { chunk_acts: 1 },
    );
    for (a, s) in run.losses.iter().zip(sl.iter()) {
        assert!((a - s).abs() < 1e-4, "loss {a} vs {s}");
    }
    for k in 0..net.depth() {
        for (a, s) in run.net.layers[k].vals.iter().zip(serial.layers[k].vals.iter()) {
            assert!((a - s).abs() < 1e-4, "layer {k}: {a} vs {s}");
        }
    }
}
