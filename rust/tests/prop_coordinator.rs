//! Property tests on coordinator invariants: routing (plan ↔ live
//! counters), batching (SpMM ≡ per-column SpMV; minibatch b=1 ≡ per-sample
//! step), and state (merge reconstructs exactly what ranks hold; serial
//! equivalence under randomized nets, partitions, and rank counts).

use spdnn::coordinator::minibatch::train_distributed_minibatch;
use spdnn::coordinator::sgd::{infer_distributed, run_with_plan, train_distributed};
use spdnn::dnn::{sgd_serial, Activation, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::sparse::Coo;
use spdnn::util::{prop, Rng};

/// Random sparse net with every neuron connected (gradients flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

fn random_data(rng: &mut Rng, count: usize, n: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let inputs = (0..count)
        .map(|_| (0..n).map(|_| rng.gen_f32()).collect())
        .collect();
    let targets = (0..count)
        .map(|_| (0..n).map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 }).collect())
        .collect();
    (inputs, targets)
}

#[test]
fn routing_live_counters_always_equal_plan() {
    prop::check_seeded(0xC0DE, 12, |rng| {
        let n = 8 + rng.gen_range(24);
        let layers = 2 + rng.gen_range(3);
        let nparts = 2 + rng.gen_range(5);
        let net = random_net(rng, n, layers, 0.15);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let (inputs, targets) = random_data(rng, 2, n);
        let run = run_with_plan(&net, &part, &plan, &inputs, &targets, 0.1, 1);
        let fs = plan.fwd_send_volume_per_rank();
        let fr = plan.fwd_recv_volume_per_rank();
        let ms = plan.fwd_send_msgs_per_rank();
        let mr = plan.fwd_recv_msgs_per_rank();
        for r in 0..nparts {
            assert_eq!(run.sent[r].0, 2 * (fs[r] + fr[r]), "rank {r} words");
            assert_eq!(run.sent[r].1, 2 * (ms[r] + mr[r]), "rank {r} msgs");
        }
    });
}

#[test]
fn state_distributed_equals_serial_randomized() {
    prop::check_seeded(0x5EED5, 10, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 2 + rng.gen_range(6);
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let (inputs, targets) = random_data(rng, 3, n);
        let run = train_distributed(&net, &part, &inputs, &targets, 0.25, 1);
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.25, 1);
        for (a, b) in run.losses.iter().zip(sl.iter()) {
            assert!((a - b).abs() < 1e-3, "loss {a} vs {b}");
        }
        for k in 0..net.depth() {
            for (a, b) in run.net.layers[k]
                .vals
                .iter()
                .zip(serial.layers[k].vals.iter())
            {
                assert!((a - b).abs() < 1e-3, "layer {k}");
            }
        }
    });
}

#[test]
fn batching_inference_equals_serial_randomized() {
    prop::check_seeded(0xBA7C4, 10, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 2 + rng.gen_range(4);
        let b = 1 + rng.gen_range(6);
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let serial = spdnn::dnn::inference::infer_batch(&net, &x0, b);
        let (out, _) = infer_distributed(&net, &part, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-4);
        }
    });
}

#[test]
fn batching_minibatch_b1_equals_per_sample_randomized() {
    prop::check_seeded(0xB1, 8, |rng| {
        let n = 8 + rng.gen_range(12);
        let layers = 2 + rng.gen_range(2);
        let nparts = 2 + rng.gen_range(3);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let (inputs, targets) = random_data(rng, 3, n);
        let a = train_distributed_minibatch(&net, &part, &inputs, &targets, 1, 0.2, 1);
        let b = train_distributed(&net, &part, &inputs, &targets, 0.2, 1);
        for (x, y) in a.losses.iter().zip(b.losses.iter()) {
            assert!((x - y).abs() < 1e-5);
        }
        for k in 0..net.depth() {
            for (u, v) in a.net.layers[k].vals.iter().zip(b.net.layers[k].vals.iter()) {
                assert!((u - v).abs() < 1e-5);
            }
        }
    });
}

#[test]
fn state_merge_preserves_untouched_weights() {
    // training with eta = 0 must leave the merged model exactly equal to
    // the input model (merge writes back precisely what ranks hold).
    prop::check_seeded(0xE7A0, 8, |rng| {
        let n = 8 + rng.gen_range(12);
        let net = random_net(rng, n, 2, 0.3);
        let nparts = 2 + rng.gen_range(4);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let (inputs, targets) = random_data(rng, 2, n);
        let run = train_distributed(&net, &part, &inputs, &targets, 0.0, 1);
        for k in 0..net.depth() {
            assert_eq!(run.net.layers[k].vals, net.layers[k].vals, "layer {k}");
            assert_eq!(run.net.biases[k], net.biases[k]);
        }
    });
}
