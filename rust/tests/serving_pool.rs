//! Integration: the persistent serving pool — multi-threaded stress
//! against the serial reference, micro-batch coalescing, fault-injected
//! failure recovery (requeue, watchdog, circuit breaker), and graceful
//! shutdown with the no-message-leak invariant.

use spdnn::coordinator::ExecMode;
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::SparseNet;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::runtime::{FaultPlan, FaultSpec};
use spdnn::serving::{PoolConfig, RankPool, RecoveryConfig, ServeError};
use spdnn::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn net64() -> SparseNet {
    generate(&RadixNetConfig::graph_challenge(64, 3).expect("cfg"))
}

fn random_input(rng: &mut Rng, n: usize, b: usize) -> Vec<f32> {
    (0..n * b)
        .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
        .collect()
}

fn assert_matches_serial(net: &SparseNet, x0: &[f32], b: usize, out: &[f32], ctx: &str) {
    let serial = infer_batch(net, x0, b);
    assert_eq!(out.len(), serial.len(), "{ctx}: output shape");
    for (i, (a, s)) in out.iter().zip(serial.iter()).enumerate() {
        assert!((a - s).abs() < 1e-5, "{ctx}: entry {i}: {a} vs serial {s}");
    }
}

/// Fast backoff so recovery tests don't sit in respawn sleeps.
fn quick_recovery(retry_budget: u32) -> RecoveryConfig {
    RecoveryConfig {
        retry_budget,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(10),
        ..RecoveryConfig::default()
    }
}

/// THE scheduler stress test: 8 client threads × 50 requests each with
/// mixed batch sizes; every ticket must match the serial engine within
/// 1e-5 and the pool must shut down without leaking a single message.
#[test]
fn stress_eight_clients_fifty_requests_match_serial() {
    let net = Arc::new(net64());
    let pool = Arc::new(RankPool::start(
        (*net).clone(),
        PoolConfig {
            nranks: 4,
            max_batch: 32,
            max_wait: Duration::from_millis(1),
            adaptive: true,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    ));
    let clients = 8usize;
    let requests = 50usize;
    let sizes = [1usize, 2, 3, 5, 8];
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let net = Arc::clone(&net);
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                for r in 0..requests {
                    let b = sizes[(c + r) % sizes.len()];
                    let x0 = random_input(&mut rng, 64, b);
                    let out = pool
                        .submit(x0.clone(), b)
                        .wait()
                        .unwrap_or_else(|f| panic!("client {c} req {r}: {f}"));
                    assert_matches_serial(&net, &x0, b, &out, &format!("client {c} req {r}"));
                }
            })
        })
        .collect();
    let total_cols: usize = (0..clients)
        .flat_map(|c| (0..requests).map(move |r| sizes[(c + r) % sizes.len()]))
        .sum();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let summary = pool.shutdown().expect("first shutdown");
    assert!(
        summary.leaked_ranks.is_empty(),
        "messages leaked at shutdown: ranks {:?}",
        summary.leaked_ranks
    );
    let s = &summary.stats;
    assert_eq!(s.requests, (clients * requests) as u64);
    assert_eq!(s.failed_requests, 0);
    assert_eq!(s.pool_rebuilds, 0);
    assert_eq!(s.columns, total_cols as u64);
    assert!(s.batches <= s.requests, "batches never exceed requests");
    assert!(s.p50_secs > 0.0 && s.p99_secs >= s.p50_secs);
}

/// A burst of single-image requests must be coalesced into far fewer
/// fused dispatches than requests.
#[test]
fn queued_singles_coalesce_into_batches() {
    let net = net64();
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(200),
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<f32>> = (0..16).map(|_| random_input(&mut rng, 64, 1)).collect();
    let tickets: Vec<_> = inputs.iter().map(|x0| pool.submit(x0.clone(), 1)).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().expect("served");
        assert_matches_serial(&net, &inputs[i], 1, &out, &format!("single {i}"));
    }
    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    assert_eq!(summary.stats.requests, 16);
    assert!(
        summary.stats.batches <= 4,
        "16 back-to-back singles should coalesce, got {} batches",
        summary.stats.batches
    );
    assert!(summary.stats.mean_batch >= 4.0);
}

/// Satellite regression (ported from the old `submit_sabotaged` hook to
/// the seeded failpoint engine): an injected rank panic mid-request fails
/// only that request's ticket with the root-cause `RankFailure` — never a
/// masked secondary unwind — and the pool rebuilds its generation and
/// keeps serving correctly afterwards.
#[test]
fn rank_panic_fails_one_request_then_pool_recovers() {
    let net = net64();
    // panic_p = 1.0 with a budget of exactly one fault: the first fused
    // dispatch panics on whichever rank wins the budget race, everything
    // after is fault-free. retry_budget 0 makes the failure observable.
    let plan = FaultPlan::new(FaultSpec {
        panic_p: 1.0,
        budget: 1,
        ..FaultSpec::default()
    });
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 4,
            max_batch: 8,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            faults: Some(Arc::clone(&plan)),
            recovery: quick_recovery(0),
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(21);

    // injected fault: one rank panics serving the first request
    let x0 = random_input(&mut rng, 64, 2);
    let err = pool
        .submit(x0, 2)
        .wait()
        .expect_err("faulted request must fail");
    let rf = err.rank_failure().expect("expected a rank failure");
    assert!(rf.rank < 4, "failure carries a real rank: {}", rf.rank);
    assert!(
        rf.message.contains("fault injected: compute panic"),
        "root cause must not be masked by a secondary unwind: {}",
        rf.message
    );
    assert_eq!(plan.injected(), 1, "exactly one fault fired");

    // the pool must still be fully serviceable afterwards
    for r in 0..6 {
        let b = 1 + (r % 3);
        let x0 = random_input(&mut rng, 64, b);
        let out = pool
            .submit(x0.clone(), b)
            .wait()
            .unwrap_or_else(|f| panic!("post-fault request {r}: {f}"));
        assert_matches_serial(&net, &x0, b, &out, &format!("post-fault {r}"));
    }

    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty(), "post-recovery leak");
    assert_eq!(summary.stats.failed_requests, 1);
    assert_eq!(summary.stats.pool_rebuilds, 1);
    assert_eq!(summary.stats.generations_respawned, 1);
    assert_eq!(summary.stats.requests, 6, "only successful requests count");
}

/// Tentpole: with a retry budget, the innocent request from a poisoned
/// batch is requeued onto the respawned generation and still served
/// correctly — the caller sees plain `Ok`, never the fault.
#[test]
fn retry_budget_masks_one_injected_fault() {
    let net = net64();
    let plan = FaultPlan::new(FaultSpec {
        panic_p: 1.0,
        budget: 1,
        ..FaultSpec::default()
    });
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 3,
            max_batch: 8,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            faults: Some(Arc::clone(&plan)),
            recovery: quick_recovery(2),
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(55);
    let x0 = random_input(&mut rng, 64, 3);
    let out = pool
        .submit(x0.clone(), 3)
        .wait()
        .expect("retried request must succeed");
    assert_matches_serial(&net, &x0, 3, &out, "retried");
    assert_eq!(plan.injected(), 1);

    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    assert_eq!(summary.stats.requests, 1);
    assert_eq!(summary.stats.failed_requests, 0, "the retry absorbed the fault");
    assert_eq!(summary.stats.requests_retried, 1);
    assert_eq!(summary.stats.pool_rebuilds, 1);
    assert_eq!(summary.stats.generations_respawned, 1);
}

/// Tentpole: a stall longer than the watchdog deadline is converted into
/// a typed watchdog trip (not a hang), the innocent request is retried,
/// and the trip is counted.
#[test]
fn stall_watchdog_trips_and_request_is_retried() {
    let net = net64();
    let plan = FaultPlan::new(FaultSpec {
        stall_p: 1.0,
        stall_ms: 400,
        watchdog_ms: 100,
        budget: 1,
        ..FaultSpec::default()
    });
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 2,
            max_batch: 8,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            faults: Some(Arc::clone(&plan)),
            recovery: quick_recovery(2),
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(91);
    let x0 = random_input(&mut rng, 64, 2);
    let out = pool
        .submit(x0.clone(), 2)
        .wait()
        .expect("stalled request must recover via retry");
    assert_matches_serial(&net, &x0, 2, &out, "post-stall");
    assert_eq!(plan.injected(), 1);

    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    assert_eq!(summary.stats.failed_requests, 0);
    assert_eq!(summary.stats.watchdog_trips, 1, "the stall surfaced as a watchdog trip");
    assert_eq!(summary.stats.requests_retried, 1);
    assert_eq!(summary.stats.pool_rebuilds, 1);
}

/// Tentpole: repeated generation failures trip the circuit breaker — the
/// pool fast-fails with `Unavailable` instead of queueing behind the
/// crash loop — and a half-open trial after the cooldown closes it again.
#[test]
fn breaker_opens_after_streak_and_half_open_trial_closes_it() {
    let net = net64();
    let plan = FaultPlan::new(FaultSpec {
        panic_p: 1.0,
        budget: 3,
        ..FaultSpec::default()
    });
    let cooldown = Duration::from_millis(250);
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 1,
            max_batch: 4,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            faults: Some(Arc::clone(&plan)),
            recovery: RecoveryConfig {
                retry_budget: 0,
                backoff_base: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(5),
                breaker_threshold: 3,
                breaker_cooldown: cooldown,
            },
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(13);

    // three consecutive injected failures trip the breaker
    for r in 0..3 {
        let x0 = random_input(&mut rng, 64, 1);
        let err = pool.submit(x0, 1).wait().expect_err("injected failure");
        assert!(err.rank_failure().is_some(), "req {r}: {err}");
    }
    assert_eq!(plan.injected(), 3);
    assert_eq!(pool.stats().breaker_state, 2, "breaker must be open");

    // while open: fast-fail, no dispatch, no extra rebuild
    let x0 = random_input(&mut rng, 64, 1);
    let err = pool.submit(x0, 1).wait().expect_err("breaker fast-fail");
    match err {
        ServeError::Unavailable { failures } => assert_eq!(failures, 3),
        other => panic!("expected Unavailable, got {other}"),
    }
    assert!(err.is_unavailable());

    // after the cooldown the half-open trial succeeds (fault budget is
    // spent) and the breaker closes
    std::thread::sleep(cooldown + Duration::from_millis(150));
    let x0 = random_input(&mut rng, 64, 1);
    let out = pool
        .submit(x0.clone(), 1)
        .wait()
        .expect("half-open trial must be served");
    assert_matches_serial(&net, &x0, 1, &out, "half-open trial");
    assert_eq!(pool.stats().breaker_state, 0, "trial success closes the breaker");

    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    assert_eq!(summary.stats.requests, 1);
    assert_eq!(summary.stats.failed_requests, 3);
    assert_eq!(summary.stats.unavailable_requests, 1);
    assert_eq!(summary.stats.pool_rebuilds, 3);
    assert!(summary.stats.generations_respawned <= summary.stats.pool_rebuilds + 1);
}

/// Graceful shutdown: requests already queued when shutdown is requested
/// are still served (and correctly).
#[test]
fn shutdown_drains_queued_requests() {
    let net = net64();
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(33);
    let inputs: Vec<Vec<f32>> = (0..12).map(|_| random_input(&mut rng, 64, 2)).collect();
    let tickets: Vec<_> = inputs.iter().map(|x0| pool.submit(x0.clone(), 2)).collect();
    let summary = pool.shutdown().expect("shutdown");
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().expect("queued request served during drain");
        assert_matches_serial(&net, &inputs[i], 2, &out, &format!("drained {i}"));
    }
    assert_eq!(summary.stats.requests, 12);
    assert!(summary.leaked_ranks.is_empty());
}

/// A request larger than `max_batch` is served alone (never split) and
/// still matches serial.
#[test]
fn oversized_request_served_alone() {
    let net = net64();
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 3,
            max_batch: 4,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(5);
    let b = 10;
    let x0 = random_input(&mut rng, 64, b);
    let out = pool.submit(x0.clone(), b).wait().expect("served");
    assert_matches_serial(&net, &x0, b, &out, "oversized");
    let summary = pool.shutdown().expect("shutdown");
    assert_eq!(summary.stats.batches, 1);
    assert_eq!(summary.stats.columns, b as u64);
}

/// Satellite: a ticket whose queue wait blows its SLO is shed with
/// `ServeError::DeadlineExceeded` instead of being served late, and the
/// shed shows up in the stats — while a generous SLO is served normally.
#[test]
fn deadline_blown_ticket_is_shed_not_served_late() {
    let net = net64();
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 2,
            max_batch: 8,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(77);

    // keep the scheduler busy so the deadline ticket has to queue
    let busy: Vec<_> = (0..4)
        .map(|_| {
            let x0 = random_input(&mut rng, 64, 8);
            pool.submit(x0, 8)
        })
        .collect();
    // zero SLO: any nonzero queue wait blows it
    let x0 = random_input(&mut rng, 64, 2);
    let doomed = pool.submit_with_deadline(x0, 2, Duration::ZERO);
    let err = doomed.wait().expect_err("zero-SLO ticket must be shed");
    assert!(err.is_deadline(), "expected deadline shed, got: {err}");
    match err {
        ServeError::DeadlineExceeded { waited, slo } => {
            assert_eq!(slo, Duration::ZERO);
            assert!(waited > Duration::ZERO);
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }
    for t in busy {
        t.wait().expect("busy traffic still served");
    }

    // a generous SLO is served normally and matches serial
    let x0 = random_input(&mut rng, 64, 3);
    let out = pool
        .submit_with_deadline(x0.clone(), 3, Duration::from_secs(60))
        .wait()
        .expect("generous SLO served");
    assert_matches_serial(&net, &x0, 3, &out, "generous SLO");

    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    assert_eq!(summary.stats.shed_requests, 1);
    assert_eq!(summary.stats.failed_requests, 0, "shed is not a rank failure");
    assert_eq!(summary.stats.pool_rebuilds, 0, "shedding forces no rebuild");
    assert_eq!(summary.stats.requests, 5, "4 busy + 1 generous served");
}

/// Deadline shedding also applies while draining the queue at shutdown:
/// stale tickets fail fast instead of being served long past their SLO.
#[test]
fn shutdown_drain_sheds_expired_tickets() {
    let net = net64();
    let pool = RankPool::start(
        net,
        PoolConfig {
            nranks: 2,
            max_batch: 4,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::Overlap,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(41);
    let x0 = random_input(&mut rng, 64, 2);
    let kept = pool.submit(x0, 2);
    let x0 = random_input(&mut rng, 64, 2);
    let doomed = pool.submit_with_deadline(x0, 2, Duration::ZERO);
    let summary = pool.shutdown().expect("shutdown");
    kept.wait().expect("undeadlined ticket drains normally");
    assert!(doomed.wait().expect_err("expired at drain").is_deadline());
    assert_eq!(summary.stats.shed_requests, 1);
}

/// The pipelined engine serves correctly behind the pool — mixed batch
/// sizes over long-lived rank threads, chunked sub-transfer tags reused
/// across requests without cross-request mismatches.
#[test]
fn pipelined_mode_pool_matches_serial() {
    let net = net64();
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 3,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            adaptive: true,
            mode: ExecMode::Pipelined { chunk_acts: 4 },
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(23);
    for req in 0..8 {
        let b = 1 + (req % 4);
        let x0 = random_input(&mut rng, 64, b);
        let out = pool.submit(x0.clone(), b).wait().expect("served");
        assert_matches_serial(&net, &x0, b, &out, &format!("pipelined req {req}"));
    }
    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty(), "chunked tags leaked messages");
    assert_eq!(summary.stats.requests, 8);
    assert_eq!(summary.stats.failed_requests, 0);
}
