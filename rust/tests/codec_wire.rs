//! Integration: the wire-codec subsystem across every engine.
//!
//! - `Codec::F32` is a true passthrough: all four engines (serial,
//!   blocking, overlap, pipelined) agree to 1e-5 across 1–8 ranks, and
//!   the live word counters still equal the plan volumes exactly (zero
//!   wire overhead).
//! - `Codec::F16` / `Codec::Int8` keep every engine within the codec's
//!   bounded error of the serial oracle while measurably shrinking the
//!   bytes on the wire.
//! - f16 digits SGD converges on par with f32 (the accuracy half of the
//!   compression trade).
//! - The pipelined engine's live message counters match the plan's
//!   **chunk-aware** expected counts — the cross-check that gates the
//!   pool's pipelined-by-default flip.

use spdnn::comm::Codec;
use spdnn::coordinator::sgd::{infer_with_plan_mode, run_with_plan_mode};
use spdnn::coordinator::ExecMode;
use spdnn::dnn::inference::infer_batch;
use spdnn::dnn::{Activation, SparseNet};
use spdnn::partition::plan::CommPlan;
use spdnn::partition::random::random_partition;
use spdnn::serving::{PoolConfig, RankPool};
use spdnn::sparse::Coo;
use spdnn::util::{prop, Rng};
use std::time::Duration;

/// Random sparse net with every neuron connected (so values flow).
fn random_net(rng: &mut Rng, n: usize, layers: usize, p: f64) -> SparseNet {
    let mut ws = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let mut any = false;
            for c in 0..n {
                if rng.gen_bool(p) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                    any = true;
                }
            }
            if !any {
                coo.push(r, rng.gen_range(n), rng.gen_f32_range(-1.0, 1.0));
            }
        }
        ws.push(coo.to_csr());
    }
    SparseNet::new(ws, Activation::Sigmoid)
}

/// THE acceptance property: with the codec explicitly pinned to F32 the
/// wire is bit-identical to the pre-codec fabric — serial ≡ blocking ≡
/// overlap ≡ pipelined to 1e-5 across 1–8 ranks, and the live word
/// counters still equal the plan volumes exactly (no headers, no
/// reshaping).
#[test]
fn f32_codec_is_passthrough_in_every_engine() {
    prop::check_seeded(0xC0DE, 10, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(3);
        let nparts = 1 + rng.gen_range(8);
        let b = 1 + rng.gen_range(6);
        let chunk_acts = 1 + rng.gen_range(4);
        let net = random_net(rng, n, layers, 0.2);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let mut plan = CommPlan::build(&net.layers, &part);
        plan.set_codec(Codec::F32, Codec::F32);
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();

        let serial = infer_batch(&net, &x0, b);
        for mode in [
            ExecMode::Blocking,
            ExecMode::Overlap,
            ExecMode::Pipelined { chunk_acts },
        ] {
            let (out, sent) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
            for (i, (o, s)) in out.iter().zip(serial.iter()).enumerate() {
                assert!(
                    (o - s).abs() < 1e-5,
                    "P={nparts} b={b} {mode:?} entry {i}: {o} vs serial {s}"
                );
            }
            // zero wire overhead: words sent == plan forward volume × b
            let fwd = plan.fwd_send_volume_per_rank();
            for (r, &(words, _)) in sent.iter().enumerate() {
                assert_eq!(
                    words,
                    fwd[r] * b as u64,
                    "P={nparts} {mode:?} rank {r}: F32 codec must add no wire words"
                );
            }
        }
    });
}

/// Lossy codecs keep every engine within a bounded distance of the serial
/// oracle — forward paths only, all three engines, chunked and unchunked.
#[test]
fn lossy_codecs_bound_inference_error_in_every_engine() {
    prop::check_seeded(0xF16, 8, |rng| {
        let n = 8 + rng.gen_range(16);
        let layers = 2 + rng.gen_range(2);
        let nparts = 2 + rng.gen_range(6);
        let b = 1 + rng.gen_range(5);
        let chunk_acts = 1 + rng.gen_range(4);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let x0: Vec<f32> = (0..n * b).map(|_| rng.gen_f32()).collect();
        let serial = infer_batch(&net, &x0, b);

        // sigmoid keeps activations in [0,1]; with ≤ 4 layers the f16
        // per-hop error (≤ 2^-11 rel) stays far below 1e-2, and the int8
        // per-hop error (≤ absmax/254) below ~2e-1
        for (codec, tol) in [(Codec::F16, 1e-2f32), (Codec::int8(), 0.2)] {
            let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
            for mode in [
                ExecMode::Blocking,
                ExecMode::Overlap,
                ExecMode::Pipelined { chunk_acts },
            ] {
                let (out, _) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
                for (i, (o, s)) in out.iter().zip(serial.iter()).enumerate() {
                    assert!(
                        (o - s).abs() < tol,
                        "{codec:?} {mode:?} P={nparts} b={b} entry {i}: {o} vs {s}"
                    );
                }
            }
        }
    });
}

/// f16 payloads ship measurably fewer words than f32 on the same plan
/// once transfers are wide enough to amortize the 2-word headers.
#[test]
fn f16_shrinks_live_wire_words() {
    let mut rng = Rng::new(31);
    let net = random_net(&mut rng, 48, 3, 0.4);
    let part = random_partition(&net.layers, 4, 5);
    let b = 16usize;
    let x0: Vec<f32> = (0..48 * b).map(|_| rng.gen_f32()).collect();
    let words_of = |codec: Codec| -> u64 {
        let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
        let (_, sent) = infer_with_plan_mode(&net, &part, &plan, &x0, b, ExecMode::Overlap);
        sent.iter().map(|&(w, _)| w).sum()
    };
    let w32 = words_of(Codec::F32);
    let w16 = words_of(Codec::F16);
    let w8 = words_of(Codec::int8());
    assert!(w32 > 0, "this partition must communicate");
    assert!(
        w16 * 10 <= w32 * 6,
        "f16 {w16} words vs f32 {w32}: must be under 60%"
    );
    assert!(
        w8 * 10 <= w32 * 4,
        "int8 {w8} words vs f32 {w32}: must be under 40%"
    );
}

/// SGD convergence parity at f16 on the digits workload: training the
/// same net on the same data under f16 payloads must land within 1% of
/// the f32 final loss (the paper-facing accuracy criterion), in both the
/// overlap and pipelined engines, forward AND backward compressed.
#[test]
fn f16_digits_sgd_converges_on_par_with_f32() {
    use spdnn::data::synthetic_mnist;
    use spdnn::partition::contiguous_partition;
    use spdnn::radixnet::{generate, RadixNetConfig};
    let n = 64usize;
    let net = generate(&RadixNetConfig::graph_challenge(n, 4).expect("cfg"));
    let part = contiguous_partition(&net.layers, 4);
    let steps = 60usize;
    let data = synthetic_mnist(8, steps, 3);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..steps).map(|i| data.target(i, n)).collect();
    let final_loss = |codec: Codec, mode: ExecMode| -> f64 {
        let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
        let run = run_with_plan_mode(&net, &part, &plan, &inputs, &targets, 0.3, 1, mode);
        let tail = 6;
        run.losses[steps - tail..]
            .iter()
            .map(|&l| l as f64)
            .sum::<f64>()
            / tail as f64
    };
    let base = final_loss(Codec::F32, ExecMode::Overlap);
    assert!(base > 0.0 && base.is_finite());
    for mode in [ExecMode::Overlap, ExecMode::Pipelined { chunk_acts: 8 }] {
        let f16 = final_loss(Codec::F16, mode);
        let delta = (f16 - base).abs() / base;
        assert!(
            delta < 0.01,
            "{mode:?}: f16 final loss {f16} vs f32 {base} (Δ {:.3}%)",
            delta * 100.0
        );
    }
}

/// The pipelined engine's live counters match the plan's **chunk-aware**
/// expected message counts (and the unchanged word volumes) — the
/// cross-check the ROADMAP required before flipping the pool default.
#[test]
fn pipelined_live_counters_match_chunked_plan() {
    prop::check_seeded(0x51AC, 6, |rng| {
        let n = 8 + rng.gen_range(12);
        let layers = 2 + rng.gen_range(3);
        let nparts = 2 + rng.gen_range(5);
        let chunk_acts = 1 + rng.gen_range(5);
        let net = random_net(rng, n, layers, 0.25);
        let part = random_partition(&net.layers, nparts, rng.next_u64());
        let plan = CommPlan::build(&net.layers, &part);
        let samples = 2usize;
        let inputs: Vec<Vec<f32>> = (0..samples)
            .map(|_| (0..n).map(|_| rng.gen_f32()).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..samples)
            .map(|_| {
                (0..n)
                    .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        let run = run_with_plan_mode(
            &net,
            &part,
            &plan,
            &inputs,
            &targets,
            0.2,
            1,
            ExecMode::Pipelined { chunk_acts },
        );
        let fwd_words = plan.fwd_send_volume_per_rank();
        let bwd_words = plan.fwd_recv_volume_per_rank();
        let fwd_msgs = plan.fwd_send_msgs_per_rank_chunked(chunk_acts);
        let bwd_msgs = plan.fwd_recv_msgs_per_rank_chunked(chunk_acts);
        let steps = samples as u64;
        for r in 0..nparts {
            let expect_words = steps * (fwd_words[r] + bwd_words[r]);
            let expect_msgs = steps * (fwd_msgs[r] + bwd_msgs[r]);
            assert_eq!(
                run.sent[r].0, expect_words,
                "rank {r} words (chunk_acts {chunk_acts})"
            );
            assert_eq!(
                run.sent[r].1, expect_msgs,
                "rank {r} msgs (chunk_acts {chunk_acts})"
            );
        }
        // chunked counts collapse to the whole-transfer counts at 0
        assert_eq!(
            plan.fwd_send_msgs_per_rank_chunked(0),
            plan.fwd_send_msgs_per_rank()
        );
        assert_eq!(
            plan.fwd_recv_msgs_per_rank_chunked(0),
            plan.fwd_recv_msgs_per_rank()
        );
    });
}

/// The serving pool under an f16 codec: replies stay within the codec's
/// error of the serial engine and the stats report a real compression
/// ratio (raw bytes > wire bytes).
#[test]
fn pool_with_f16_codec_serves_and_reports_compression() {
    use spdnn::radixnet::{generate, RadixNetConfig};
    let net = generate(&RadixNetConfig::graph_challenge(64, 3).expect("cfg"));
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: 4,
            max_batch: 32,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::pipelined(),
            codec: Codec::F16,
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(77);
    for req in 0..4 {
        let b = 8usize;
        let x0: Vec<f32> = (0..64 * b)
            .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
            .collect();
        let out = pool.submit(x0.clone(), b).wait().expect("served");
        let serial = infer_batch(&net, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-2, "req {req}: {a} vs {s}");
        }
    }
    let summary = pool.shutdown().expect("shutdown");
    assert!(summary.leaked_ranks.is_empty());
    let s = &summary.stats;
    assert!(
        s.raw_bytes > s.wire_bytes && s.wire_bytes > 0,
        "raw {} wire {}: f16 must compress",
        s.raw_bytes,
        s.wire_bytes
    );
    assert!(s.wire_compression() > 1.2, "ratio {}", s.wire_compression());
    assert!(s.to_json().contains("\"wire_compression\""));
}
