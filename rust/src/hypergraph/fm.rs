//! Fiduccia–Mattheyses 2-way refinement with fixed-vertex support.
//!
//! Works on a bisection (sides 0/1) with per-side maximum weights. Fixed
//! vertices are permanently locked to their side. Pass-based: tentatively
//! move the best feasible vertex until none remain, keep the best prefix.

use super::model::{Hypergraph, FREE};
use std::collections::BinaryHeap;

/// Bisection state: side per vertex + per-net side counts.
pub struct Bisection<'a> {
    pub hg: &'a Hypergraph,
    pub side: Vec<u8>,
    /// pins of each net on side 0 / side 1
    cnt: Vec<[u32; 2]>,
    pub weight: [u64; 2],
}

impl<'a> Bisection<'a> {
    pub fn new(hg: &'a Hypergraph, side: Vec<u8>) -> Self {
        assert_eq!(side.len(), hg.nv);
        let mut cnt = vec![[0u32; 2]; hg.num_nets()];
        for n in 0..hg.num_nets() {
            for &p in hg.net_pins(n) {
                cnt[n][side[p as usize] as usize] += 1;
            }
        }
        let mut weight = [0u64; 2];
        for v in 0..hg.nv {
            weight[side[v] as usize] += hg.vwgt[v] as u64;
        }
        Self {
            hg,
            side,
            cnt,
            weight,
        }
    }

    /// Current (2-way) cutsize: Σ cost over nets with pins on both sides.
    pub fn cutsize(&self) -> u64 {
        (0..self.hg.num_nets())
            .filter(|&n| self.cnt[n][0] > 0 && self.cnt[n][1] > 0)
            .map(|n| self.hg.ncost[n] as u64)
            .sum()
    }

    /// FM gain of moving v to the other side.
    #[inline]
    fn gain(&self, v: usize) -> i64 {
        let s = self.side[v] as usize;
        let mut g = 0i64;
        for &n in self.hg.vertex_nets(v) {
            let n = n as usize;
            let c = self.hg.ncost[n] as i64;
            if self.cnt[n][s] == 1 {
                g += c; // moving v uncuts the net
            }
            if self.cnt[n][1 - s] == 0 {
                g -= c; // moving v cuts the net
            }
        }
        g
    }

    /// Apply a move (updates side, counts, weights).
    fn apply(&mut self, v: usize) {
        let s = self.side[v] as usize;
        let w = self.hg.vwgt[v] as u64;
        self.weight[s] -= w;
        self.weight[1 - s] += w;
        for &n in self.hg.vertex_nets(v) {
            let n = n as usize;
            self.cnt[n][s] -= 1;
            self.cnt[n][1 - s] += 1;
        }
        self.side[v] = 1 - self.side[v];
    }

    /// One FM pass. `maxw[s]` is the weight cap for side s. Returns the
    /// cut improvement (>= 0; 0 means no progress).
    pub fn fm_pass(&mut self, maxw: [u64; 2]) -> u64 {
        let nv = self.hg.nv;
        let mut locked = vec![false; nv];
        let mut stamp: Vec<u32> = vec![0; nv];
        let mut heap: BinaryHeap<(i64, u32, u32)> = BinaryHeap::new(); // (gain, stamp, v)
        for v in 0..nv {
            if self.hg.fixed[v] != FREE {
                locked[v] = true;
                continue;
            }
            heap.push((self.gain(v), 0, v as u32));
        }

        let start_cut = self.cutsize() as i64;
        let mut cur_gain = 0i64;
        let mut best_gain = 0i64;
        let mut moves: Vec<u32> = Vec::new();
        let mut best_len = 0usize;

        while let Some((g, st, vu)) = heap.pop() {
            let v = vu as usize;
            if locked[v] || st != stamp[v] {
                continue;
            }
            // re-check gain freshness (lazy heap)
            let fresh = self.gain(v);
            if fresh != g {
                stamp[v] += 1;
                heap.push((fresh, stamp[v], vu));
                continue;
            }
            // feasibility: destination side must stay under cap
            let dst = 1 - self.side[v] as usize;
            if self.weight[dst] + self.hg.vwgt[v] as u64 > maxw[dst] {
                // cannot move now; drop (may be re-pushed via neighbor updates)
                stamp[v] += 1;
                continue;
            }
            // tentatively move
            let touched: Vec<u32> = self
                .hg
                .vertex_nets(v)
                .iter()
                .flat_map(|&n| self.hg.net_pins(n as usize).iter().copied())
                .collect();
            self.apply(v);
            locked[v] = true;
            cur_gain += g;
            moves.push(vu);
            if cur_gain > best_gain {
                best_gain = cur_gain;
                best_len = moves.len();
            }
            // refresh neighbor gains
            for &u in &touched {
                let u = u as usize;
                if !locked[u] {
                    stamp[u] += 1;
                    heap.push((self.gain(u), stamp[u], u as u32));
                }
            }
            // early stop: long negative tail
            if moves.len() > best_len + 200 {
                break;
            }
        }

        // rollback moves after the best prefix
        for &vu in moves[best_len..].iter().rev() {
            self.apply(vu as usize);
        }
        debug_assert_eq!(self.cutsize() as i64, start_cut - best_gain);
        best_gain.max(0) as u64
    }

    /// Run FM passes until no improvement (or `max_passes`).
    pub fn refine(&mut self, maxw: [u64; 2], max_passes: usize) -> u64 {
        let mut total = 0u64;
        for _ in 0..max_passes {
            // zero cut cannot improve; skip the O(nv log nv) pass entirely
            // (frequent on butterfly layers whose stages split perfectly)
            if self.cutsize() == 0 {
                break;
            }
            let imp = self.fm_pass(maxw);
            total += imp;
            if imp == 0 {
                break;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn two_clusters() -> Hypergraph {
        // vertices 0-3 densely tied, 4-7 densely tied, one bridge net.
        let nets = vec![
            vec![0, 1],
            vec![1, 2],
            vec![2, 3],
            vec![0, 3],
            vec![0, 2],
            vec![4, 5],
            vec![5, 6],
            vec![6, 7],
            vec![4, 7],
            vec![5, 7],
            vec![3, 4], // bridge
        ];
        let nnets = nets.len();
        Hypergraph::new(8, nets, vec![1; 8], vec![1; nnets])
    }

    #[test]
    fn fm_finds_natural_cut() {
        let hg = two_clusters();
        // bad start: interleaved sides
        let side: Vec<u8> = (0..8).map(|v| (v % 2) as u8).collect();
        let mut b = Bisection::new(&hg, side);
        let before = b.cutsize();
        b.refine([5, 5], 8);
        let after = b.cutsize();
        assert!(after <= before);
        assert_eq!(after, 1, "optimal cut is the single bridge net");
        // clusters ended up together
        assert_eq!(b.side[0], b.side[1]);
        assert_eq!(b.side[0], b.side[2]);
        assert_eq!(b.side[4], b.side[5]);
        assert_ne!(b.side[0], b.side[4]);
    }

    #[test]
    fn fixed_vertices_never_move() {
        let mut hg = two_clusters();
        hg.fix(0, 1); // pin vertex 0 to side 1 even though cluster prefers 0
        let mut side: Vec<u8> = vec![0; 8];
        side[0] = 1;
        for v in 4..8 {
            side[v] = 1;
        }
        let mut b = Bisection::new(&hg, side);
        b.refine([8, 8], 8);
        assert_eq!(b.side[0], 1, "fixed vertex moved");
    }

    #[test]
    fn balance_cap_respected() {
        prop::check(|rng| {
            let nv = 6 + rng.gen_range(20);
            let mut nets = Vec::new();
            for _ in 0..nv * 2 {
                let k = 2 + rng.gen_range(3);
                nets.push(rng.sample_distinct(nv, k.min(nv)));
            }
            let nnets = nets.len();
            let vwgt: Vec<u32> = (0..nv).map(|_| 1 + rng.gen_range(4) as u32).collect();
            let hg = Hypergraph::new(nv, nets, vwgt, vec![1; nnets]);
            let side: Vec<u8> = (0..nv).map(|_| rng.gen_range(2) as u8).collect();
            let total = hg.total_vwgt();
            let cap = [(total * 3) / 5 + 1, (total * 3) / 5 + 1];
            let mut b = Bisection::new(&hg, side);
            b.refine(cap, 6);
            assert!(b.weight[0] <= cap[0] || b.weight[1] <= cap[1]);
            // weights always consistent with sides
            let w0: u64 = (0..nv)
                .filter(|&v| b.side[v] == 0)
                .map(|v| hg.vwgt[v] as u64)
                .sum();
            assert_eq!(w0, b.weight[0]);
        });
    }

    #[test]
    fn refine_never_worsens_cut() {
        prop::check(|rng| {
            let nv = 4 + rng.gen_range(30);
            let mut nets = Vec::new();
            for _ in 0..nv {
                let k = 2 + rng.gen_range(4);
                nets.push(rng.sample_distinct(nv, k.min(nv)));
            }
            let nnets = nets.len();
            let hg = Hypergraph::new(nv, nets, vec![1; nv], vec![2; nnets]);
            let side: Vec<u8> = (0..nv).map(|_| rng.gen_range(2) as u8).collect();
            let mut b = Bisection::new(&hg, side);
            let before = b.cutsize();
            b.refine([nv as u64, nv as u64], 4);
            assert!(b.cutsize() <= before);
        });
    }
}
