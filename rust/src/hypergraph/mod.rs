//! Hypergraph partitioning substrate (the paper's PaToH dependency,
//! reimplemented): model + multilevel recursive-bisection partitioner with
//! fixed-vertex support.

pub mod coarsen;
pub mod fm;
pub mod model;
pub mod partitioner;

pub use model::{Hypergraph, FREE};
pub use partitioner::{partition, PartitionConfig};
