//! Multilevel recursive-bisection P-way partitioner with fixed vertices —
//! the crate's PaToH stand-in.
//!
//! Pipeline per bisection: coarsen (heavy-connectivity matching) →
//! greedy-growth initial bisection → FM refinement, projected back up the
//! levels with boundary refinement. P-way via recursive bisection with net
//! splitting, so the sum of bisection cuts equals the connectivity-1
//! cutsize (Eq. 1) of the final P-way partition.

use super::coarsen::{coarsen, CoarseLevel};
use super::fm::Bisection;
use super::model::{Hypergraph, FREE};
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Nanosecond profile counters (read via [`profile_snapshot`]).
pub static T_COARSEN: AtomicU64 = AtomicU64::new(0);
pub static T_REFINE: AtomicU64 = AtomicU64::new(0);
pub static T_EXTRACT: AtomicU64 = AtomicU64::new(0);

/// (coarsen, refine, extract) seconds accumulated so far.
pub fn profile_snapshot() -> (f64, f64, f64) {
    (
        T_COARSEN.load(Ordering::Relaxed) as f64 / 1e9,
        T_REFINE.load(Ordering::Relaxed) as f64 / 1e9,
        T_EXTRACT.load(Ordering::Relaxed) as f64 / 1e9,
    )
}

#[inline]
fn timed<T>(acc: &AtomicU64, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    acc.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    out
}

/// Partitioner knobs.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    pub nparts: usize,
    /// Allowed imbalance ε (Eq. 2): max part weight ≤ avg·(1+ε).
    pub epsilon: f64,
    pub seed: u64,
    /// Stop coarsening below this many vertices.
    pub coarsen_to: usize,
    /// FM passes per level.
    pub fm_passes: usize,
    /// Random restarts of the initial bisection.
    pub initial_tries: usize,
    /// Optional per-part target weight fractions (heterogeneous systems,
    /// paper §5.1: "enforcing different target part weights to distribute
    /// different sized computational loads"). Must have `nparts` entries
    /// summing to ~1.0; `None` = uniform.
    pub target_weights: Option<Vec<f64>>,
}

impl PartitionConfig {
    pub fn new(nparts: usize) -> Self {
        let envu = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Self {
            nparts,
            epsilon: 0.01,
            seed: 0x9A27,
            // env overrides support perf tuning (EXPERIMENTS.md §Perf)
            coarsen_to: envu("SPDNN_COARSEN_TO", 160),
            fm_passes: envu("SPDNN_FM_PASSES", 4),
            initial_tries: envu("SPDNN_INIT_TRIES", 6),
            target_weights: None,
        }
    }

    /// Heterogeneous variant with explicit per-part weight fractions.
    pub fn with_targets(nparts: usize, targets: Vec<f64>) -> Self {
        assert_eq!(targets.len(), nparts);
        let sum: f64 = targets.iter().sum();
        assert!(sum > 0.0);
        let mut cfg = Self::new(nparts);
        cfg.target_weights = Some(targets.iter().map(|t| t / sum).collect());
        cfg
    }

    /// Target fraction of part p (uniform if unset).
    fn target_of(&self, p: usize) -> f64 {
        match &self.target_weights {
            Some(t) => t[p],
            None => 1.0 / self.nparts as f64,
        }
    }
}

/// Partition `hg` into `cfg.nparts` parts honoring fixed vertices.
/// Returns the part id per vertex.
pub fn partition(hg: &Hypergraph, cfg: &PartitionConfig) -> Vec<u32> {
    assert!(cfg.nparts >= 1);
    let mut parts = vec![0u32; hg.nv];
    if cfg.nparts == 1 {
        return parts;
    }
    let mut rng = Rng::new(cfg.seed);
    // Per-bisection ε: distribute the total allowance over ~log2(P) levels.
    let levels = (cfg.nparts as f64).log2().ceil().max(1.0);
    let eps_level = ((1.0 + cfg.epsilon).powf(1.0 / levels) - 1.0).max(0.002);
    // rb consumes its hypergraph (children are owned sub-hypergraphs), so
    // only this single top-level clone is ever made.
    rb(hg.clone(), 0, cfg.nparts as u32, cfg, eps_level, &mut rng, &mut parts);
    parts
}

/// Recursive bisection of `hg` (consumed) into parts [base, base+k).
fn rb(
    mut hg: Hypergraph,
    base: u32,
    k: u32,
    cfg: &PartitionConfig,
    eps: f64,
    rng: &mut Rng,
    out: &mut [u32],
) {
    if k == 1 {
        for v in 0..hg.nv {
            out[v] = base;
        }
        return;
    }
    let kl = k / 2 + k % 2; // left gets the extra part
    let kr = k / 2;
    // split ratio = share of the target weight assigned to the left parts
    let left_target: f64 = (base..base + kl).map(|p| cfg.target_of(p as usize)).sum();
    let all_target: f64 = (base..base + k).map(|p| cfg.target_of(p as usize)).sum();
    let ratio = (left_target / all_target).clamp(0.05, 0.95);

    // Map fixed parts to sides for this split — rewritten in place (we own
    // hg), remembering the original ids for the children.
    let side_of_part = |p: i32| -> i32 {
        if p < base as i32 || p >= (base + k) as i32 {
            FREE // shouldn't happen; treat as free
        } else if (p as u32) < base + kl {
            0
        } else {
            1
        }
    };
    let orig_fixed: Vec<(u32, i32)> = hg
        .fixed
        .iter()
        .enumerate()
        .filter(|(_, &f)| f != FREE)
        .map(|(v, &f)| (v as u32, f))
        .collect();
    for v in 0..hg.nv {
        if hg.fixed[v] != FREE {
            hg.fixed[v] = side_of_part(hg.fixed[v]);
        }
    }

    let side = multilevel_bisect(&hg, ratio, eps, cfg, rng);

    // Split into two sub-hypergraphs with net splitting.
    let (mut lhg, lmap) = timed(&T_EXTRACT, || extract_side(&hg, &side, 0));
    let (mut rhg, rmap) = timed(&T_EXTRACT, || extract_side(&hg, &side, 1));
    drop(hg); // free the parent before recursing

    // restore fixed part ids in children (they were converted to sides)
    for &(vu, f) in &orig_fixed {
        let v = vu as usize;
        if side[v] == 0 {
            lhg.fixed[lmap[v] as usize] = f;
        } else {
            rhg.fixed[rmap[v] as usize] = f;
        }
    }

    let nl = lhg.nv;
    let nr = rhg.nv;
    let mut lout = vec![0u32; nl];
    let mut rout = vec![0u32; nr];
    rb(lhg, base, kl, cfg, eps, rng, &mut lout);
    rb(rhg, base + kl, kr, cfg, eps, rng, &mut rout);
    for (v, &sd) in side.iter().enumerate() {
        out[v] = if sd == 0 {
            lout[lmap[v] as usize]
        } else {
            rout[rmap[v] as usize]
        };
    }
}

/// Extract the sub-hypergraph induced by `side == s` (net splitting:
/// keep per-net pins on this side, drop nets with < 2 remaining pins).
/// Returns (sub, fine→sub vertex map; u32::MAX for the other side).
fn extract_side(hg: &Hypergraph, side: &[u8], s: u8) -> (Hypergraph, Vec<u32>) {
    let mut map = vec![u32::MAX; hg.nv];
    let mut next = 0u32;
    let mut vwgt = Vec::new();
    for v in 0..hg.nv {
        if side[v] == s {
            map[v] = next;
            vwgt.push(hg.vwgt[v]);
            next += 1;
        }
    }
    let mut nets = Vec::new();
    let mut ncost = Vec::new();
    let mut buf = Vec::new();
    for n in 0..hg.num_nets() {
        buf.clear();
        for &p in hg.net_pins(n) {
            if side[p as usize] == s {
                buf.push(map[p as usize]);
            }
        }
        if buf.len() >= 2 {
            nets.push(buf.clone());
            ncost.push(hg.ncost[n]);
        }
    }
    let sub = Hypergraph::new(next as usize, nets, vwgt, ncost);
    (sub, map)
}

/// Multilevel 2-way: coarsen, initial, uncoarsen+refine.
/// `ratio` = target fraction of weight on side 0.
fn multilevel_bisect(
    hg: &Hypergraph,
    ratio: f64,
    eps: f64,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Vec<u8> {
    // Coarsening chain (each level owns its coarse hypergraph; no copies)
    let mut levels: Vec<CoarseLevel> = Vec::new();
    loop {
        let next = {
            let cur: &Hypergraph = levels.last().map(|l| &l.coarse).unwrap_or(hg);
            if cur.nv <= cfg.coarsen_to {
                None
            } else {
                match timed(&T_COARSEN, || coarsen(cur, rng)) {
                    Some(lvl) if lvl.coarse.nv < (cur.nv * 95) / 100 => Some(lvl),
                    _ => None,
                }
            }
        };
        match next {
            Some(l) => levels.push(l),
            None => break,
        }
    }

    let coarsest: &Hypergraph = levels.last().map(|l| &l.coarse).unwrap_or(hg);

    // Initial bisection on the coarsest level
    let total = coarsest.total_vwgt();
    let target0 = (total as f64 * ratio) as u64;
    let maxw = caps(total, ratio, eps);
    let mut best_side: Option<Vec<u8>> = None;
    let mut best_cut = u64::MAX;
    for _ in 0..cfg.initial_tries {
        let side = grow_initial(coarsest, target0, rng);
        let mut b = Bisection::new(coarsest, side);
        b.refine(maxw, cfg.fm_passes);
        let cut = b.cutsize();
        if cut < best_cut && b.weight[0] <= maxw[0] && b.weight[1] <= maxw[1] {
            best_cut = cut;
            best_side = Some(b.side.clone());
        } else if best_side.is_none() {
            best_side = Some(b.side.clone());
            best_cut = cut;
        }
    }
    let mut side = best_side.unwrap();

    // Uncoarsen: project through levels in reverse, refining each
    for i in (0..levels.len()).rev() {
        let fine: &Hypergraph = if i == 0 { hg } else { &levels[i - 1].coarse };
        let mut fside = vec![0u8; fine.nv];
        for v in 0..fine.nv {
            fside[v] = side[levels[i].map[v] as usize];
        }
        let ftotal = fine.total_vwgt();
        let fmaxw = caps(ftotal, ratio, eps);
        timed(&T_REFINE, || {
            let mut b = Bisection::new(fine, fside);
            b.refine(fmaxw, cfg.fm_passes);
            side = b.side;
        });
    }
    side
}

fn caps(total: u64, ratio: f64, eps: f64) -> [u64; 2] {
    let t0 = total as f64 * ratio;
    let t1 = total as f64 * (1.0 - ratio);
    [
        (t0 * (1.0 + eps)).ceil() as u64 + 1,
        (t1 * (1.0 + eps)).ceil() as u64 + 1,
    ]
}

/// Greedy BFS growth: fixed side-0/1 vertices pre-placed; grow side 0 from a
/// random free seed (preferring net neighbors) until `target0` weight.
fn grow_initial(hg: &Hypergraph, target0: u64, rng: &mut Rng) -> Vec<u8> {
    let nv = hg.nv;
    let mut side = vec![1u8; nv];
    let mut w0 = 0u64;
    let mut in0 = vec![false; nv];
    let mut queue: std::collections::VecDeque<u32> = Default::default();

    // fixed placement first
    for v in 0..nv {
        if hg.fixed[v] == 0 {
            side[v] = 0;
            in0[v] = true;
            w0 += hg.vwgt[v] as u64;
            queue.push_back(v as u32);
        }
    }

    let order = rng.permutation(nv);
    let mut oi = 0usize;
    while w0 < target0 {
        let v = match queue.pop_front() {
            Some(v) => v as usize,
            None => {
                // new random seed among free side-1 vertices
                let mut found = None;
                while oi < order.len() {
                    let c = order[oi] as usize;
                    oi += 1;
                    if !in0[c] && hg.fixed[c] == FREE {
                        found = Some(c);
                        break;
                    }
                }
                match found {
                    Some(c) => {
                        in0[c] = true;
                        side[c] = 0;
                        w0 += hg.vwgt[c] as u64;
                        c
                    }
                    None => break, // everything placed
                }
            }
        };
        // expand neighbors of v
        for &n in hg.vertex_nets(v) {
            let pins = hg.net_pins(n as usize);
            if pins.len() > 64 {
                continue;
            }
            for &u in pins {
                let u = u as usize;
                if !in0[u] && hg.fixed[u] == FREE && w0 < target0 {
                    in0[u] = true;
                    side[u] = 0;
                    w0 += hg.vwgt[u] as u64;
                    queue.push_back(u as u32);
                }
            }
        }
    }
    side
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn random_hg(rng: &mut Rng, nv: usize, nnets: usize, maxpins: usize) -> Hypergraph {
        let mut nets = Vec::with_capacity(nnets);
        for _ in 0..nnets {
            let k = 2 + rng.gen_range(maxpins.saturating_sub(1).max(1));
            nets.push(rng.sample_distinct(nv, k.min(nv)));
        }
        let vwgt: Vec<u32> = (0..nv).map(|_| 1 + rng.gen_range(3) as u32).collect();
        Hypergraph::new(nv, nets, vwgt, vec![2; nnets])
    }

    #[test]
    fn partition_is_valid_and_balanced() {
        prop::check(|rng| {
            let nv = 40 + rng.gen_range(100);
            let hg = random_hg(rng, nv, nv * 2, 5);
            for &p in &[2usize, 3, 4, 7] {
                let mut cfg = PartitionConfig::new(p);
                cfg.epsilon = 0.10;
                cfg.seed = rng.next_u64();
                let parts = partition(&hg, &cfg);
                hg.check_partition(&parts, p).unwrap();
                let w = hg.part_weights(&parts, p);
                let avg = hg.total_vwgt() as f64 / p as f64;
                let maxw = w.iter().copied().max().unwrap() as f64;
                // generous slack: small instances can't always hit ε exactly
                assert!(
                    maxw <= avg * 1.6 + 4.0,
                    "P={p}: max part weight {maxw} vs avg {avg}"
                );
                // no empty parts for these sizes
                assert!(w.iter().all(|&x| x > 0), "P={p}: empty part: {w:?}");
            }
        });
    }

    #[test]
    fn respects_fixed_vertices() {
        prop::check(|rng| {
            let nv = 60;
            let mut hg = random_hg(rng, nv, 120, 4);
            let p = 4usize;
            // fix ~nv/4 vertices to random parts
            for _ in 0..nv / 4 {
                let v = rng.gen_range(nv);
                hg.fix(v, rng.gen_range(p) as u32);
            }
            let mut cfg = PartitionConfig::new(p);
            cfg.seed = rng.next_u64();
            cfg.epsilon = 0.2;
            let parts = partition(&hg, &cfg);
            hg.check_partition(&parts, p).unwrap();
        });
    }

    #[test]
    fn beats_random_on_clustered_instance() {
        // Build a hypergraph with 4 planted clusters; H-partition should
        // recover a far smaller cut than a random balanced assignment.
        let mut rng = Rng::new(99);
        let nv = 128;
        let mut nets = Vec::new();
        for c in 0..4 {
            let base = c * 32;
            for _ in 0..150 {
                let k = 2 + rng.gen_range(3);
                let mut pins: Vec<u32> = rng
                    .sample_distinct(32, k)
                    .into_iter()
                    .map(|v| v + base as u32)
                    .collect();
                pins.sort_unstable();
                nets.push(pins);
            }
        }
        // a few cross-cluster nets
        for _ in 0..20 {
            nets.push(rng.sample_distinct(nv, 3));
        }
        let nnets = nets.len();
        let hg = Hypergraph::new(nv, nets, vec![1; nv], vec![2; nnets]);
        let cfg = PartitionConfig::new(4);
        let parts = partition(&hg, &cfg);
        let hcut = hg.cutsize(&parts, 4);
        // random balanced baseline
        let mut rand_parts: Vec<u32> = (0..nv).map(|v| (v % 4) as u32).collect();
        rng.shuffle(&mut rand_parts);
        let rcut = hg.cutsize(&rand_parts, 4);
        assert!(
            (hcut as f64) < rcut as f64 * 0.35,
            "hcut {hcut} not ≪ random {rcut}"
        );
    }

    #[test]
    fn single_part_trivial() {
        let mut rng = Rng::new(1);
        let hg = random_hg(&mut rng, 20, 30, 4);
        let parts = partition(&hg, &PartitionConfig::new(1));
        assert!(parts.iter().all(|&p| p == 0));
    }

    #[test]
    fn rb_cut_equals_connectivity_cut() {
        // Internal consistency: the P-way cutsize computed by Eq. 1 matches
        // what recursive bisection optimized (sanity on net splitting).
        let mut rng = Rng::new(5);
        let hg = random_hg(&mut rng, 90, 200, 5);
        let cfg = PartitionConfig::new(8);
        let parts = partition(&hg, &cfg);
        let cut = hg.cutsize(&parts, 8);
        // cut is bounded by total net cost * (P-1)
        let bound: u64 = hg.ncost.iter().map(|&c| c as u64).sum::<u64>() * 7;
        assert!(cut <= bound);
        hg.check_partition(&parts, 8).unwrap();
    }

    #[test]
    fn deterministic_for_seed() {
        let mut rng = Rng::new(8);
        let hg = random_hg(&mut rng, 70, 140, 4);
        let cfg = PartitionConfig::new(4);
        let a = partition(&hg, &cfg);
        let b = partition(&hg, &cfg);
        assert_eq!(a, b);
    }
}

#[cfg(test)]
mod hetero_tests {
    use super::*;

    #[test]
    fn heterogeneous_targets_shape_part_weights() {
        let mut rng = Rng::new(13);
        // dense-ish random hypergraph, unit weights
        let nv = 200;
        let mut nets = Vec::new();
        for _ in 0..400 {
            let k = 2 + rng.gen_range(3);
            nets.push(rng.sample_distinct(nv, k));
        }
        let nnets = nets.len();
        let hg = Hypergraph::new(nv, nets, vec![1; nv], vec![1; nnets]);
        let cfg = PartitionConfig::with_targets(2, vec![3.0, 1.0]); // 75/25
        let parts = partition(&hg, &cfg);
        let w = hg.part_weights(&parts, 2);
        let frac0 = w[0] as f64 / (w[0] + w[1]) as f64;
        assert!(
            (0.65..0.85).contains(&frac0),
            "part-0 fraction {frac0}, weights {w:?}"
        );
    }

    #[test]
    fn heterogeneous_four_way() {
        let mut rng = Rng::new(14);
        let nv = 240;
        let mut nets = Vec::new();
        for _ in 0..480 {
            nets.push(rng.sample_distinct(nv, 3));
        }
        let nnets = nets.len();
        let hg = Hypergraph::new(nv, nets, vec![1; nv], vec![1; nnets]);
        let targets = vec![4.0, 2.0, 1.0, 1.0];
        let cfg = PartitionConfig::with_targets(4, targets.clone());
        let parts = partition(&hg, &cfg);
        hg.check_partition(&parts, 4).unwrap();
        let w = hg.part_weights(&parts, 4);
        let total: u64 = w.iter().sum();
        let sum_t: f64 = targets.iter().sum();
        for p in 0..4 {
            let frac = w[p] as f64 / total as f64;
            let want = targets[p] / sum_t;
            assert!(
                (frac - want).abs() < 0.12,
                "part {p}: fraction {frac} vs target {want} ({w:?})"
            );
        }
    }

    #[test]
    fn uniform_targets_equal_default() {
        let mut rng = Rng::new(15);
        let nv = 100;
        let mut nets = Vec::new();
        for _ in 0..150 {
            nets.push(rng.sample_distinct(nv, 3));
        }
        let nnets = nets.len();
        let hg = Hypergraph::new(nv, nets, vec![1; nv], vec![1; nnets]);
        let a = partition(&hg, &PartitionConfig::new(4));
        let b = partition(
            &hg,
            &PartitionConfig::with_targets(4, vec![1.0, 1.0, 1.0, 1.0]),
        );
        assert_eq!(a, b);
    }
}
