//! Hypergraph data structure (Section 3.1 of the paper).
//!
//! `H = (V, N)`: vertices carry weights, nets carry costs and connect pin
//! sets. Vertices may be *fixed* to a part before partitioning — the
//! mechanism the paper's multi-phase model uses to encode the dependency on
//! the previous layer's partition (Section 5).

/// Sentinel for "not fixed".
pub const FREE: i32 = -1;

/// Immutable hypergraph in CSR-like storage (nets→pins and vertex→nets).
#[derive(Debug, Clone)]
pub struct Hypergraph {
    /// Number of vertices.
    pub nv: usize,
    /// net -> pins
    pub net_ptr: Vec<u32>,
    pub pins: Vec<u32>,
    /// vertex -> incident nets (derived)
    pub v_ptr: Vec<u32>,
    pub v_nets: Vec<u32>,
    /// Vertex weights (computational load; Section 5 uses nnz of the row).
    pub vwgt: Vec<u32>,
    /// Net costs (the paper uses a uniform cost of 2).
    pub ncost: Vec<u32>,
    /// Fixed part per vertex or `FREE`.
    pub fixed: Vec<i32>,
}

impl Hypergraph {
    /// Build from explicit pin lists. Single-pin and empty nets are allowed
    /// (they can never be cut; kept so net ids remain meaningful).
    pub fn new(nv: usize, nets: Vec<Vec<u32>>, vwgt: Vec<u32>, ncost: Vec<u32>) -> Self {
        assert_eq!(vwgt.len(), nv);
        assert_eq!(ncost.len(), nets.len());
        let mut net_ptr = Vec::with_capacity(nets.len() + 1);
        net_ptr.push(0u32);
        let total_pins: usize = nets.iter().map(|n| n.len()).sum();
        let mut pins = Vec::with_capacity(total_pins);
        for n in &nets {
            for &p in n {
                debug_assert!((p as usize) < nv, "pin out of range");
                pins.push(p);
            }
            net_ptr.push(pins.len() as u32);
        }
        let mut hg = Self {
            nv,
            net_ptr,
            pins,
            v_ptr: Vec::new(),
            v_nets: Vec::new(),
            vwgt,
            ncost,
            fixed: vec![FREE; nv],
        };
        hg.build_vertex_index();
        hg
    }

    /// (Re)build the vertex→nets index from nets→pins.
    pub fn build_vertex_index(&mut self) {
        let mut counts = vec![0u32; self.nv + 1];
        for &p in &self.pins {
            counts[p as usize + 1] += 1;
        }
        for i in 0..self.nv {
            counts[i + 1] += counts[i];
        }
        self.v_ptr = counts.clone();
        let mut cursor = counts;
        let mut v_nets = vec![0u32; self.pins.len()];
        for n in 0..self.num_nets() {
            for i in self.net_ptr[n] as usize..self.net_ptr[n + 1] as usize {
                let v = self.pins[i] as usize;
                v_nets[cursor[v] as usize] = n as u32;
                cursor[v] += 1;
            }
        }
        self.v_nets = v_nets;
    }

    pub fn num_nets(&self) -> usize {
        self.net_ptr.len() - 1
    }

    pub fn num_pins(&self) -> usize {
        self.pins.len()
    }

    #[inline]
    pub fn net_pins(&self, n: usize) -> &[u32] {
        &self.pins[self.net_ptr[n] as usize..self.net_ptr[n + 1] as usize]
    }

    #[inline]
    pub fn vertex_nets(&self, v: usize) -> &[u32] {
        &self.v_nets[self.v_ptr[v] as usize..self.v_ptr[v + 1] as usize]
    }

    pub fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().map(|&w| w as u64).sum()
    }

    /// Mark vertex fixed to `part`.
    pub fn fix(&mut self, v: usize, part: u32) {
        self.fixed[v] = part as i32;
    }

    /// Connectivity-1 cutsize (Eq. 1): Σ_n cost(n) · (λ(n) − 1), plus the
    /// per-net connectivity vector if requested.
    pub fn cutsize(&self, parts: &[u32], nparts: usize) -> u64 {
        assert_eq!(parts.len(), self.nv);
        let mut mark = vec![u32::MAX; nparts];
        let mut cut = 0u64;
        for n in 0..self.num_nets() {
            let mut lambda = 0u32;
            for &p in self.net_pins(n) {
                let pt = parts[p as usize] as usize;
                if mark[pt] != n as u32 {
                    mark[pt] = n as u32;
                    lambda += 1;
                }
            }
            if lambda > 1 {
                cut += self.ncost[n] as u64 * (lambda as u64 - 1);
            }
        }
        cut
    }

    /// λ(n) for each net under `parts`.
    pub fn connectivities(&self, parts: &[u32], nparts: usize) -> Vec<u32> {
        let mut mark = vec![u32::MAX; nparts];
        (0..self.num_nets())
            .map(|n| {
                let mut lambda = 0u32;
                for &p in self.net_pins(n) {
                    let pt = parts[p as usize] as usize;
                    if mark[pt] != n as u32 {
                        mark[pt] = n as u32;
                        lambda += 1;
                    }
                }
                lambda
            })
            .collect()
    }

    /// Part weights under `parts`.
    pub fn part_weights(&self, parts: &[u32], nparts: usize) -> Vec<u64> {
        let mut w = vec![0u64; nparts];
        for v in 0..self.nv {
            w[parts[v] as usize] += self.vwgt[v] as u64;
        }
        w
    }

    /// Check a partition: every fixed vertex on its part, all part ids valid.
    pub fn check_partition(&self, parts: &[u32], nparts: usize) -> Result<(), String> {
        if parts.len() != self.nv {
            return Err("length mismatch".into());
        }
        for v in 0..self.nv {
            if parts[v] as usize >= nparts {
                return Err(format!("vertex {v} part {} out of range", parts[v]));
            }
            if self.fixed[v] != FREE && parts[v] != self.fixed[v] as u32 {
                return Err(format!(
                    "fixed vertex {v} on part {} (wanted {})",
                    parts[v], self.fixed[v]
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The toy hypergraph from Fig. 3 of the paper would do; this is smaller.
    fn tiny() -> Hypergraph {
        // 4 vertices; nets: {0,1}, {1,2,3}, {3}
        Hypergraph::new(
            4,
            vec![vec![0, 1], vec![1, 2, 3], vec![3]],
            vec![1, 2, 3, 4],
            vec![2, 2, 2],
        )
    }

    #[test]
    fn indices_consistent() {
        let hg = tiny();
        assert_eq!(hg.num_nets(), 3);
        assert_eq!(hg.num_pins(), 6);
        assert_eq!(hg.net_pins(1), &[1, 2, 3]);
        assert_eq!(hg.vertex_nets(1), &[0, 1]);
        assert_eq!(hg.vertex_nets(3), &[1, 2]);
        assert_eq!(hg.total_vwgt(), 10);
    }

    #[test]
    fn cutsize_connectivity_minus_one() {
        let hg = tiny();
        // all same part: cut 0
        assert_eq!(hg.cutsize(&[0, 0, 0, 0], 2), 0);
        // {0,1} vs {2,3}: net0 uncut, net1 λ=2 → cost 2, net2 uncut → 2
        assert_eq!(hg.cutsize(&[0, 0, 1, 1], 2), 2);
        // each vertex its own part: net0 λ=2 → 2; net1 λ=3 → 4; net2 λ=1 → 0
        assert_eq!(hg.cutsize(&[0, 1, 2, 3], 4), 6);
    }

    #[test]
    fn connectivities_vector() {
        let hg = tiny();
        assert_eq!(hg.connectivities(&[0, 1, 1, 0], 2), vec![2, 2, 1]);
    }

    #[test]
    fn part_weights_sum() {
        let hg = tiny();
        let w = hg.part_weights(&[0, 1, 0, 1], 2);
        assert_eq!(w, vec![4, 6]);
    }

    #[test]
    fn check_partition_honors_fixed() {
        let mut hg = tiny();
        hg.fix(2, 1);
        assert!(hg.check_partition(&[0, 0, 1, 0], 2).is_ok());
        assert!(hg.check_partition(&[0, 0, 0, 0], 2).is_err());
        assert!(hg.check_partition(&[0, 0, 1, 7], 2).is_err());
    }
}
