//! Multilevel coarsening: heavy-connectivity matching.
//!
//! Free vertices are pairwise matched with the neighbor they share the most
//! net connectivity with (score Σ cost(n)/(|pins(n)|−1), the standard
//! heavy-connectivity heuristic). Fixed vertices are never matched — in the
//! paper's phase hypergraphs they are degree-1, weight-0 markers and
//! coarsening them would only blur the fixed-side information. Identical
//! coarse nets are merged (their costs add), which matters a lot on
//! butterfly-structured layers where many columns share pin sets.

use super::model::{Hypergraph, FREE};
use crate::util::Rng;

/// One coarsening level: the coarse hypergraph plus the fine→coarse map.
pub struct CoarseLevel {
    pub coarse: Hypergraph,
    /// fine vertex -> coarse vertex
    pub map: Vec<u32>,
}

/// Nets larger than this are skipped during matching (they carry almost no
/// locality signal and make matching quadratic).
const MATCH_NET_LIMIT: usize = 64;

/// Compute a heavy-connectivity matching and build the coarse hypergraph.
/// Returns `None` if coarsening made no progress (coarse nv == fine nv).
pub fn coarsen(hg: &Hypergraph, rng: &mut Rng) -> Option<CoarseLevel> {
    let nv = hg.nv;
    let mut mate: Vec<u32> = vec![u32::MAX; nv];
    let order = rng.permutation(nv);
    // scratch: score accumulation per candidate
    let mut score: Vec<f32> = vec![0.0; nv];
    let mut touched: Vec<u32> = Vec::with_capacity(64);

    for &vu in &order {
        let v = vu as usize;
        if mate[v] != u32::MAX || hg.fixed[v] != FREE {
            continue;
        }
        touched.clear();
        for &n in hg.vertex_nets(v) {
            let pins = hg.net_pins(n as usize);
            if pins.len() > MATCH_NET_LIMIT || pins.len() < 2 {
                continue;
            }
            let w = hg.ncost[n as usize] as f32 / (pins.len() as f32 - 1.0);
            for &u in pins {
                let u = u as usize;
                if u == v || mate[u] != u32::MAX || hg.fixed[u] != FREE {
                    continue;
                }
                if score[u] == 0.0 {
                    touched.push(u as u32);
                }
                score[u] += w;
            }
        }
        // pick best candidate
        let mut best = u32::MAX;
        let mut best_score = 0.0f32;
        for &u in &touched {
            let s = score[u as usize];
            if s > best_score {
                best_score = s;
                best = u;
            }
            score[u as usize] = 0.0;
        }
        if best != u32::MAX {
            mate[v] = best;
            mate[best as usize] = v as u32;
        }
    }

    // assign coarse ids
    let mut map = vec![u32::MAX; nv];
    let mut next = 0u32;
    for v in 0..nv {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = next;
        let m = mate[v];
        if m != u32::MAX && map[m as usize] == u32::MAX {
            map[m as usize] = next;
        }
        next += 1;
    }
    let cnv = next as usize;
    if cnv == nv {
        return None;
    }

    // coarse vertex weights and fixed flags
    let mut vwgt = vec![0u32; cnv];
    let mut fixed = vec![FREE; cnv];
    for v in 0..nv {
        let c = map[v] as usize;
        vwgt[c] = vwgt[c].saturating_add(hg.vwgt[v]);
        if hg.fixed[v] != FREE {
            fixed[c] = hg.fixed[v];
        }
    }

    // coarse nets: project pins, dedup within net, drop <2-pin nets,
    // merge identical nets summing costs. The merge map is keyed by a
    // 64-bit hash of the pin list with bucket chaining into `nets` itself,
    // so unique nets are stored once (no duplicate Vec keys) and duplicate
    // detection allocates nothing.
    use std::collections::HashMap;
    let mut net_map: HashMap<u64, Vec<u32>> = HashMap::new(); // hash -> net ids
    let mut nets: Vec<Vec<u32>> = Vec::new();
    let mut ncost: Vec<u32> = Vec::new();
    let mut buf: Vec<u32> = Vec::with_capacity(64);
    for n in 0..hg.num_nets() {
        buf.clear();
        buf.extend(hg.net_pins(n).iter().map(|&p| map[p as usize]));
        buf.sort_unstable();
        buf.dedup();
        if buf.len() < 2 {
            continue;
        }
        // FNV-1a over the pin words
        let mut h = 0xcbf29ce484222325u64;
        for &p in &buf {
            h = (h ^ p as u64).wrapping_mul(0x100000001b3);
        }
        let bucket = net_map.entry(h).or_default();
        if let Some(&id) = bucket
            .iter()
            .find(|&&id| nets[id as usize] == buf)
        {
            ncost[id as usize] += hg.ncost[n];
        } else {
            bucket.push(nets.len() as u32);
            nets.push(std::mem::take(&mut buf));
            ncost.push(hg.ncost[n]);
            buf = Vec::with_capacity(64);
        }
    }

    let mut coarse = Hypergraph::new(cnv, nets, vwgt, ncost);
    coarse.fixed = fixed;
    Some(CoarseLevel { coarse, map })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> Hypergraph {
        // path hypergraph: nets {i, i+1}
        let nets = (0..n - 1).map(|i| vec![i as u32, i as u32 + 1]).collect();
        Hypergraph::new(n, nets, vec![1; n], vec![1; n - 1])
    }

    #[test]
    fn coarsening_shrinks() {
        let hg = chain(32);
        let mut rng = Rng::new(1);
        let lvl = coarsen(&hg, &mut rng).expect("should coarsen");
        assert!(lvl.coarse.nv < 32);
        assert!(lvl.coarse.nv >= 16);
        // total weight preserved
        assert_eq!(lvl.coarse.total_vwgt(), hg.total_vwgt());
    }

    #[test]
    fn fixed_vertices_stay_singleton_and_fixed() {
        let mut hg = chain(16);
        hg.fix(0, 0);
        hg.fix(15, 1);
        let mut rng = Rng::new(2);
        let lvl = coarsen(&hg, &mut rng).unwrap();
        let c0 = lvl.map[0] as usize;
        let c15 = lvl.map[15] as usize;
        assert_eq!(lvl.coarse.fixed[c0], 0);
        assert_eq!(lvl.coarse.fixed[c15], 1);
        // singleton: no other fine vertex maps there
        for v in 1..15 {
            assert_ne!(lvl.map[v] as usize, c0);
            assert_ne!(lvl.map[v] as usize, c15);
        }
    }

    #[test]
    fn identical_nets_merge_costs() {
        // two identical nets {0,1} with costs 2 and 3; after coarsening of a
        // larger structure they must merge if both pins stay distinct.
        let hg = Hypergraph::new(
            4,
            vec![vec![0, 1], vec![0, 1], vec![2, 3]],
            vec![1; 4],
            vec![2, 3, 1],
        );
        let mut rng = Rng::new(3);
        if let Some(lvl) = coarsen(&hg, &mut rng) {
            // if 0,1 merged the nets vanish; if not, they merged into one net
            let c0 = lvl.map[0];
            let c1 = lvl.map[1];
            if c0 != c1 {
                let mut found = false;
                for n in 0..lvl.coarse.num_nets() {
                    let mut p = lvl.coarse.net_pins(n).to_vec();
                    p.sort_unstable();
                    let mut q = vec![c0, c1];
                    q.sort_unstable();
                    if p == q {
                        assert_eq!(lvl.coarse.ncost[n], 5);
                        found = true;
                    }
                }
                assert!(found);
            }
        }
    }

    #[test]
    fn cutsize_preserved_under_projection() {
        // any coarse partition, projected to fine, has the same cutsize
        // (coarse cut counts merged nets with summed costs)
        crate::util::prop::check(|rng| {
            let n = 8 + rng.gen_range(24);
            let mut nets = Vec::new();
            for _ in 0..n {
                let k = 2 + rng.gen_range(3);
                nets.push(rng.sample_distinct(n, k.min(n)));
            }
            let nnets = nets.len();
            let hg = Hypergraph::new(n, nets, vec![1; n], vec![2; nnets]);
            if let Some(lvl) = coarsen(&hg, rng) {
                let cparts: Vec<u32> = (0..lvl.coarse.nv)
                    .map(|_| rng.gen_range(2) as u32)
                    .collect();
                let fparts: Vec<u32> = (0..n).map(|v| cparts[lvl.map[v] as usize]).collect();
                // fine cut == coarse cut: vertices merged together can never
                // separate, dropped nets are internal (never cut), merged
                // identical nets carry summed costs.
                assert_eq!(
                    hg.cutsize(&fparts, 2),
                    lvl.coarse.cutsize(&cparts, 2),
                    "projection changed cutsize"
                );
            }
        });
    }
}
