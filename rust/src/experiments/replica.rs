//! `spdnn replica` — scaling harness for the replica-group training
//! subsystem ([`crate::replica`]): the bundled digits SGD workload pushed
//! through `R ∈ groups` data-parallel replica groups of `ranks`
//! model-parallel ranks each, per engine and per cross-group gradient
//! codec. The dataset is fixed, so the sweep is a strong-scaling run at
//! constant per-group batch (the weak per-group load): `R` groups consume
//! `R` batches per step, ideally dividing wall time by `R`.
//!
//! Per (R, engine, codec) row: wall seconds, samples/s, tail loss, and
//! the intra-/inter-group wire bytes actually shipped. The CI bench-smoke
//! job runs this with `SPDNN_SECTION=replica SPDNN_ENFORCE=1`, turning
//! the acceptance bars into hard failures ([`enforce`]):
//!
//! - every row reports nonzero throughput, and `R = 1` rows ship zero
//!   inter-group bytes (the degenerate ring is message-free);
//! - the int8+EF gradient exchange ships ≤ [`REPLICA_BYTE_BAR`] of the
//!   f32 exchange's inter-group bytes at equal R;
//! - the int8+EF digits SGD tail loss stays within [`REPLICA_LOSS_BAR`]
//!   of the f32 run's (error feedback makes compression ~free);
//! - `R = 2` sustains ≥ [`REPLICA_SPEEDUP_BAR`]× the one-group
//!   samples/s — enforced only when the host exposes at least
//!   `2 × ranks` hardware threads, since the bar is meaningless when the
//!   extra group has no core to run on.
//!
//! The report is written as `BENCH_replica.json` (schema in
//! `docs/BENCHMARKS.md`; topology and residual contract in
//! `docs/TRAINING.md`).

use super::Table;
use crate::comm::Codec;
use crate::coordinator::ExecMode;
use crate::partition::{contiguous_partition, CommPlan};
use crate::radixnet::{generate, RadixNetConfig};
use crate::replica::{train_replicas_with_plan, ReplicaConfig};
use crate::runtime::parallel::FaultScope;
use crate::util::Stopwatch;

/// `R = 2` must sustain at least this multiple of the one-group
/// samples/s (enforced only with ≥ `2 × ranks` hardware threads).
pub const REPLICA_SPEEDUP_BAR: f64 = 1.5;
/// int8+EF inter-group bytes ≤ this fraction of the f32 exchange.
pub const REPLICA_BYTE_BAR: f64 = 0.35;
/// |int8 tail loss − f32 tail loss| / f32 tail loss ≤ this.
pub const REPLICA_LOSS_BAR: f64 = 0.01;

/// Workload shape and sweep axes of one `spdnn replica` run.
#[derive(Debug, Clone)]
pub struct ReplicaBenchConfig {
    pub neurons: usize,
    pub layers: usize,
    /// Model-parallel ranks per group.
    pub ranks: usize,
    /// Minibatch size per group per step.
    pub batch: usize,
    pub epochs: usize,
    /// Dataset size (digit samples; `samples / batch` batches per epoch).
    pub samples: usize,
    pub eta: f32,
    pub seed: u64,
    /// Replica-group counts to sweep. The first entry is the scaling
    /// baseline; include 1 and 2 or the speedup bar reports 0.
    pub groups: Vec<usize>,
    /// Intra-group engines to sweep; the first is the bar reference.
    pub modes: Vec<ExecMode>,
    /// Cross-group gradient codecs; the first must be `Codec::F32` (the
    /// byte/loss bars compare the others against it).
    pub codecs: Vec<Codec>,
}

impl Default for ReplicaBenchConfig {
    fn default() -> Self {
        Self {
            neurons: 256,
            layers: 8,
            ranks: 2,
            batch: 4,
            epochs: 3,
            samples: 64,
            eta: 0.2,
            seed: 42,
            groups: vec![1, 2, 4],
            modes: vec![ExecMode::Overlap, ExecMode::pipelined()],
            codecs: vec![Codec::F32, Codec::int8()],
        }
    }
}

/// One (R, engine, codec) measurement.
#[derive(Debug, Clone)]
pub struct ReplicaRow {
    pub groups: usize,
    pub mode: &'static str,
    pub codec: Codec,
    /// Effective optimizer steps taken (each consumes `groups × batch`
    /// samples).
    pub steps: usize,
    pub secs: f64,
    pub samples_per_sec: f64,
    /// Mean loss over the final 10% of steps.
    pub final_loss: f64,
    /// Post-codec bytes shipped on the inter-group (all-reduce) fabrics,
    /// summed over every thread.
    pub inter_wire_bytes: u64,
    /// Same for the intra-group (model-parallel) fabrics.
    pub intra_wire_bytes: u64,
}

/// Full sweep result plus the derived bar quantities.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub neurons: usize,
    pub layers: usize,
    pub ranks: usize,
    pub batch: usize,
    pub epochs: usize,
    /// Hardware threads the host exposes (gates the speedup bar).
    pub threads: usize,
    pub rows: Vec<ReplicaRow>,
    /// samples/s of R=2 over R=1 (first mode, first codec); 0 when the
    /// sweep lacks either point.
    pub speedup2: f64,
    /// int8 / f32 inter-group bytes at R=2 (first mode); 0 when absent.
    pub int8_byte_ratio: f64,
    /// Relative int8-vs-f32 tail-loss delta at R=2 (first mode).
    pub int8_loss_delta: f64,
}

/// Run the sweep: one replica training run per (R, engine, codec).
pub fn run(cfg: &ReplicaBenchConfig) -> ReplicaReport {
    let side = (cfg.neurons as f64).sqrt() as usize;
    assert_eq!(side * side, cfg.neurons, "digits input needs a square neuron count");
    let net = generate(
        &RadixNetConfig::graph_challenge(cfg.neurons, cfg.layers)
            .unwrap_or_else(|| panic!("unsupported neuron count {}", cfg.neurons)),
    );
    let part = contiguous_partition(&net.layers, cfg.ranks);
    let plan = CommPlan::build(&net.layers, &part);
    let data = crate::data::synthetic_mnist(side, cfg.samples, cfg.seed);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..cfg.samples).map(|i| data.target(i, cfg.neurons)).collect();

    let mut rows = Vec::new();
    for &groups in &cfg.groups {
        for &mode in &cfg.modes {
            for &codec in &cfg.codecs {
                let rcfg = ReplicaConfig {
                    groups,
                    batch: cfg.batch,
                    eta: cfg.eta,
                    epochs: cfg.epochs,
                    mode,
                    codec,
                    scope: FaultScope::Off,
                };
                let sw = Stopwatch::start();
                let run = train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &rcfg);
                let secs = sw.elapsed_secs();
                let steps = run.losses.len();
                let tail = (steps / 10).max(1);
                let final_loss = run.losses[steps - tail..]
                    .iter()
                    .map(|&l| l as f64)
                    .sum::<f64>()
                    / tail as f64;
                let sum_wire = |fabrics: &Vec<Vec<crate::comm::FabricStats>>| -> u64 {
                    fabrics
                        .iter()
                        .flatten()
                        .map(|st| st.sent_wire_bytes)
                        .sum()
                };
                rows.push(ReplicaRow {
                    groups,
                    mode: mode.label(),
                    codec,
                    steps,
                    secs,
                    samples_per_sec: (steps * groups * cfg.batch) as f64 / secs.max(1e-12),
                    final_loss,
                    inter_wire_bytes: sum_wire(&run.inter),
                    intra_wire_bytes: sum_wire(&run.intra),
                });
            }
        }
    }

    let mode0 = cfg.modes.first().map(|m| m.label()).unwrap_or("overlap");
    let codec0 = cfg.codecs.first().copied().unwrap_or(Codec::F32);
    let find = |g: usize, c: Codec| -> Option<&ReplicaRow> {
        rows.iter().find(|r| r.groups == g && r.mode == mode0 && r.codec == c)
    };
    let speedup2 = match (find(1, codec0), find(2, codec0)) {
        (Some(r1), Some(r2)) => r2.samples_per_sec / r1.samples_per_sec,
        _ => 0.0,
    };
    let (int8_byte_ratio, int8_loss_delta) = match (find(2, Codec::F32), find(2, Codec::int8())) {
        (Some(f), Some(q)) => (
            q.inter_wire_bytes as f64 / f.inter_wire_bytes.max(1) as f64,
            if f.final_loss > 0.0 {
                (q.final_loss - f.final_loss) / f.final_loss
            } else {
                0.0
            },
        ),
        _ => (0.0, 0.0),
    };
    ReplicaReport {
        neurons: cfg.neurons,
        layers: cfg.layers,
        ranks: cfg.ranks,
        batch: cfg.batch,
        epochs: cfg.epochs,
        threads: std::thread::available_parallelism().map_or(1, |n| n.get()),
        rows,
        speedup2,
        int8_byte_ratio,
        int8_loss_delta,
    }
}

/// The enforced CI bars (`SPDNN_ENFORCE=1`). The speedup bar is skipped
/// (with a log line) when the host cannot physically run two groups in
/// parallel; the byte and loss bars are machine-independent and always
/// enforced when their rows exist.
pub fn enforce(rep: &ReplicaReport) {
    for r in &rep.rows {
        assert!(
            r.secs > 0.0 && r.samples_per_sec > 0.0,
            "replica bar: R={} {} {} reported no throughput",
            r.groups,
            r.mode,
            r.codec.label()
        );
        if r.groups == 1 {
            assert_eq!(
                r.inter_wire_bytes, 0,
                "replica bar: R=1 {} {} shipped inter-group bytes",
                r.mode,
                r.codec.label()
            );
        } else {
            assert!(
                r.inter_wire_bytes > 0,
                "replica bar: R={} {} {} shipped no gradients",
                r.groups,
                r.mode,
                r.codec.label()
            );
        }
    }
    if rep.int8_byte_ratio > 0.0 {
        assert!(
            rep.int8_byte_ratio <= REPLICA_BYTE_BAR,
            "replica bar: int8 shipped {:.3} of the f32 inter-group bytes, above {REPLICA_BYTE_BAR}",
            rep.int8_byte_ratio
        );
        assert!(
            rep.int8_loss_delta.abs() <= REPLICA_LOSS_BAR,
            "replica bar: int8+EF tail-loss delta {:+.4} outside ±{REPLICA_LOSS_BAR}",
            rep.int8_loss_delta
        );
    }
    if rep.speedup2 > 0.0 {
        if rep.threads >= 2 * rep.ranks {
            assert!(
                rep.speedup2 >= REPLICA_SPEEDUP_BAR,
                "replica bar: R=2 speedup {:.3}x below {REPLICA_SPEEDUP_BAR}x \
                 ({} threads available)",
                rep.speedup2,
                rep.threads
            );
        } else {
            crate::log!(
                Warn,
                "replica speedup bar skipped: {} hardware threads < {} needed for R=2",
                rep.threads,
                2 * rep.ranks
            );
        }
    }
}

/// Human summary for the CLI.
pub fn render(rep: &ReplicaReport) -> String {
    let mut t = Table::new(&[
        "R", "engine", "codec", "steps", "secs", "samp/s", "tail loss", "inter(KB)", "intra(KB)",
    ]);
    for r in &rep.rows {
        t.row(vec![
            r.groups.to_string(),
            r.mode.to_string(),
            r.codec.label().to_string(),
            r.steps.to_string(),
            format!("{:.3}", r.secs),
            format!("{:.1}", r.samples_per_sec),
            format!("{:.5}", r.final_loss),
            format!("{:.1}", r.inter_wire_bytes as f64 / 1e3),
            format!("{:.1}", r.intra_wire_bytes as f64 / 1e3),
        ]);
    }
    format!(
        "{}\nR=2 speedup {:.2}x (bar {REPLICA_SPEEDUP_BAR}x, {} threads) | \
         int8/f32 inter-group bytes {:.3} (bar {REPLICA_BYTE_BAR}) | \
         int8 tail-loss Δ {:+.3}% (bar ±{:.0}%)",
        t.render(),
        rep.speedup2,
        rep.threads,
        rep.int8_byte_ratio,
        rep.int8_loss_delta * 100.0,
        REPLICA_LOSS_BAR * 100.0
    )
}

/// Machine-readable JSON (the CI smoke job writes `BENCH_replica.json`).
pub fn to_json(rep: &ReplicaReport) -> String {
    let rows: Vec<String> = rep
        .rows
        .iter()
        .map(|r| {
            format!(
                "{{\"groups\":{},\"mode\":\"{}\",\"codec\":\"{}\",\"steps\":{},\
                 \"secs\":{:.4},\"samples_per_sec\":{:.2},\"final_loss\":{:.6},\
                 \"inter_wire_bytes\":{},\"intra_wire_bytes\":{}}}",
                r.groups,
                r.mode,
                r.codec.label(),
                r.steps,
                r.secs,
                r.samples_per_sec,
                r.final_loss,
                r.inter_wire_bytes,
                r.intra_wire_bytes
            )
        })
        .collect();
    format!(
        "{{\"neurons\":{},\"layers\":{},\"ranks\":{},\"batch\":{},\"epochs\":{},\
         \"threads\":{},\"rows\":[{}],\"speedup2\":{:.4},\"int8_byte_ratio\":{:.4},\
         \"int8_loss_delta\":{:.6},\"speedup_bar\":{REPLICA_SPEEDUP_BAR},\
         \"byte_bar\":{REPLICA_BYTE_BAR},\"loss_bar\":{REPLICA_LOSS_BAR}}}",
        rep.neurons,
        rep.layers,
        rep.ranks,
        rep.batch,
        rep.epochs,
        rep.threads,
        rows.join(","),
        rep.speedup2,
        rep.int8_byte_ratio,
        rep.int8_loss_delta
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_consistent_rows() {
        let cfg = ReplicaBenchConfig {
            neurons: 64,
            layers: 3,
            ranks: 2,
            batch: 2,
            epochs: 1,
            samples: 16,
            eta: 0.1,
            seed: 5,
            groups: vec![1, 2],
            modes: vec![ExecMode::Overlap],
            codecs: vec![Codec::F32, Codec::int8()],
        };
        let rep = run(&cfg);
        assert_eq!(rep.rows.len(), 4);
        for r in &rep.rows {
            assert!(r.secs > 0.0 && r.samples_per_sec > 0.0);
            assert!(r.final_loss.is_finite() && r.final_loss > 0.0);
            if r.groups == 1 {
                assert_eq!(r.inter_wire_bytes, 0, "{} {}", r.mode, r.codec.label());
            } else {
                assert!(r.inter_wire_bytes > 0);
            }
            assert!(r.intra_wire_bytes > 0);
        }
        // R=1 takes 8 steps over the 8 batches, R=2 takes 4
        assert_eq!(rep.rows[0].steps, 8);
        assert_eq!(rep.rows[2].steps, 4);
        // compression is real on the gradient exchange even at this toy
        // size, where the per-payload headers weigh most; the CI bench
        // enforces the tight REPLICA_BYTE_BAR on the full-size workload
        assert!(
            rep.int8_byte_ratio > 0.0 && rep.int8_byte_ratio < 0.5,
            "int8 byte ratio {}",
            rep.int8_byte_ratio
        );
        assert!(rep.int8_loss_delta.abs() < 0.05, "Δ {}", rep.int8_loss_delta);
        assert!(rep.speedup2 > 0.0);

        let json = to_json(&rep);
        assert!(json.contains("\"rows\":[{"), "{json}");
        assert!(json.contains("\"speedup2\":"), "{json}");
        assert!(json.contains("\"codec\":\"int8\""), "{json}");
        let text = render(&rep);
        assert!(text.contains("inter(KB)") && text.contains("int8"), "{text}");
    }
}
