//! Figure 5 — breakdown of the per-input running time into local SpMV,
//! gradient-update, and communication components, H-SGD vs SGD.

use super::{partition_with, structure_for, Method, Table};
use crate::comm::netmodel::ComputeModel;
use crate::coordinator::replay::{replay, ReplayConfig, ReplayResult};
use crate::partition::CommPlan;

/// One breakdown bar.
#[derive(Debug, Clone)]
pub struct Bar {
    pub nparts: usize,
    pub method: Method,
    pub parts: ReplayResult,
}

impl Bar {
    pub fn comm_fraction(&self) -> f64 {
        let t = self.parts.total();
        if t == 0.0 {
            0.0
        } else {
            self.parts.comm / t
        }
    }
}

pub fn run(
    neurons: usize,
    layers: usize,
    parts_list: &[usize],
    comp: ComputeModel,
    seed: u64,
) -> Vec<Bar> {
    let structure = structure_for(neurons, layers);
    let cfg = ReplayConfig::training(comp);
    let mut out = Vec::new();
    for &p in parts_list {
        for method in [Method::Hypergraph, Method::Random] {
            let part = partition_with(&structure, method, p, seed);
            let plan = CommPlan::build(&structure, &part);
            out.push(Bar {
                nparts: p,
                method,
                parts: replay(&structure, &part, &plan, &cfg),
            });
        }
    }
    out
}

pub fn render(neurons: usize, bars: &[Bar]) -> String {
    let mut t = Table::new(&[
        "N", "P", "", "SpMV(s)", "Updt(s)", "Comm(s)", "Total(s)", "Comm%",
    ]);
    for b in bars {
        t.row(vec![
            neurons.to_string(),
            b.nparts.to_string(),
            b.method.label().into(),
            format!("{:.3e}", b.parts.spmv),
            format!("{:.3e}", b.parts.updt),
            format!("{:.3e}", b.parts.comm),
            format!("{:.3e}", b.parts.total()),
            format!("{:.0}%", b.comm_fraction() * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_fraction_grows_with_p() {
        let comp = ComputeModel::haswell_defaults();
        let bars = run(256, 8, &[2, 32], comp, 1);
        // bars: [H@2, R@2, H@32, R@32]
        let h2 = &bars[0];
        let h32 = &bars[2];
        assert!(
            h32.comm_fraction() > h2.comm_fraction(),
            "{} vs {}",
            h32.comm_fraction(),
            h2.comm_fraction()
        );
        // H commits less comm time than R at the same P
        let r32 = &bars[3];
        assert!(h32.parts.comm < r32.parts.comm);
        assert!(render(256, &bars).contains("Comm%"));
    }
}
