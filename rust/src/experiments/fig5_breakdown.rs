//! Figure 5 — breakdown of the per-input running time into local SpMV,
//! gradient-update, and communication components, H-SGD vs SGD — plus a
//! **live** section measuring, on real threads, how much of the blocking
//! engine's receive stall the split-CSR overlapped engine hides.

use super::{partition_with, structure_for, Method, Table};
use crate::comm::netmodel::ComputeModel;
use crate::coordinator::replay::{replay, ReplayConfig, ReplayResult};
use crate::coordinator::sgd::run_with_plan_mode;
use crate::coordinator::ExecMode;
use crate::partition::CommPlan;

/// One breakdown bar.
#[derive(Debug, Clone)]
pub struct Bar {
    pub nparts: usize,
    pub method: Method,
    pub parts: ReplayResult,
}

impl Bar {
    pub fn comm_fraction(&self) -> f64 {
        let t = self.parts.total();
        if t == 0.0 {
            0.0
        } else {
            self.parts.comm / t
        }
    }
}

pub fn run(
    neurons: usize,
    layers: usize,
    parts_list: &[usize],
    comp: ComputeModel,
    seed: u64,
) -> Vec<Bar> {
    let structure = structure_for(neurons, layers);
    let cfg = ReplayConfig::training(comp);
    let mut out = Vec::new();
    for &p in parts_list {
        for method in [Method::Hypergraph, Method::Random] {
            let part = partition_with(&structure, method, p, seed);
            let plan = CommPlan::build(&structure, &part);
            out.push(Bar {
                nparts: p,
                method,
                parts: replay(&structure, &part, &plan, &cfg),
            });
        }
    }
    out
}

pub fn render(neurons: usize, bars: &[Bar]) -> String {
    let mut t = Table::new(&[
        "N", "P", "", "SpMV(s)", "Updt(s)", "Comm(s)", "Total(s)", "Comm%",
    ]);
    for b in bars {
        t.row(vec![
            neurons.to_string(),
            b.nparts.to_string(),
            b.method.label().into(),
            format!("{:.3e}", b.parts.spmv),
            format!("{:.3e}", b.parts.updt),
            format!("{:.3e}", b.parts.comm),
            format!("{:.3e}", b.parts.total()),
            format!("{:.0}%", b.comm_fraction() * 100.0),
        ]);
    }
    t.render()
}

/// Per-phase wall time (seconds, summed over ranks) of one live training
/// run.
#[derive(Debug, Clone, Copy, Default)]
pub struct LivePhases {
    pub spmv: f64,
    pub updt: f64,
    /// Send-side work (payload gather + channel push).
    pub comm: f64,
    /// Time actually blocked waiting for receives — what overlap hides.
    pub wait: f64,
    /// Bytes the engine actually put on the wire across the whole run
    /// (codec-encoded payload footprint, summed over ranks).
    pub wire_bytes: u64,
}

impl LivePhases {
    pub fn total(&self) -> f64 {
        self.spmv + self.updt + self.comm + self.wait
    }
}

/// Live blocking-vs-overlap-vs-pipelined phase breakdown: the same model,
/// partition, plan, and data trained under all three engines on real rank
/// threads.
#[derive(Debug, Clone)]
pub struct LiveOverlapBreakdown {
    pub neurons: usize,
    pub nparts: usize,
    pub blocking: LivePhases,
    pub overlap: LivePhases,
    /// The send-side pipelined engine ([`ExecMode::Pipelined`]) — its
    /// residual wait is what the chunked send schedule could not hide.
    pub pipelined: LivePhases,
}

impl LiveOverlapBreakdown {
    /// Fraction of the blocking engine's receive stall hidden by the
    /// overlapped schedule: `1 − wait_overlap / wait_blocking`. Can be
    /// slightly negative under scheduler noise; 0 when there was nothing
    /// to hide.
    pub fn hidden_wait_fraction(&self) -> f64 {
        1.0 - self.residual_wait_fraction(&self.overlap)
    }

    /// What remains of the blocking engine's receive stall under `engine`
    /// (`wait_engine / wait_blocking`); 1.0 when there was nothing to
    /// hide. The pipelined engine's residual is the number this PR's send
    /// schedule attacks.
    pub fn residual_wait_fraction(&self, engine: &LivePhases) -> f64 {
        if self.blocking.wait <= 0.0 {
            1.0
        } else {
            engine.wait / self.blocking.wait
        }
    }
}

/// Train the same workload under both engines and collect the live phase
/// timers. Random (high-cut) partitions make the receive stall visible.
pub fn run_live(
    neurons: usize,
    layers: usize,
    nparts: usize,
    samples: usize,
    seed: u64,
) -> LiveOverlapBreakdown {
    use crate::radixnet::{generate, RadixNetConfig};
    let cfg = RadixNetConfig::graph_challenge(neurons, layers)
        .unwrap_or_else(|| panic!("unsupported neuron count {neurons}"));
    let net = generate(&cfg);
    let part = crate::partition::random::random_partition(&net.layers, nparts, seed);
    let plan = CommPlan::build(&net.layers, &part);
    let mut rng = crate::util::Rng::new(seed ^ 0x5eed);
    let inputs: Vec<Vec<f32>> = (0..samples)
        .map(|_| {
            (0..net.input_dim())
                .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
                .collect()
        })
        .collect();
    let targets: Vec<Vec<f32>> = (0..samples)
        .map(|i| {
            let mut y = vec![0f32; net.output_dim()];
            y[i % net.output_dim()] = 1.0;
            y
        })
        .collect();
    let phases_of = |mode: ExecMode| -> LivePhases {
        let run = run_with_plan_mode(&net, &part, &plan, &inputs, &targets, 0.1, 1, mode);
        LivePhases {
            spmv: run.timer.get_secs("spmv"),
            updt: run.timer.get_secs("updt"),
            comm: run.timer.get_secs("comm"),
            wait: run.timer.get_secs("wait"),
            wire_bytes: 4 * run.sent.iter().map(|&(words, _)| words).sum::<u64>(),
        }
    };
    LiveOverlapBreakdown {
        neurons,
        nparts,
        blocking: phases_of(ExecMode::Blocking),
        overlap: phases_of(ExecMode::Overlap),
        pipelined: phases_of(ExecMode::pipelined()),
    }
}

pub fn render_live(b: &LiveOverlapBreakdown) -> String {
    let mut t = Table::new(&[
        "N", "P", "engine", "SpMV(s)", "Updt(s)", "Comm(s)", "Wait(s)", "Total(s)", "Wait%",
        "Wire(KB)",
    ]);
    for (label, p) in [
        ("blocking", &b.blocking),
        ("overlap", &b.overlap),
        ("pipelined", &b.pipelined),
    ] {
        t.row(vec![
            b.neurons.to_string(),
            b.nparts.to_string(),
            label.into(),
            format!("{:.3e}", p.spmv),
            format!("{:.3e}", p.updt),
            format!("{:.3e}", p.comm),
            format!("{:.3e}", p.wait),
            format!("{:.3e}", p.total()),
            format!("{:.0}%", b.residual_wait_fraction(p) * 100.0),
            format!("{:.1}", p.wire_bytes as f64 / 1e3),
        ]);
    }
    format!(
        "{}comm-wait hidden by overlap: {:.0}%  |  residual wait: overlap {:.0}%, pipelined {:.0}% of blocking\n",
        t.render(),
        b.hidden_wait_fraction() * 100.0,
        b.residual_wait_fraction(&b.overlap) * 100.0,
        b.residual_wait_fraction(&b.pipelined) * 100.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_fraction_grows_with_p() {
        let comp = ComputeModel::haswell_defaults();
        let bars = run(256, 8, &[2, 32], comp, 1);
        // bars: [H@2, R@2, H@32, R@32]
        let h2 = &bars[0];
        let h32 = &bars[2];
        assert!(
            h32.comm_fraction() > h2.comm_fraction(),
            "{} vs {}",
            h32.comm_fraction(),
            h2.comm_fraction()
        );
        // H commits less comm time than R at the same P
        let r32 = &bars[3];
        assert!(h32.parts.comm < r32.parts.comm);
        assert!(render(256, &bars).contains("Comm%"));
    }

    #[test]
    fn live_breakdown_reports_all_three_engines() {
        let b = run_live(64, 3, 4, 4, 11);
        // every engine did real compute, and the hidden fraction is a
        // sane ratio (noise can push it slightly negative, never above 1)
        assert!(b.blocking.spmv > 0.0 && b.overlap.spmv > 0.0 && b.pipelined.spmv > 0.0);
        assert!(b.blocking.total() > 0.0 && b.overlap.total() > 0.0 && b.pipelined.total() > 0.0);
        let h = b.hidden_wait_fraction();
        assert!(h.is_finite() && h <= 1.0, "hidden fraction {h}");
        let rp = b.residual_wait_fraction(&b.pipelined);
        assert!(rp.is_finite() && rp >= 0.0, "residual fraction {rp}");
        assert!(
            b.blocking.wire_bytes > 0 && b.blocking.wire_bytes == b.overlap.wire_bytes,
            "same plan + F32 codec ⇒ identical bytes on the wire"
        );
        let s = render_live(&b);
        assert!(s.contains("Wait(s)") && s.contains("overlap") && s.contains("blocking"));
        assert!(s.contains("pipelined") && s.contains("residual wait"));
        assert!(s.contains("comm-wait hidden by overlap"));
        assert!(s.contains("Wire(KB)"));
    }
}
