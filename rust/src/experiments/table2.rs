//! Table 2 — inference-only throughput (edges/second): H-SpFF (model-
//! parallel, hypergraph-partitioned, batched SpMM on P ranks — simulated
//! via replay with measured compute rates) vs GB (data-parallel
//! shared-memory baseline, single-core rate measured live and scaled to
//! the paper's 16-core node).

use super::{partition_with, sci, Method, Table};
use crate::comm::netmodel::ComputeModel;
use crate::coordinator::gb_baseline::{gb_throughput, GbConfig};
use crate::coordinator::replay::throughput_edges_per_sec;
use crate::partition::CommPlan;
use crate::radixnet::{generate, RadixNetConfig};

/// One Table-2 row.
#[derive(Debug, Clone)]
pub struct Row {
    pub neurons: usize,
    pub layers: usize,
    pub hspff_eps: f64,
    pub gb_eps: f64,
}

impl Row {
    pub fn speedup(&self) -> f64 {
        self.hspff_eps / self.gb_eps
    }
}

/// Configuration of the throughput experiment.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Ranks for H-SpFF (paper: 128 MPI ranks × 4 threads = 512 cores).
    pub nparts: usize,
    /// SpMM batch width.
    pub batch: usize,
    /// Inputs per measurement (paper: 60k MNIST; scaled down by default).
    pub inputs: usize,
    /// Live-measurement sample for the GB single-core rate.
    pub gb_sample: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            nparts: 128,
            batch: 64,
            inputs: 60_000,
            gb_sample: 128,
        }
    }
}

pub fn run(neurons: usize, layers: usize, cfg: &Config, comp: ComputeModel, seed: u64) -> Row {
    let net_cfg = RadixNetConfig::graph_challenge(neurons, layers)
        .unwrap_or_else(|| panic!("unsupported size {neurons}"));
    let net = generate(&net_cfg);
    let structure = net.layers.clone();

    // H-SpFF: hypergraph partition + replay-simulated distributed SpMM.
    // The paper's H-SpFF threads local SpMM over 4 cores per rank; our
    // per-rank rate is single-core, so we charge rank-local compute at
    // measured single-core speed — conservative for H-SpFF.
    let part = partition_with(&structure, Method::Hypergraph, cfg.nparts, seed);
    let plan = CommPlan::build(&structure, &part);
    let hspff = throughput_edges_per_sec(&structure, &part, &plan, comp, cfg.batch, cfg.inputs);

    // GB: measured single-core full-model rate × 16 cores × efficiency.
    let gb = gb_throughput(&net, &GbConfig::paper_node(), cfg.gb_sample);

    Row {
        neurons,
        layers,
        hspff_eps: hspff,
        gb_eps: gb,
    }
}

pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "Neurons", "Layers", "H-SpFF eps", "GB eps", "Speedup",
    ]);
    for r in rows {
        t.row(vec![
            r.neurons.to_string(),
            r.layers.to_string(),
            sci(r.hspff_eps),
            sci(r.gb_eps),
            format!("{:.2}", r.speedup()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughputs_positive_and_finite() {
        let comp = ComputeModel::haswell_defaults();
        let cfg = Config {
            nparts: 16,
            batch: 16,
            inputs: 64,
            gb_sample: 32,
        };
        let row = run(256, 4, &cfg, comp, 1);
        assert!(row.hspff_eps > 0.0 && row.hspff_eps.is_finite());
        assert!(row.gb_eps > 0.0 && row.gb_eps.is_finite());
        assert!(render(&[row]).contains("Speedup"));
    }
}
