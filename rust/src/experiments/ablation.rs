//! Ablation of the paper's key design choice: the **fixed-vertex chaining**
//! between phases (Section 5). Three strategies are compared at equal P:
//!
//! - `chained`    — the paper's multi-phase model: phase φ^k fixes one
//!   vertex per column to the part that produced x^{k-1}(j) in φ^{k-1};
//! - `independent`— same per-layer hypergraph, but no fixed vertices: each
//!   layer is partitioned in isolation (what a naive per-layer
//!   min-cut would do);
//! - `random`     — the evenly-split random baseline.
//!
//! The gap between `chained` and `independent` isolates exactly what the
//! fixed vertices buy: inter-layer producer/consumer alignment.

use super::{structure_for, Table};
use crate::hypergraph::PartitionConfig;
use crate::partition::metrics::PartitionMetrics;
use crate::partition::phases::{build_phase_hypergraph, hypergraph_partition, PhaseConfig};
use crate::partition::random::random_partition;
use crate::partition::DnnPartition;

/// One strategy's metrics.
#[derive(Debug, Clone)]
pub struct Row {
    pub strategy: &'static str,
    pub avg_vol_k: f64,
    pub max_vol_k: f64,
    pub avg_msg_k: f64,
    pub imb: f64,
}

pub fn run(neurons: usize, layers: usize, nparts: usize, seed: u64) -> Vec<Row> {
    let structure = structure_for(neurons, layers);

    let mut cfg = PhaseConfig::new(nparts);
    cfg.seed = seed;
    let chained = hypergraph_partition(&structure, &cfg);

    let mut layer_parts = Vec::new();
    for (k, w) in structure.iter().enumerate() {
        let hg = build_phase_hypergraph(w, None);
        let mut pcfg = PartitionConfig::new(nparts);
        pcfg.seed = seed.wrapping_add(1000 + k as u64);
        let parts = crate::hypergraph::partition(&hg, &pcfg);
        layer_parts.push(parts[..w.nrows].to_vec());
    }
    let independent = DnnPartition {
        nparts,
        input_parts: chained.input_parts.clone(),
        layer_parts,
    };
    let random = random_partition(&structure, nparts, seed);

    [
        ("chained (paper)", &chained),
        ("independent", &independent),
        ("random", &random),
    ]
    .into_iter()
    .map(|(name, part)| {
        let m = PartitionMetrics::compute(&structure, part);
        Row {
            strategy: name,
            avg_vol_k: m.avg_volume() / 1e3,
            max_vol_k: m.max_volume() / 1e3,
            avg_msg_k: m.avg_msgs() / 1e3,
            imb: m.comp_imbalance(),
        }
    })
    .collect()
}

pub fn render(neurons: usize, nparts: usize, rows: &[Row]) -> String {
    let mut t = Table::new(&["N", "P", "strategy", "VolAvg(K)", "VolMax(K)", "MsgAvg(K)", "imb"]);
    for r in rows {
        t.row(vec![
            neurons.to_string(),
            nparts.to_string(),
            r.strategy.to_string(),
            format!("{:.2}", r.avg_vol_k),
            format!("{:.2}", r.max_vol_k),
            format!("{:.2}", r.avg_msg_k),
            format!("{:.3}", r.imb),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaining_strictly_helps() {
        let rows = run(256, 8, 8, 1);
        let chained = &rows[0];
        let independent = &rows[1];
        let random = &rows[2];
        assert!(chained.avg_vol_k <= independent.avg_vol_k);
        assert!(independent.avg_vol_k < random.avg_vol_k);
        assert!(render(256, 8, &rows).contains("chained"));
    }
}
