//! Ablation of the paper's key design choice: the **fixed-vertex chaining**
//! between phases (Section 5). Three strategies are compared at equal P:
//!
//! - `chained`    — the paper's multi-phase model: phase φ^k fixes one
//!   vertex per column to the part that produced x^{k-1}(j) in φ^{k-1};
//! - `independent`— same per-layer hypergraph, but no fixed vertices: each
//!   layer is partitioned in isolation (what a naive per-layer
//!   min-cut would do);
//! - `random`     — the evenly-split random baseline.
//!
//! The gap between `chained` and `independent` isolates exactly what the
//! fixed vertices buy: inter-layer producer/consumer alignment.

use super::{structure_for, Table};
use crate::comm::Codec;
use crate::coordinator::sgd::run_with_plan_mode;
use crate::coordinator::ExecMode;
use crate::hypergraph::PartitionConfig;
use crate::partition::metrics::PartitionMetrics;
use crate::partition::phases::{build_phase_hypergraph, hypergraph_partition, PhaseConfig};
use crate::partition::random::random_partition;
use crate::partition::{contiguous_partition, CommPlan, DnnPartition};

/// One strategy's metrics.
#[derive(Debug, Clone)]
pub struct Row {
    pub strategy: &'static str,
    pub avg_vol_k: f64,
    pub max_vol_k: f64,
    pub avg_msg_k: f64,
    pub imb: f64,
}

pub fn run(neurons: usize, layers: usize, nparts: usize, seed: u64) -> Vec<Row> {
    let structure = structure_for(neurons, layers);

    let mut cfg = PhaseConfig::new(nparts);
    cfg.seed = seed;
    let chained = hypergraph_partition(&structure, &cfg);

    let mut layer_parts = Vec::new();
    for (k, w) in structure.iter().enumerate() {
        let hg = build_phase_hypergraph(w, None);
        let mut pcfg = PartitionConfig::new(nparts);
        pcfg.seed = seed.wrapping_add(1000 + k as u64);
        let parts = crate::hypergraph::partition(&hg, &pcfg);
        layer_parts.push(parts[..w.nrows].to_vec());
    }
    let independent = DnnPartition {
        nparts,
        input_parts: chained.input_parts.clone(),
        layer_parts,
    };
    let random = random_partition(&structure, nparts, seed);

    [
        ("chained (paper)", &chained),
        ("independent", &independent),
        ("random", &random),
    ]
    .into_iter()
    .map(|(name, part)| {
        let m = PartitionMetrics::compute(&structure, part);
        Row {
            strategy: name,
            avg_vol_k: m.avg_volume() / 1e3,
            max_vol_k: m.max_volume() / 1e3,
            avg_msg_k: m.avg_msgs() / 1e3,
            imb: m.comp_imbalance(),
        }
    })
    .collect()
}

pub fn render(neurons: usize, nparts: usize, rows: &[Row]) -> String {
    let mut t = Table::new(&["N", "P", "strategy", "VolAvg(K)", "VolMax(K)", "MsgAvg(K)", "imb"]);
    for r in rows {
        t.row(vec![
            neurons.to_string(),
            nparts.to_string(),
            r.strategy.to_string(),
            format!("{:.2}", r.avg_vol_k),
            format!("{:.2}", r.max_vol_k),
            format!("{:.2}", r.avg_msg_k),
            format!("{:.3}", r.imb),
        ]);
    }
    t.render()
}

/// One wire codec's accuracy-vs-volume row: the same digits SGD run under
/// each codec, reporting the convergence delta the compression costs and
/// the bytes it saves.
#[derive(Debug, Clone)]
pub struct CodecRow {
    pub codec: Codec,
    /// Mean loss over the final 10% of steps.
    pub final_loss: f64,
    /// Relative delta vs the `Codec::F32` run (0 for the F32 row itself).
    pub loss_delta: f64,
    /// Bytes actually shipped over the fabric during the run.
    pub wire_bytes: u64,
}

/// Codec ablation: train the digits workload once per codec — same net,
/// partition, plan, data, and schedule; only the wire format of the
/// fabric payloads changes — and measure what quantized activations and
/// gradients cost in SGD convergence vs what they save in bytes.
pub fn codec_convergence(
    neurons: usize,
    layers: usize,
    ranks: usize,
    steps: usize,
    eta: f32,
    seed: u64,
) -> Vec<CodecRow> {
    use crate::radixnet::{generate, RadixNetConfig};
    let side = (neurons as f64).sqrt() as usize;
    assert_eq!(side * side, neurons, "digits input needs a square neuron count");
    let cfg = RadixNetConfig::graph_challenge(neurons, layers)
        .unwrap_or_else(|| panic!("unsupported neuron count {neurons}"));
    let net = generate(&cfg);
    let part = contiguous_partition(&net.layers, ranks);
    let data = crate::data::synthetic_mnist(side, steps, seed);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..steps).map(|i| data.target(i, neurons)).collect();

    let tail = (steps / 10).max(1);
    let mut rows = Vec::new();
    let mut f32_loss = 0f64;
    for codec in [Codec::F32, Codec::F16, Codec::int8()] {
        let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
        let run = run_with_plan_mode(
            &net,
            &part,
            &plan,
            &inputs,
            &targets,
            eta,
            1,
            ExecMode::Overlap,
        );
        let final_loss = run.losses[run.losses.len() - tail..]
            .iter()
            .map(|&l| l as f64)
            .sum::<f64>()
            / tail as f64;
        let wire_bytes = 4 * run.sent.iter().map(|&(w, _)| w).sum::<u64>();
        if codec == Codec::F32 {
            f32_loss = final_loss;
        }
        let loss_delta = if f32_loss > 0.0 {
            (final_loss - f32_loss) / f32_loss
        } else {
            0.0
        };
        rows.push(CodecRow {
            codec,
            final_loss,
            loss_delta,
            wire_bytes,
        });
    }
    rows
}

pub fn render_codec(neurons: usize, ranks: usize, rows: &[CodecRow]) -> String {
    let mut t = Table::new(&["N", "P", "codec", "final loss", "Δ vs f32", "wire(KB)", "ratio"]);
    let raw = rows.first().map_or(0, |r| r.wire_bytes);
    for r in rows {
        t.row(vec![
            neurons.to_string(),
            ranks.to_string(),
            r.codec.label().to_string(),
            format!("{:.5}", r.final_loss),
            format!("{:+.3}%", r.loss_delta * 100.0),
            format!("{:.1}", r.wire_bytes as f64 / 1e3),
            format!("{:.2}x", raw as f64 / r.wire_bytes.max(1) as f64),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaining_strictly_helps() {
        let rows = run(256, 8, 8, 1);
        let chained = &rows[0];
        let independent = &rows[1];
        let random = &rows[2];
        assert!(chained.avg_vol_k <= independent.avg_vol_k);
        assert!(independent.avg_vol_k < random.avg_vol_k);
        assert!(render(256, 8, &rows).contains("chained"));
    }

    #[test]
    fn codec_ablation_trades_bytes_for_bounded_loss_delta() {
        let rows = codec_convergence(256, 3, 4, 30, 0.5, 9);
        assert_eq!(rows.len(), 3);
        let (f32r, f16r, i8r) = (&rows[0], &rows[1], &rows[2]);
        assert_eq!(f32r.loss_delta, 0.0);
        // compression is real even with per-payload headers on b=1
        // training payloads: f16 ≤ 65%, int8 ≤ 50% of the raw bytes
        assert!(
            f16r.wire_bytes * 100 <= f32r.wire_bytes * 65,
            "f16 {} vs f32 {}",
            f16r.wire_bytes,
            f32r.wire_bytes
        );
        assert!(
            i8r.wire_bytes * 100 <= f32r.wire_bytes * 50,
            "int8 {} vs f32 {}",
            i8r.wire_bytes,
            f32r.wire_bytes
        );
        // and the convergence hit is bounded (loose here; the bench section
        // enforces the 1% f16 parity bar on the full digits run)
        assert!(f16r.loss_delta.abs() < 0.05, "f16 Δ {}", f16r.loss_delta);
        assert!(i8r.final_loss.is_finite() && i8r.final_loss > 0.0);
        let s = render_codec(64, 4, &rows);
        assert!(s.contains("f16") && s.contains("int8") && s.contains("ratio"));
    }
}
