//! Graph Challenge inference driver (arXiv 1909.05631): a RadixNet at
//! challenge scale pushed through the three live engines and the serving
//! pool, scored in **edges/sec** — `nnz(W) × inputs / seconds`, the
//! challenge's throughput metric — per engine/codec/rank-count.
//!
//! Correctness ride-along: before timing, each engine/codec/rank combo
//! classifies one batch and its category set (inputs with any active
//! output neuron, see [`categories`]) is compared against the serial
//! reference engine. Lossless f32 wires must agree exactly; lossy codecs
//! report their own category count (quantization can legitimately flip a
//! near-threshold input). Shared by `spdnn graphchallenge` and the
//! `SPDNN_SECTION=graphchallenge` bench-smoke section.

use super::{sci, Table};
use crate::comm::Codec;
use crate::coordinator::sgd::infer_with_plan_mode;
use crate::coordinator::{ExecMode, RankScratch, RankState};
use crate::dnn::inference::infer_batch;
use crate::dnn::SparseNet;
use crate::partition::{contiguous_partition, CommPlan};
use crate::radixnet::{categories, gc_input_batch, generate, RadixNetConfig};
use crate::runtime::parallel::run_ranks;
use crate::serving::{PoolConfig, RankPool};
use crate::util::Stopwatch;
use std::time::Duration;

/// Workload shape for one [`run`].
#[derive(Debug, Clone)]
pub struct GcConfig {
    /// Neurons per layer (must be a supported RadixNet preset size).
    pub neurons: usize,
    /// Weight layer count.
    pub layers: usize,
    /// Rank counts to sweep (the engine grid runs once per entry).
    pub ranks: Vec<usize>,
    /// Inputs per dispatched batch (the SpMM width).
    pub batch: usize,
    /// Total inputs to stream per combo (rounded up to whole batches).
    pub inputs: usize,
    /// Engines to sweep.
    pub modes: Vec<ExecMode>,
    /// Wire codecs to sweep.
    pub codecs: Vec<Codec>,
    /// Also measure the persistent serving pool (pipelined, first codec,
    /// last rank count).
    pub pool: bool,
    /// Input batch seed.
    pub seed: u64,
}

impl Default for GcConfig {
    fn default() -> Self {
        Self {
            neurons: 1024,
            layers: 32, // 32 layers × 32K edges = 1,048,576 edges
            ranks: vec![4],
            batch: 64,
            inputs: 256,
            modes: vec![ExecMode::Blocking, ExecMode::Overlap, ExecMode::pipelined()],
            codecs: vec![Codec::F32],
            pool: true,
            seed: 0x6C,
        }
    }
}

/// One engine/codec/rank measurement.
#[derive(Debug, Clone)]
pub struct GcRow {
    /// Engine label (`blocking` | `overlap` | `pipelined` | `pool`).
    pub engine: &'static str,
    /// Wire codec label.
    pub codec: &'static str,
    /// Rank count.
    pub ranks: usize,
    /// Steady-state wall seconds for the streamed inputs (slowest rank).
    pub secs: f64,
    /// The Graph Challenge metric: `nnz(W) × inputs / secs`.
    pub edges_per_sec: f64,
    /// Categories found on the check batch (sanity signal for lossy
    /// codecs; equals the serial count on f32 wires by assertion).
    pub categories: usize,
}

/// A full sweep: the workload plus every measured row.
#[derive(Debug, Clone)]
pub struct GcReport {
    /// Neurons per layer.
    pub neurons: usize,
    /// Weight layer count.
    pub layers: usize,
    /// Total edge count of the generated network.
    pub edges: u64,
    /// Inputs per batch.
    pub batch: usize,
    /// Inputs streamed per combo (whole batches).
    pub inputs: usize,
    /// Serial-reference category count on the check batch.
    pub serial_categories: usize,
    /// One row per engine/codec/rank combo.
    pub rows: Vec<GcRow>,
}

/// Generate the network, cross-check every combo's categories against the
/// serial engine, and measure steady-state edges/sec per combo.
pub fn run(cfg: &GcConfig) -> GcReport {
    let net_cfg = RadixNetConfig::graph_challenge_inference(cfg.neurons, cfg.layers)
        .unwrap_or_else(|| panic!("unsupported neuron count {}", cfg.neurons));
    let net = generate(&net_cfg);
    let edges = net.total_nnz() as u64;
    let nl = net.output_dim();
    let nbatches = cfg.inputs.div_ceil(cfg.batch).max(1);
    let batches: Vec<Vec<f32>> = (0..nbatches)
        .map(|i| gc_input_batch(net.input_dim(), cfg.batch, cfg.seed.wrapping_add(i as u64)))
        .collect();
    let inputs = nbatches * cfg.batch;

    // serial reference + its category set on the check batch (batch 0)
    let reference = infer_batch(&net, &batches[0], cfg.batch);
    let ref_cats = categories(&reference, nl, cfg.batch, 0.0);

    let mut rows = Vec::new();
    for &nranks in &cfg.ranks {
        let part = contiguous_partition(&net.layers, nranks);
        for &codec in &cfg.codecs {
            let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
            for &mode in &cfg.modes {
                let (out, _) =
                    infer_with_plan_mode(&net, &part, &plan, &batches[0], cfg.batch, mode);
                let cats = categories(&out, nl, cfg.batch, 0.0);
                if codec == Codec::F32 {
                    assert_eq!(
                        cats,
                        ref_cats,
                        "{} engine (codec {}, P={nranks}) disagrees with serial categories",
                        mode.label(),
                        codec.label()
                    );
                }
                // steady-state loop: rank threads, states, and scratch
                // built once; only the batch stream is on the clock
                let timed = run_ranks(nranks, |rank, ep| {
                    let mut state = RankState::build(&net, &part, &plan, rank as u32, mode);
                    let mut scratch = RankScratch::new();
                    let _ =
                        state.infer_owned_outputs(ep, &plan, &batches[0], cfg.batch, &mut scratch);
                    let sw = Stopwatch::start();
                    for x0 in &batches {
                        let _ = state.infer_owned_outputs(ep, &plan, x0, cfg.batch, &mut scratch);
                    }
                    sw.elapsed_secs()
                })
                .expect("graphchallenge engine run failed");
                let secs = timed.outputs.into_iter().fold(0f64, f64::max);
                rows.push(GcRow {
                    engine: mode.label(),
                    codec: codec.label(),
                    ranks: nranks,
                    secs,
                    edges_per_sec: edges as f64 * inputs as f64 / secs,
                    categories: cats.len(),
                });
            }
        }
    }
    if cfg.pool {
        rows.push(pool_row(&net, cfg, &batches, edges, nl, &ref_cats));
    }
    GcReport {
        neurons: cfg.neurons,
        layers: cfg.layers,
        edges,
        batch: cfg.batch,
        inputs,
        serial_categories: ref_cats.len(),
        rows,
    }
}

/// The serving-pool measurement: same batch stream submitted as tickets
/// to a persistent [`RankPool`] in its default pipelined mode.
fn pool_row(
    net: &SparseNet,
    cfg: &GcConfig,
    batches: &[Vec<f32>],
    edges: u64,
    nl: usize,
    ref_cats: &[u32],
) -> GcRow {
    let nranks = *cfg.ranks.last().expect("at least one rank count");
    let codec = cfg.codecs[0];
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks,
            max_batch: cfg.batch,
            max_wait: Duration::ZERO,
            adaptive: false,
            mode: ExecMode::pipelined(),
            codec,
            ..PoolConfig::default()
        },
    );
    let out = pool
        .submit(batches[0].clone(), cfg.batch)
        .wait()
        .expect("pool warm-up request failed");
    let cats = categories(&out, nl, cfg.batch, 0.0);
    if codec == Codec::F32 {
        assert_eq!(cats, ref_cats, "pool (P={nranks}) disagrees with serial categories");
    }
    let sw = Stopwatch::start();
    let tickets: Vec<_> = batches
        .iter()
        .map(|x0| pool.submit(x0.clone(), cfg.batch))
        .collect();
    for t in tickets {
        let _ = t.wait().expect("pool request failed");
    }
    let secs = sw.elapsed_secs();
    let _ = pool.shutdown();
    GcRow {
        engine: "pool",
        codec: codec.label(),
        ranks: nranks,
        secs,
        edges_per_sec: edges as f64 * (batches.len() * cfg.batch) as f64 / secs,
        categories: cats.len(),
    }
}

/// Fixed-width table for the CLI/bench output.
pub fn render(rep: &GcReport) -> String {
    let mut t = Table::new(&["engine", "codec", "P", "s", "edges/s", "cats"]);
    for r in &rep.rows {
        t.row(vec![
            r.engine.to_string(),
            r.codec.to_string(),
            r.ranks.to_string(),
            format!("{:.3}", r.secs),
            sci(r.edges_per_sec),
            r.categories.to_string(),
        ]);
    }
    format!(
        "RadixNet N={} L={} — {} edges, {} inputs × b={} (serial cats {})\n{}",
        rep.neurons,
        rep.layers,
        rep.edges,
        rep.inputs,
        rep.batch,
        rep.serial_categories,
        t.render()
    )
}

/// The `BENCH_graphchallenge.json` payload (schema documented in
/// `docs/BENCHMARKS.md`).
pub fn to_json(rep: &GcReport) -> String {
    let rows: Vec<String> = rep
        .rows
        .iter()
        .map(|r| {
            format!(
                "{{\"engine\":\"{}\",\"codec\":\"{}\",\"ranks\":{},\"secs\":{:.4},\
                 \"edges_per_sec\":{:.1},\"categories\":{}}}",
                r.engine, r.codec, r.ranks, r.secs, r.edges_per_sec, r.categories
            )
        })
        .collect();
    format!(
        "{{\"neurons\":{},\"layers\":{},\"edges\":{},\"batch\":{},\"inputs\":{},\
         \"serial_categories\":{},\"rows\":[{}]}}",
        rep.neurons,
        rep.layers,
        rep.edges,
        rep.batch,
        rep.inputs,
        rep.serial_categories,
        rows.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_reports_every_combo() {
        let cfg = GcConfig {
            neurons: 64,
            layers: 4,
            ranks: vec![2],
            batch: 8,
            inputs: 16,
            codecs: vec![Codec::F32],
            pool: true,
            ..GcConfig::default()
        };
        let rep = run(&cfg);
        assert_eq!(rep.inputs, 16);
        assert_eq!(rep.edges, 64 * 8 * 4);
        // 3 engines × 1 codec × 1 rank count, plus the pool row
        assert_eq!(rep.rows.len(), 4);
        for r in &rep.rows {
            assert!(r.secs > 0.0 && r.edges_per_sec > 0.0, "{} not timed", r.engine);
            assert_eq!(r.categories, rep.serial_categories, "{} cats", r.engine);
        }
        let json = to_json(&rep);
        assert!(json.contains("\"edges\":2048"));
        assert!(json.contains("\"engine\":\"pool\""));
        assert!(render(&rep).contains("pool"));
    }
}
