//! Figure 4 — strong scaling of SGD vs H-SGD: average simulated time to
//! process one input vector, over processor counts.

use super::{partition_with, structure_for, Method, Table};
use crate::comm::netmodel::ComputeModel;
use crate::coordinator::replay::{replay, ReplayConfig};
use crate::partition::CommPlan;

/// One scaling point.
#[derive(Debug, Clone)]
pub struct Point {
    pub nparts: usize,
    pub h_secs: f64,
    pub r_secs: f64,
}

impl Point {
    pub fn speedup(&self) -> f64 {
        self.r_secs / self.h_secs
    }
}

/// Run the sweep for one network size.
pub fn run(
    neurons: usize,
    layers: usize,
    parts: &[usize],
    comp: ComputeModel,
    seed: u64,
) -> Vec<Point> {
    let structure = structure_for(neurons, layers);
    let cfg = ReplayConfig::training(comp);
    parts
        .iter()
        .map(|&p| {
            let h = partition_with(&structure, Method::Hypergraph, p, seed);
            let r = partition_with(&structure, Method::Random, p, seed);
            let hp = CommPlan::build(&structure, &h);
            let rp = CommPlan::build(&structure, &r);
            Point {
                nparts: p,
                h_secs: replay(&structure, &h, &hp, &cfg).total(),
                r_secs: replay(&structure, &r, &rp, &cfg).total(),
            }
        })
        .collect()
}

pub fn render(neurons: usize, points: &[Point]) -> String {
    let mut t = Table::new(&["N", "P", "SGD s/input", "H-SGD s/input", "H speedup"]);
    for p in points {
        t.row(vec![
            neurons.to_string(),
            p.nparts.to_string(),
            format!("{:.3e}", p.r_secs),
            format!("{:.3e}", p.h_secs),
            format!("{:.2}x", p.speedup()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h_faster_and_both_scale() {
        let comp = ComputeModel::haswell_defaults();
        // N=1024 is the smallest paper size; 256/8-rank scaling is already
        // latency-bound (which the paper also observes for small nets).
        let pts = run(1024, 8, &[2, 8], comp, 1);
        for p in &pts {
            assert!(p.speedup() > 1.0, "P={}: speedup {}", p.nparts, p.speedup());
        }
        // strong scaling: P=8 beats P=2 on the compute-bound N=1024 net
        assert!(pts[1].h_secs < pts[0].h_secs);
        let s = render(1024, &pts);
        assert!(s.contains("H speedup"));
    }
}
