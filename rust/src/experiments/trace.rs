//! `spdnn trace` — flight-recorder capture driver: run a digits inference
//! workload with per-rank tracing forced on, export the spans as Chrome
//! trace-event JSON (Perfetto-loadable), and report span coverage plus a
//! replay-vs-measured drift check.
//!
//! The driver wraps every inference pass in a rank-level `pass` span, so
//! the union of each rank's spans covers the whole serving window — the
//! CI trace-smoke step asserts coverage ≥ 0.90 on the emitted JSON. The
//! drift report compares the α-β replay model's predicted compute/comm
//! seconds ([`crate::coordinator::replay`]) against the live per-phase
//! timers the same run measured, closing the loop between the simulated
//! results (Fig. 4/5, Table 2) and real span timings.

use crate::comm::netmodel::ComputeModel;
use crate::comm::Codec;
use crate::coordinator::{replay, ExecMode, RankScratch, RankState, ReplayConfig};
use crate::data::synthetic_mnist;
use crate::obs::{chrome_trace_json, span_coverage, TraceMode, NO_CHUNK, NO_LAYER};
use crate::partition::{contiguous_partition, CommPlan};
use crate::radixnet::{generate, RadixNetConfig};
use crate::runtime::parallel::run_ranks;
use crate::util::{PhaseTimer, Stopwatch};

/// Workload shape for one trace capture.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub neurons: usize,
    pub layers: usize,
    pub ranks: usize,
    /// Columns per inference batch.
    pub batch: usize,
    /// Batched passes traced back-to-back.
    pub passes: usize,
    pub mode: ExecMode,
    pub codec: Codec,
    /// Ring capacity per rank (spans); the oldest spans drop on overflow.
    pub capacity: usize,
    /// Measure real per-nnz rates for the drift report (the CLI default);
    /// `false` uses the Haswell defaults — cheap enough for tests.
    pub calibrate: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            neurons: 1024,
            layers: 24,
            ranks: 4,
            batch: 16,
            passes: 8,
            mode: ExecMode::pipelined(),
            codec: Codec::F32,
            capacity: crate::obs::DEFAULT_TRACE_CAPACITY,
            calibrate: true,
        }
    }
}

/// Replay-model prediction vs measured per-phase seconds for the traced
/// run. "Measured" takes the per-phase **maximum over ranks** (the
/// critical-path proxy the replay's per-layer barrier models); ratios
/// above 1.0 mean the live run was slower than the α-β model predicts.
#[derive(Debug, Clone, Copy)]
pub struct TraceDrift {
    pub measured_spmv_secs: f64,
    pub modeled_spmv_secs: f64,
    pub measured_comm_secs: f64,
    pub modeled_comm_secs: f64,
}

impl TraceDrift {
    pub fn spmv_ratio(&self) -> f64 {
        self.measured_spmv_secs / self.modeled_spmv_secs.max(1e-12)
    }

    pub fn comm_ratio(&self) -> f64 {
        self.measured_comm_secs / self.modeled_comm_secs.max(1e-12)
    }
}

/// Everything one capture produced: the Chrome trace JSON plus the
/// numbers the CLI prints and CI gates on.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub mode: &'static str,
    pub ranks: usize,
    pub batch: usize,
    pub passes: usize,
    pub wall_secs: f64,
    /// Per-rank span coverage of `[first span, last span]` (union-merged).
    pub coverage: Vec<f64>,
    /// Total spans recorded across ranks (post-wrap survivors).
    pub spans: usize,
    /// Spans overwritten by ring wraps, summed over ranks.
    pub dropped: u64,
    pub drift: TraceDrift,
    /// Chrome trace-event JSON with an `"spdnn"` metadata key.
    pub json: String,
}

impl TraceReport {
    /// The smallest per-rank coverage — the number CI gates on.
    pub fn min_coverage(&self) -> f64 {
        self.coverage.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Capture one trace: generate the RadixNet, partition contiguously, run
/// `passes` batched inference passes with tracing forced on (independent
/// of `SPDNN_TRACE`), and assemble the report.
pub fn run(cfg: &TraceConfig) -> TraceReport {
    let net = generate(
        &RadixNetConfig::graph_challenge(cfg.neurons, cfg.layers)
            .unwrap_or_else(|| panic!("unsupported neuron count {}", cfg.neurons)),
    );
    let side = (cfg.neurons as f64).sqrt() as usize;
    assert_eq!(side * side, cfg.neurons, "neurons must be a square");
    let data = synthetic_mnist(side, cfg.batch, 42);
    let (x0, b) = data.pack_batch(0, cfg.batch);
    let part = contiguous_partition(&net.layers, cfg.ranks);
    let mut plan = CommPlan::build(&net.layers, &part);
    plan.set_codec(cfg.codec, cfg.codec);

    // one mode value for every rank: the shared epoch puts all rank
    // tracks on a single timeline in the exported JSON
    let trace = TraceMode::with_capacity(cfg.capacity);
    let mode = cfg.mode;
    let passes = cfg.passes;
    let sw = Stopwatch::start();
    let run = run_ranks(cfg.ranks, |rank, ep| {
        let mut state = RankState::build_traced(&net, &part, &plan, rank as u32, mode, trace);
        let mut scratch = RankScratch::new();
        for _ in 0..passes {
            let sp = state.tracer.start();
            let _ = state.infer_owned_outputs(ep, &plan, &x0, b, &mut scratch);
            state.tracer.end(sp, "pass", "drv", NO_LAYER, NO_CHUNK, 0);
        }
        state
    })
    .unwrap_or_else(|f| panic!("trace run failed: {f}"));
    let wall_secs = sw.elapsed_secs();

    // drift: replay the same plan through the α-β + calibrated-rate model
    let comp = if cfg.calibrate {
        ComputeModel::calibrate()
    } else {
        ComputeModel::haswell_defaults()
    };
    let modeled = replay(&net.layers, &part, &plan, &ReplayConfig::inference(comp, b));
    let mut maxed = PhaseTimer::new();
    for state in &run.outputs {
        maxed.merge_max(&state.timer);
    }
    let drift = TraceDrift {
        measured_spmv_secs: maxed.get_secs("spmv"),
        modeled_spmv_secs: modeled.spmv * passes as f64,
        measured_comm_secs: maxed.get_secs("comm") + maxed.get_secs("wait"),
        modeled_comm_secs: modeled.comm * passes as f64,
    };

    let tracks: Vec<(String, Vec<crate::obs::Span>)> = run
        .outputs
        .iter()
        .map(|state| (format!("rank {}", state.tracer.rank()), state.tracer.spans()))
        .collect();
    let coverage: Vec<f64> = tracks.iter().map(|(_, s)| span_coverage(s)).collect();
    let spans: usize = tracks.iter().map(|(_, s)| s.len()).sum();
    let dropped: u64 = run.outputs.iter().map(|state| state.tracer.dropped()).sum();

    let chrome = chrome_trace_json(&tracks);
    let min_cov = coverage.iter().copied().fold(f64::INFINITY, f64::min);
    let cov_list: Vec<String> = coverage.iter().map(|c| format!("{c:.4}")).collect();
    let meta = format!(
        "\"spdnn\":{{\"mode\":\"{}\",\"neurons\":{},\"layers\":{},\"ranks\":{},\"batch\":{},\
         \"passes\":{},\"wall_secs\":{:.6},\"spans\":{},\"dropped\":{},\"coverage\":{:.4},\
         \"coverage_per_rank\":[{}],\"drift\":{{\"measured_spmv_secs\":{:.6},\
         \"modeled_spmv_secs\":{:.6},\"spmv_ratio\":{:.3},\"measured_comm_secs\":{:.6},\
         \"modeled_comm_secs\":{:.6},\"comm_ratio\":{:.3}}}}}",
        cfg.mode.label(),
        cfg.neurons,
        cfg.layers,
        cfg.ranks,
        b,
        passes,
        wall_secs,
        spans,
        dropped,
        min_cov,
        cov_list.join(","),
        drift.measured_spmv_secs,
        drift.modeled_spmv_secs,
        drift.spmv_ratio(),
        drift.measured_comm_secs,
        drift.modeled_comm_secs,
        drift.comm_ratio(),
    );
    // splice the metadata key into the Chrome JSON object
    let json = format!("{{{meta},{}", &chrome[1..]);

    TraceReport {
        mode: cfg.mode.label(),
        ranks: cfg.ranks,
        batch: b,
        passes,
        wall_secs,
        coverage,
        spans,
        dropped,
        drift,
        json,
    }
}

/// Human summary for the CLI.
pub fn render(rep: &TraceReport) -> String {
    format!(
        "{} engine, {} ranks × {} passes (b={}): {:.3}s wall\n\
         spans: {} recorded, {} dropped | min rank coverage {:.1}%\n\
         drift vs replay model: spmv {:.3}s measured / {:.3}s modeled ({:.2}x), \
         comm {:.3}s / {:.3}s ({:.2}x)",
        rep.mode,
        rep.ranks,
        rep.passes,
        rep.batch,
        rep.wall_secs,
        rep.spans,
        rep.dropped,
        rep.min_coverage() * 100.0,
        rep.drift.measured_spmv_secs,
        rep.drift.modeled_spmv_secs,
        rep.drift.spmv_ratio(),
        rep.drift.measured_comm_secs,
        rep.drift.modeled_comm_secs,
        rep.drift.comm_ratio(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TraceConfig {
        TraceConfig {
            neurons: 64,
            layers: 3,
            ranks: 2,
            batch: 4,
            passes: 2,
            mode: ExecMode::Overlap,
            codec: Codec::F32,
            capacity: 4096,
            calibrate: false,
        }
    }

    #[test]
    fn capture_produces_covered_chrome_json() {
        let rep = run(&tiny());
        assert!(rep.spans > 0, "no spans recorded");
        assert_eq!(rep.coverage.len(), 2);
        // the per-pass driver spans alone cover the whole window
        assert!(rep.min_coverage() > 0.9, "coverage {}", rep.min_coverage());
        assert!(rep.json.contains("\"traceEvents\""));
        assert!(rep.json.contains("\"spdnn\""));
        assert!(rep.json.contains("\"coverage\""));
        assert!(rep.json.contains("\"name\":\"pass\""));
        assert!(rep.drift.modeled_spmv_secs > 0.0);
    }

    #[test]
    fn pipelined_capture_reconstructs_schedule() {
        let mut cfg = tiny();
        cfg.mode = ExecMode::pipelined();
        let rep = run(&cfg);
        // the pipelined engine's signature spans are all present
        for name in ["spmv.boundary", "post", "epilogue.interior"] {
            assert!(
                rep.json.contains(&format!("\"name\":\"{name}\"")),
                "missing span {name}"
            );
        }
    }
}
