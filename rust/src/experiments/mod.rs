//! Experiment drivers — one per table/figure of the paper's evaluation
//! (Section 6). Shared by the CLI (`spdnn <experiment>`) and the bench
//! harnesses (`cargo bench`).

pub mod ablation;
pub mod chaos;
pub mod fig4_scaling;
pub mod fig5_breakdown;
pub mod graphchallenge;
pub mod replica;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod trace;

use crate::partition::phases::{hypergraph_partition, PhaseConfig};
use crate::partition::random::random_partition;
use crate::partition::DnnPartition;
use crate::radixnet::{generate_structure, RadixNetConfig};
use crate::sparse::Csr;

/// Which partitioner ("H" rows vs "R" rows of the tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Hypergraph,
    Random,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Hypergraph => "H",
            Method::Random => "R",
        }
    }
}

/// Build the benchmark structure for (neurons, layers).
pub fn structure_for(neurons: usize, layers: usize) -> Vec<Csr> {
    let cfg = RadixNetConfig::graph_challenge(neurons, layers)
        .unwrap_or_else(|| panic!("unsupported neuron count {neurons}"));
    generate_structure(&cfg)
}

/// Partition with the given method.
pub fn partition_with(structure: &[Csr], method: Method, nparts: usize, seed: u64) -> DnnPartition {
    match method {
        Method::Hypergraph => {
            let mut cfg = PhaseConfig::new(nparts);
            cfg.seed = seed;
            hypergraph_partition(structure, &cfg)
        }
        Method::Random => random_partition(structure, nparts, seed),
    }
}

/// Simple fixed-width table printer.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn sci(x: f64) -> String {
    format!("{x:.2E}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["P", "vol"]);
        t.row(vec!["32".into(), "1.5".into()]);
        t.row(vec!["512".into(), "12.25".into()]);
        let s = t.render();
        assert!(s.contains("P"));
        assert!(s.contains("512"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn partition_with_both_methods() {
        let s = structure_for(64, 3);
        let h = partition_with(&s, Method::Hypergraph, 4, 1);
        let r = partition_with(&s, Method::Random, 4, 1);
        h.validate(&s).unwrap();
        r.validate(&s).unwrap();
        assert_eq!(Method::Hypergraph.label(), "H");
        assert_eq!(Method::Random.label(), "R");
    }
}
