//! Table 1 — communication volume / message counts / computational
//! imbalance, H-SGD vs SGD (random), over processor counts and network
//! sizes.
//!
//! Units (matching the magnitudes of the paper's table): Volume = thousands
//! of words sent per processor per SGD iteration (SpFF + SpBP over all L
//! layers); Messages = thousands of point-to-point messages per processor
//! per iteration; imb = max/avg computational load.

use super::{f2, partition_with, structure_for, Method, Table};
use crate::partition::metrics::PartitionMetrics;

/// One (N, P) cell of Table 1 for one method.
#[derive(Debug, Clone)]
pub struct Cell {
    pub method: Method,
    pub avg_vol_k: f64,
    pub max_vol_k: f64,
    pub avg_msg_k: f64,
    pub max_msg_k: f64,
    pub imb: f64,
}

/// One (N, P) row pair: H and R plus the H/R ratios.
#[derive(Debug, Clone)]
pub struct RowPair {
    pub neurons: usize,
    pub nparts: usize,
    pub h: Cell,
    pub r: Cell,
}

impl RowPair {
    pub fn ratio_avg_vol(&self) -> f64 {
        safe_ratio(self.h.avg_vol_k, self.r.avg_vol_k)
    }
    pub fn ratio_max_vol(&self) -> f64 {
        safe_ratio(self.h.max_vol_k, self.r.max_vol_k)
    }
    pub fn ratio_avg_msg(&self) -> f64 {
        safe_ratio(self.h.avg_msg_k, self.r.avg_msg_k)
    }
    pub fn ratio_max_msg(&self) -> f64 {
        safe_ratio(self.h.max_msg_k, self.r.max_msg_k)
    }
}

fn safe_ratio(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        if a == 0.0 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        a / b
    }
}

fn cell(structure: &[crate::sparse::Csr], method: Method, p: usize, seed: u64) -> Cell {
    let part = partition_with(structure, method, p, seed);
    let m = PartitionMetrics::compute(structure, &part);
    Cell {
        method,
        avg_vol_k: m.avg_volume() / 1e3,
        max_vol_k: m.max_volume() / 1e3,
        avg_msg_k: m.avg_msgs() / 1e3,
        max_msg_k: m.max_msgs() / 1e3,
        imb: m.comp_imbalance(),
    }
}

/// Run the experiment for one network size across processor counts.
pub fn run(neurons: usize, layers: usize, parts: &[usize], seed: u64) -> Vec<RowPair> {
    let structure = structure_for(neurons, layers);
    parts
        .iter()
        .map(|&p| RowPair {
            neurons,
            nparts: p,
            h: cell(&structure, Method::Hypergraph, p, seed),
            r: cell(&structure, Method::Random, p, seed),
        })
        .collect()
}

/// Render rows in the paper's three-line-per-P format.
pub fn render(rows: &[RowPair]) -> String {
    let mut t = Table::new(&[
        "N", "P", "", "VolAvg(K)", "VolMax(K)", "MsgAvg(K)", "MsgMax(K)", "imb",
    ]);
    for rp in rows {
        t.row(vec![
            rp.neurons.to_string(),
            rp.nparts.to_string(),
            "H/R".into(),
            f2(rp.ratio_avg_vol()),
            f2(rp.ratio_max_vol()),
            f2(rp.ratio_avg_msg()),
            f2(rp.ratio_max_msg()),
            "".into(),
        ]);
        for c in [&rp.h, &rp.r] {
            t.row(vec![
                "".into(),
                "".into(),
                c.method.label().into(),
                f2(c.avg_vol_k),
                f2(c.max_vol_k),
                f2(c.avg_msg_k),
                f2(c.max_msg_k),
                f2(c.imb),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h_ratio_below_one_on_benchmark() {
        let rows = run(256, 6, &[4, 8], 1);
        for rp in &rows {
            assert!(
                rp.ratio_avg_vol() < 0.9,
                "P={}: ratio {}",
                rp.nparts,
                rp.ratio_avg_vol()
            );
            assert!(rp.h.imb >= 1.0 && rp.r.imb >= 1.0);
        }
        let s = render(&rows);
        assert!(s.contains("H/R"));
    }

    #[test]
    fn volume_grows_sublinearly_with_p_for_h() {
        let rows = run(256, 6, &[2, 8], 2);
        // total volume grows with P; per-rank volume shrinks or stays flat
        assert!(rows[1].h.avg_vol_k * 8.0 > rows[0].h.avg_vol_k * 2.0);
    }
}
