//! Table 3 — partitioning (preprocessing) times in seconds, per network
//! size and processor count. Measured live on this host.

use super::{structure_for, Table};
use crate::partition::phases::{hypergraph_partition, PhaseConfig};
use crate::util::Stopwatch;

#[derive(Debug, Clone)]
pub struct Row {
    pub neurons: usize,
    pub nparts: usize,
    pub secs: f64,
}

pub fn run(neurons: usize, layers: usize, parts: &[usize], seed: u64) -> Vec<Row> {
    let structure = structure_for(neurons, layers);
    parts
        .iter()
        .map(|&p| {
            let mut cfg = PhaseConfig::new(p);
            cfg.seed = seed;
            let sw = Stopwatch::start();
            let part = hypergraph_partition(&structure, &cfg);
            let secs = sw.elapsed_secs();
            part.validate(&structure).unwrap();
            Row {
                neurons,
                nparts: p,
                secs,
            }
        })
        .collect()
}

pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&["N", "P", "Partitioning time (s)"]);
    for r in rows {
        t.row(vec![
            r.neurons.to_string(),
            r.nparts.to_string(),
            format!("{:.2}", r.secs),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_recorded_and_grow_with_p() {
        let rows = run(256, 4, &[2, 16], 1);
        assert!(rows.iter().all(|r| r.secs > 0.0));
        assert!(render(&rows).contains("Partitioning"));
    }
}
