//! `spdnn chaos` — chaos-engineering smoke driver: run the serving pool
//! under a seeded fault stream (injected panics, stalls, dropped sends,
//! payload bit-flips) and report how the recovery pipeline held up. The
//! CI bench-smoke step runs this with `SPDNN_ENFORCE=1`, which turns the
//! acceptance bars into hard failures ([`enforce`]):
//!
//! - every submitted ticket resolves (100 % resolution, zero unresolved —
//!   faults must never deadlock the pool);
//! - every `Ok` reply is bit-identical-tolerance equal to the serial
//!   engine (faults never corrupt a served answer — corruption is
//!   detected and retried, or failed with a typed error);
//! - generation respawns never exceed the injected-fault budget (no
//!   respawn storms);
//! - after the stream is disarmed, a clean tail of requests all succeed
//!   (the pool heals completely).
//!
//! The report is written as `BENCH_chaos.json` (see `docs/BENCHMARKS.md`
//! for the schema and `docs/ROBUSTNESS.md` for the fault taxonomy).

use crate::coordinator::ExecMode;
use crate::dnn::inference::infer_batch;
use crate::radixnet::{generate, RadixNetConfig};
use crate::runtime::fault::{FaultPlan, FaultSpec};
use crate::serving::{PoolConfig, RankPool, RecoveryConfig, ServeError, Ticket};
use crate::util::{Rng, Stopwatch};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Workload shape and fault rates for one chaos run.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    pub neurons: usize,
    pub layers: usize,
    pub ranks: usize,
    /// Requests submitted while the fault stream is armed.
    pub requests: usize,
    pub mode: ExecMode,
    /// The seeded fault plan driving the failpoints.
    pub spec: FaultSpec,
    /// Requeue attempts granted to each ticket
    /// ([`RecoveryConfig::retry_budget`]).
    pub retry_budget: u32,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            neurons: 64,
            layers: 3,
            ranks: 4,
            requests: 200,
            mode: ExecMode::pipelined(),
            spec: FaultSpec {
                seed: 42,
                delay_p: 0.02,
                delay_us: 100,
                panic_p: 0.01,
                stall_p: 0.005,
                stall_ms: 400,
                flip_p: 0.01,
                drop_p: 0.005,
                watchdog_ms: 150,
                budget: 12,
                ..FaultSpec::default()
            },
            retry_budget: 3,
        }
    }
}

/// Outcome counts and recovery counters of one chaos run.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub requests: u64,
    /// Tickets served correctly (verified against the serial engine).
    pub ok: u64,
    /// Tickets resolved to a typed `RankFailure` (retry budget exhausted).
    pub failed_rank: u64,
    /// Tickets fast-failed by an open circuit breaker.
    pub failed_unavailable: u64,
    /// Tickets that never resolved within the driver deadline — any value
    /// above zero means the pool deadlocked under chaos.
    pub unresolved: u64,
    /// Faults actually consumed from the plan's budget.
    pub injected: u64,
    /// Generation respawns completed.
    pub respawns: u64,
    /// Ticket requeues absorbed by the retry budget.
    pub retries: u64,
    pub watchdog_trips: u64,
    pub checksum_failures: u64,
    /// Resolved tickets / submitted tickets — the headline bar (1.0).
    pub resolution_rate: f64,
    /// p95 submit→resolve latency over the chaos stream (ms) — includes
    /// requeue + respawn + backoff time for retried tickets.
    pub recovery_p95_ms: f64,
    /// All 10 post-disarm requests served correctly.
    pub clean_tail_ok: bool,
    pub wall_secs: f64,
}

fn random_input(rng: &mut Rng, n: usize, b: usize) -> Vec<f32> {
    (0..n * b)
        .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
        .collect()
}

fn matches_serial(out: &[f32], serial: &[f32]) -> bool {
    out.len() == serial.len()
        && out
            .iter()
            .zip(serial.iter())
            .all(|(a, s)| (a - s).abs() < 1e-5)
}

/// Poll one ticket to resolution with a hard deadline; `None` = the
/// ticket never resolved (the pool is stuck).
fn resolve(t: &Ticket, deadline: Duration) -> Option<Result<Vec<f32>, ServeError>> {
    let start = Instant::now();
    loop {
        if let Some(reply) = t.poll() {
            return Some(reply);
        }
        if start.elapsed() >= deadline {
            return None;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Run one chaos stream: submit `cfg.requests` under the armed fault
/// plan, resolve every ticket, disarm, serve a clean tail, and collect
/// the recovery counters.
pub fn run(cfg: &ChaosConfig) -> ChaosReport {
    let net = generate(
        &RadixNetConfig::graph_challenge(cfg.neurons, cfg.layers)
            .unwrap_or_else(|| panic!("unsupported neuron count {}", cfg.neurons)),
    );
    let plan = FaultPlan::new(cfg.spec);
    let pool = RankPool::start(
        net.clone(),
        PoolConfig {
            nranks: cfg.ranks,
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            adaptive: true,
            mode: cfg.mode,
            faults: Some(Arc::clone(&plan)),
            recovery: RecoveryConfig {
                retry_budget: cfg.retry_budget,
                backoff_base: Duration::from_millis(2),
                backoff_cap: Duration::from_millis(20),
                // the smoke measures requeue/respawn behaviour; a breaker
                // that never opens keeps the bars deterministic
                breaker_threshold: 64,
                breaker_cooldown: Duration::from_millis(100),
            },
            ..PoolConfig::default()
        },
    );
    let sw = Stopwatch::start();
    let mut rng = Rng::new(cfg.spec.seed ^ 0xC4A0_5EED);
    let mut inflight: Vec<(Vec<f32>, usize, Instant, Ticket)> =
        Vec::with_capacity(cfg.requests);
    for r in 0..cfg.requests {
        let b = 1 + (r % 4);
        let x0 = random_input(&mut rng, cfg.neurons, b);
        let t = pool.submit(x0.clone(), b);
        inflight.push((x0, b, Instant::now(), t));
    }

    let deadline = Duration::from_secs(60);
    let (mut ok, mut failed_rank, mut failed_unavailable, mut unresolved) = (0u64, 0u64, 0u64, 0u64);
    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.requests);
    for (r, (x0, b, submitted, t)) in inflight.iter().enumerate() {
        if unresolved > 0 {
            // the pool already deadlocked; count the rest without waiting
            unresolved += 1;
            continue;
        }
        match resolve(t, deadline) {
            Some(Ok(out)) => {
                let serial = infer_batch(&net, x0, *b);
                assert!(
                    matches_serial(&out, &serial),
                    "chaos req {r}: served output diverged from the serial engine"
                );
                ok += 1;
                latencies.push(submitted.elapsed().as_secs_f64());
            }
            Some(Err(e)) => {
                if e.is_unavailable() {
                    failed_unavailable += 1;
                } else {
                    failed_rank += 1;
                }
                latencies.push(submitted.elapsed().as_secs_f64());
            }
            None => unresolved += 1,
        }
    }

    // the fault stream stops: the pool must heal completely
    plan.disarm();
    let mut clean_tail_ok = unresolved == 0;
    if unresolved == 0 {
        for r in 0..10 {
            let b = 1 + (r % 3);
            let x0 = random_input(&mut rng, cfg.neurons, b);
            let t = pool.submit(x0.clone(), b);
            match resolve(&t, deadline) {
                Some(Ok(out)) => {
                    if !matches_serial(&out, &infer_batch(&net, &x0, b)) {
                        clean_tail_ok = false;
                    }
                }
                _ => clean_tail_ok = false,
            }
        }
    }
    let wall_secs = sw.elapsed_secs();

    let stats = if unresolved == 0 {
        pool.shutdown().expect("first shutdown").stats
    } else {
        // a stuck scheduler cannot be joined; snapshot and leak the pool
        // so the report (and the enforced failure) still comes out
        let s = pool.stats();
        std::mem::forget(pool);
        s
    };
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let recovery_p95_ms = if latencies.is_empty() {
        0.0
    } else {
        let idx = ((0.95 * latencies.len() as f64).ceil() as usize).clamp(1, latencies.len());
        latencies[idx - 1] * 1e3
    };
    let resolved = ok + failed_rank + failed_unavailable;
    ChaosReport {
        requests: cfg.requests as u64,
        ok,
        failed_rank,
        failed_unavailable,
        unresolved,
        injected: plan.injected(),
        respawns: stats.generations_respawned,
        retries: stats.requests_retried,
        watchdog_trips: stats.watchdog_trips,
        checksum_failures: stats.checksum_failures,
        resolution_rate: if cfg.requests == 0 {
            1.0
        } else {
            resolved as f64 / cfg.requests as f64
        },
        recovery_p95_ms,
        clean_tail_ok,
        wall_secs,
    }
}

/// The enforced CI bars (`SPDNN_ENFORCE=1`).
pub fn enforce(rep: &ChaosReport) {
    assert_eq!(rep.unresolved, 0, "chaos bar: {} tickets never resolved", rep.unresolved);
    assert!(
        (rep.resolution_rate - 1.0).abs() < 1e-12,
        "chaos bar: resolution rate {} < 1.0",
        rep.resolution_rate
    );
    assert!(
        rep.respawns <= rep.injected,
        "chaos bar: {} respawns exceed {} injected faults",
        rep.respawns,
        rep.injected
    );
    assert!(rep.clean_tail_ok, "chaos bar: pool did not heal after disarm");
}

/// Human summary for the CLI.
pub fn render(rep: &ChaosReport) -> String {
    format!(
        "{} requests under chaos in {:.2}s: {} ok, {} failed (rank), {} failed \
         (breaker), {} unresolved — resolution {:.1}%\n\
         faults: {} injected | {} retries absorbed | {} respawns | \
         {} watchdog trips | {} checksum failures\n\
         p95 submit->resolve {:.2} ms | clean tail after disarm: {}",
        rep.requests,
        rep.wall_secs,
        rep.ok,
        rep.failed_rank,
        rep.failed_unavailable,
        rep.unresolved,
        rep.resolution_rate * 100.0,
        rep.injected,
        rep.retries,
        rep.respawns,
        rep.watchdog_trips,
        rep.checksum_failures,
        rep.recovery_p95_ms,
        if rep.clean_tail_ok { "ok" } else { "FAILED" },
    )
}

/// Machine-readable JSON (the CI smoke job writes `BENCH_chaos.json`).
pub fn to_json(rep: &ChaosReport) -> String {
    format!(
        "{{\"requests\":{},\"ok\":{},\"failed_rank\":{},\"failed_unavailable\":{},\
         \"unresolved\":{},\"resolution_rate\":{:.6},\"injected\":{},\"respawns\":{},\
         \"retries\":{},\"watchdog_trips\":{},\"checksum_failures\":{},\
         \"recovery_p95_ms\":{:.4},\"clean_tail_ok\":{},\"wall_secs\":{:.4}}}",
        rep.requests,
        rep.ok,
        rep.failed_rank,
        rep.failed_unavailable,
        rep.unresolved,
        rep.resolution_rate,
        rep.injected,
        rep.respawns,
        rep.retries,
        rep.watchdog_trips,
        rep.checksum_failures,
        rep.recovery_p95_ms,
        rep.clean_tail_ok,
        rep.wall_secs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_chaos_run_clears_the_bars() {
        let cfg = ChaosConfig {
            requests: 30,
            ranks: 2,
            spec: FaultSpec {
                seed: 7,
                panic_p: 0.05,
                stall_p: 0.01,
                stall_ms: 250,
                flip_p: 0.02,
                drop_p: 0.02,
                watchdog_ms: 100,
                budget: 3,
                ..FaultSpec::default()
            },
            ..ChaosConfig::default()
        };
        let rep = run(&cfg);
        enforce(&rep);
        assert_eq!(rep.requests, 30);
        assert_eq!(rep.ok + rep.failed_rank + rep.failed_unavailable, 30);
        assert!(rep.injected <= 3, "budget bound: {}", rep.injected);
        let json = to_json(&rep);
        assert!(json.contains("\"resolution_rate\":1.000000"));
        assert!(json.contains("\"clean_tail_ok\":true"));
        assert!(render(&rep).contains("resolution 100.0%"));
    }
}
