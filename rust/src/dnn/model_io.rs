//! Whole-network persistence in the Graph Challenge layout: one TSV triple
//! file per layer (`n<neurons>-l<layer>.tsv`, 1-based indices) plus a small
//! metadata file — the on-disk format the benchmark's reference data uses,
//! so externally downloaded Graph Challenge networks drop in directly.

use crate::bail;
use crate::dnn::{Activation, SparseNet};
use crate::sparse::io::{read_tsv, write_tsv};
use crate::util::error::{Context, Error, Result};
use std::path::Path;

/// Save a network into `dir` (created if needed).
pub fn save_network(net: &SparseNet, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    let n = net.input_dim();
    for (k, w) in net.layers.iter().enumerate() {
        write_tsv(w, &dir.join(format!("n{}-l{}.tsv", n, k + 1)))?;
    }
    let meta = format!(
        "neurons\t{}\nlayers\t{}\nactivation\t{}\n",
        n,
        net.depth(),
        net.activation.name()
    );
    std::fs::write(dir.join("meta.tsv"), meta)?;
    // biases: one file, `layer \t neuron \t value`, only nonzeros
    let mut bias_lines = String::new();
    for (k, b) in net.biases.iter().enumerate() {
        for (i, v) in b.iter().enumerate() {
            if *v != 0.0 {
                bias_lines.push_str(&format!("{}\t{}\t{}\n", k + 1, i + 1, v));
            }
        }
    }
    std::fs::write(dir.join("biases.tsv"), bias_lines)?;
    Ok(())
}

/// Load a network saved by [`save_network`] (or hand-assembled in the same
/// layout from Graph Challenge reference data).
pub fn load_network(dir: &Path) -> Result<SparseNet> {
    let meta = std::fs::read_to_string(dir.join("meta.tsv"))
        .with_context(|| format!("read {dir:?}/meta.tsv"))?;
    let mut neurons = 0usize;
    let mut layers = 0usize;
    let mut activation = Activation::Sigmoid;
    for line in meta.lines() {
        let mut it = line.split_ascii_whitespace();
        match (it.next(), it.next()) {
            (Some("neurons"), Some(v)) => neurons = v.parse()?,
            (Some("layers"), Some(v)) => layers = v.parse()?,
            (Some("activation"), Some(v)) => {
                activation = Activation::from_name(v)
                    .with_context(|| format!("unknown activation {v}"))?
            }
            _ => {}
        }
    }
    if neurons == 0 || layers == 0 {
        bail!("meta.tsv missing neurons/layers");
    }
    let mut ws = Vec::with_capacity(layers);
    for k in 0..layers {
        let p = dir.join(format!("n{}-l{}.tsv", neurons, k + 1));
        ws.push(read_tsv(&p, neurons, neurons)?);
    }
    let mut net = SparseNet::new(ws, activation);
    if let Ok(bias_txt) = std::fs::read_to_string(dir.join("biases.tsv")) {
        for (lineno, line) in bias_txt.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split_ascii_whitespace();
            let (k, i, v) = match (it.next(), it.next(), it.next()) {
                (Some(k), Some(i), Some(v)) => (k, i, v),
                _ => bail!("biases.tsv:{}: malformed", lineno + 1),
            };
            let k: usize = k.parse()?;
            let i: usize = i.parse()?;
            let v: f32 = v.parse()?;
            net.biases[k - 1][i - 1] = v;
        }
    }
    net.validate().map_err(Error::msg)?;
    Ok(net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{generate, RadixNetConfig};

    #[test]
    fn roundtrip_preserves_network() {
        let mut net = generate(&RadixNetConfig::graph_challenge(64, 3).unwrap());
        net.biases[1][5] = 0.75;
        let dir = std::env::temp_dir().join("spdnn_model_io_test");
        let _ = std::fs::remove_dir_all(&dir);
        save_network(&net, &dir).unwrap();
        let loaded = load_network(&dir).unwrap();
        assert_eq!(net.depth(), loaded.depth());
        assert_eq!(net.activation, loaded.activation);
        for k in 0..net.depth() {
            assert_eq!(net.layers[k], loaded.layers[k]);
            assert_eq!(net.biases[k], loaded.biases[k]);
        }
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(load_network(Path::new("/nonexistent/spdnn")).is_err());
    }

    #[test]
    fn loaded_network_infers_identically() {
        let net = generate(&RadixNetConfig::graph_challenge(64, 4).unwrap());
        let dir = std::env::temp_dir().join("spdnn_model_io_test2");
        let _ = std::fs::remove_dir_all(&dir);
        save_network(&net, &dir).unwrap();
        let loaded = load_network(&dir).unwrap();
        let x: Vec<f32> = (0..64).map(|i| (i % 2) as f32).collect();
        let a = crate::dnn::inference::infer(&net, &x);
        let b = crate::dnn::inference::infer(&loaded, &x);
        assert_eq!(a, b);
    }
}
