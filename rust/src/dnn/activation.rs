//! Activation functions and their derivatives.
//!
//! The paper's experiments use the sigmoid (Section 6.1); ReLU is provided
//! for the Graph Challenge inference configuration, which clips activations.

/// Supported element-wise nonlinearities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Sigmoid,
    Relu,
    /// Graph Challenge variant: ReLU clipped to [0, 32] after a bias shift.
    ReluClip,
    /// Identity (for tests / linear probes).
    Identity,
}

impl Activation {
    /// f(z) applied in place.
    pub fn apply(&self, z: &mut [f32]) {
        match self {
            Activation::Sigmoid => {
                for v in z.iter_mut() {
                    *v = 1.0 / (1.0 + (-*v).exp());
                }
            }
            Activation::Relu => {
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            Activation::ReluClip => {
                for v in z.iter_mut() {
                    *v = v.max(0.0).min(32.0);
                }
            }
            Activation::Identity => {}
        }
    }

    /// f'(z) given *the output* y = f(z). For sigmoid this is the classic
    /// y(1-y); for (clipped) ReLU the subgradient from the output.
    pub fn derivative_from_output(&self, y: f32) -> f32 {
        match self {
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::ReluClip => {
                if y > 0.0 && y < 32.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Identity => 1.0,
        }
    }

    /// out[i] = s[i] * f'(z[i]) computed from outputs y (the `⊙ f'(z)` of
    /// Eqs. (6)–(7)).
    pub fn mul_derivative(&self, s: &[f32], y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(s.len(), y.len());
        debug_assert_eq!(s.len(), out.len());
        for i in 0..s.len() {
            out[i] = s[i] * self.derivative_from_output(y[i]);
        }
    }

    /// Epilogue for [`crate::sparse::Csr::spmm_fused_rowmajor`]: add the
    /// per-row bias, then apply this activation — the fusion every batched
    /// forward path (serial, per-rank, minibatch) shares.
    pub fn fused_bias_epilogue(self, bias: &[f32]) -> impl FnMut(usize, &mut [f32]) + '_ {
        move |r, tile| {
            let b = bias[r];
            for v in tile.iter_mut() {
                *v += b;
            }
            self.apply(tile);
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "sigmoid" => Some(Activation::Sigmoid),
            "relu" => Some(Activation::Relu),
            "reluclip" | "relu_clip" => Some(Activation::ReluClip),
            "identity" | "linear" => Some(Activation::Identity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Sigmoid => "sigmoid",
            Activation::Relu => "relu",
            Activation::ReluClip => "reluclip",
            Activation::Identity => "identity",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_range_and_midpoint() {
        let mut z = vec![0.0, -10.0, 10.0];
        Activation::Sigmoid.apply(&mut z);
        assert!((z[0] - 0.5).abs() < 1e-6);
        assert!(z[1] < 0.01 && z[2] > 0.99);
    }

    #[test]
    fn sigmoid_derivative_matches_finite_difference() {
        let act = Activation::Sigmoid;
        for &z0 in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let h = 1e-3f32;
            let f = |z: f32| 1.0 / (1.0 + (-z).exp());
            let fd = (f(z0 + h) - f(z0 - h)) / (2.0 * h);
            let y = f(z0);
            let an = act.derivative_from_output(y);
            assert!((fd - an).abs() < 1e-3, "z={z0}: {fd} vs {an}");
        }
    }

    #[test]
    fn relu_clip_behaviour() {
        let mut z = vec![-1.0, 5.0, 40.0];
        Activation::ReluClip.apply(&mut z);
        assert_eq!(z, vec![0.0, 5.0, 32.0]);
        assert_eq!(Activation::ReluClip.derivative_from_output(0.0), 0.0);
        assert_eq!(Activation::ReluClip.derivative_from_output(5.0), 1.0);
        assert_eq!(Activation::ReluClip.derivative_from_output(32.0), 0.0);
    }

    #[test]
    fn names_roundtrip() {
        for a in [
            Activation::Sigmoid,
            Activation::Relu,
            Activation::ReluClip,
            Activation::Identity,
        ] {
            assert_eq!(Activation::from_name(a.name()), Some(a));
        }
        assert_eq!(Activation::from_name("nope"), None);
    }

    #[test]
    fn fused_bias_epilogue_adds_then_activates() {
        let bias = [1.0f32, -2.0];
        let mut relu = Activation::Relu.fused_bias_epilogue(&bias);
        let mut row0 = [0.5f32, -3.0];
        relu(0, &mut row0);
        assert_eq!(row0, [1.5, 0.0]); // (0.5+1, -3+1 clamped)
        let mut ident = Activation::Identity.fused_bias_epilogue(&bias);
        let mut row1 = [1.0f32];
        ident(1, &mut row1);
        assert_eq!(row1, [-1.0]);
    }

    #[test]
    fn mul_derivative_identity_passthrough() {
        let s = [1.0, 2.0, 3.0];
        let y = [9.0, 9.0, 9.0];
        let mut out = [0.0; 3];
        Activation::Identity.mul_derivative(&s, &y, &mut out);
        assert_eq!(out, s);
    }
}
