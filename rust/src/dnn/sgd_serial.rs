//! Serial SGD — a direct transcription of the paper's Algorithm 1.
//!
//! This is the ground-truth oracle: the distributed coordinator
//! (`coordinator::sgd`) must produce the same weights for any partitioning
//! and any processor count (integration-tested in `rust/tests/`).

use crate::dnn::network::SparseNet;

/// Per-step trace returned by [`sgd_step`].
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// Loss J(x^L, y) evaluated on the forward pass (pre-update weights).
    pub loss: f32,
    /// Activations x^0..x^L (x^0 is the input).
    pub activations: Vec<Vec<f32>>,
}

/// Feedforward only: returns activations x^0..x^L (Alg. 1 lines 2–4).
pub fn feedforward(net: &SparseNet, x0: &[f32]) -> Vec<Vec<f32>> {
    assert_eq!(x0.len(), net.input_dim());
    let mut acts = Vec::with_capacity(net.depth() + 1);
    acts.push(x0.to_vec());
    for (k, w) in net.layers.iter().enumerate() {
        let mut z = vec![0f32; w.nrows];
        w.spmv(acts.last().unwrap(), &mut z);
        for (zi, bi) in z.iter_mut().zip(net.biases[k].iter()) {
            *zi += bi;
        }
        net.activation.apply(&mut z);
        acts.push(z);
    }
    acts
}

/// One SGD step on a single (x0, y) pair (Alg. 1 lines 2–9), updating
/// `net` in place. Returns the step trace.
///
/// Ordering note: for each layer k (from L down to 1) the backward product
/// `s = (W^k)^T δ^k` is computed *before* the weight update of `W^k`, which
/// is what both Alg. 1 (line 7 before line 9) and the distributed Alg. 3
/// (line 4 before lines 8–9) do; equivalence tests rely on this.
pub fn sgd_step(net: &mut SparseNet, x0: &[f32], y: &[f32], eta: f32) -> StepTrace {
    assert_eq!(y.len(), net.output_dim());
    let acts = feedforward(net, x0);
    let loss = net.loss.value(acts.last().unwrap(), y);

    // δ^L = ∇_x J ⊙ f'(z^L)  (Eq. 6; f' computed from the stored output)
    let xl = acts.last().unwrap();
    let mut grad = vec![0f32; xl.len()];
    net.loss.gradient(xl, y, &mut grad);
    let mut delta = vec![0f32; xl.len()];
    net.activation.mul_derivative(&grad, xl, &mut delta);

    // Backward over layers L..1
    for k in (0..net.depth()).rev() {
        // s = (W^k)^T δ^k  — before the update
        let w = &net.layers[k];
        let mut s = vec![0f32; w.ncols];
        w.spmv_t_add(&delta, &mut s);

        // ∇W^k = δ^k ⊗ x^{k-1} restricted to the sparsity pattern; update
        net.layers[k].sgd_update(&delta, &acts[k], eta);
        // bias update: ∂J/∂b = δ
        for (b, d) in net.biases[k].iter_mut().zip(delta.iter()) {
            *b -= eta * d;
        }

        if k > 0 {
            // δ^{k-1} = s ⊙ f'(z^{k-1})
            let mut next = vec![0f32; s.len()];
            net.activation.mul_derivative(&s, &acts[k], &mut next);
            delta = next;
        }
    }

    StepTrace {
        loss,
        activations: acts,
    }
}

/// Run `epochs` passes of SGD over a dataset; returns per-step losses.
pub fn train(
    net: &mut SparseNet,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    eta: f32,
    epochs: usize,
) -> Vec<f32> {
    assert_eq!(inputs.len(), targets.len());
    let mut losses = Vec::with_capacity(inputs.len() * epochs);
    for _ in 0..epochs {
        for (x, y) in inputs.iter().zip(targets.iter()) {
            losses.push(sgd_step(net, x, y, eta).loss);
        }
    }
    losses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::activation::Activation;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn random_net(rng: &mut Rng, dims: &[usize], p: f64) -> SparseNet {
        let mut layers = Vec::new();
        for k in 1..dims.len() {
            let mut c = Coo::new(dims[k], dims[k - 1]);
            for r in 0..dims[k] {
                let mut any = false;
                for col in 0..dims[k - 1] {
                    if rng.gen_bool(p) {
                        c.push(r, col, rng.gen_f32_range(-1.0, 1.0));
                        any = true;
                    }
                }
                if !any {
                    // keep every neuron connected so gradients flow
                    c.push(r, rng.gen_range(dims[k - 1]), rng.gen_f32_range(-1.0, 1.0));
                }
            }
            layers.push(c.to_csr());
        }
        SparseNet::new(layers, Activation::Sigmoid)
    }

    #[test]
    fn feedforward_shapes() {
        let mut rng = Rng::new(1);
        let net = random_net(&mut rng, &[4, 5, 3], 0.5);
        let acts = feedforward(&net, &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(acts.len(), 3);
        assert_eq!(acts[0].len(), 4);
        assert_eq!(acts[1].len(), 5);
        assert_eq!(acts[2].len(), 3);
        // sigmoid outputs in (0,1)
        assert!(acts[2].iter().all(|&v| v > 0.0 && v < 1.0));
    }

    #[test]
    fn loss_decreases_with_training() {
        let mut rng = Rng::new(2);
        let mut net = random_net(&mut rng, &[6, 8, 4], 0.6);
        let x: Vec<f32> = (0..6).map(|_| rng.gen_f32()).collect();
        let y = vec![1.0, 0.0, 0.0, 1.0];
        let first = sgd_step(&mut net, &x, &y, 0.5).loss;
        for _ in 0..200 {
            sgd_step(&mut net, &x, &y, 0.5);
        }
        let last = sgd_step(&mut net, &x, &y, 0.5).loss;
        assert!(
            last < first * 0.5,
            "loss did not decrease: {first} -> {last}"
        );
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // Check ∂J/∂W(i,j) for every stored nonzero against central FD.
        let mut rng = Rng::new(3);
        let net0 = random_net(&mut rng, &[3, 4, 2], 0.7);
        let x: Vec<f32> = (0..3).map(|_| rng.gen_f32()).collect();
        let y = vec![0.25, 0.75];
        let eta = 1.0; // so ΔW = -grad

        let mut net = net0.clone();
        sgd_step(&mut net, &x, &y, eta);

        for k in 0..net0.depth() {
            for idx in 0..net0.layers[k].nnz() {
                let analytic = net0.layers[k].vals[idx] - net.layers[k].vals[idx]; // eta*grad
                let h = 1e-2f32;
                let mut p = net0.clone();
                p.layers[k].vals[idx] += h;
                let lp = {
                    let acts = feedforward(&p, &x);
                    p.loss.value(acts.last().unwrap(), &y)
                };
                let mut m = net0.clone();
                m.layers[k].vals[idx] -= h;
                let lm = {
                    let acts = feedforward(&m, &x);
                    m.loss.value(acts.last().unwrap(), &y)
                };
                let fd = (lp - lm) / (2.0 * h);
                assert!(
                    (fd - analytic).abs() < 5e-3,
                    "layer {k} nnz {idx}: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn bias_gradient_matches_finite_difference() {
        let mut rng = Rng::new(4);
        let net0 = random_net(&mut rng, &[3, 3, 2], 0.8);
        let x = vec![0.2, 0.4, 0.9];
        let y = vec![0.1, 0.9];
        let mut net = net0.clone();
        sgd_step(&mut net, &x, &y, 1.0);
        for k in 0..net0.depth() {
            for i in 0..net0.biases[k].len() {
                let analytic = net0.biases[k][i] - net.biases[k][i];
                let h = 1e-2f32;
                let mut p = net0.clone();
                p.biases[k][i] += h;
                let lp = p.loss.value(feedforward(&p, &x).last().unwrap(), &y);
                let mut m = net0.clone();
                m.biases[k][i] -= h;
                let lm = m.loss.value(feedforward(&m, &x).last().unwrap(), &y);
                let fd = (lp - lm) / (2.0 * h);
                assert!(
                    (fd - analytic).abs() < 5e-3,
                    "layer {k} bias {i}: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_returns_all_losses() {
        let mut rng = Rng::new(5);
        let mut net = random_net(&mut rng, &[4, 4, 4], 0.5);
        let inputs = vec![vec![0.1; 4], vec![0.9; 4]];
        let targets = vec![vec![0.0; 4], vec![1.0; 4]];
        let losses = train(&mut net, &inputs, &targets, 0.1, 3);
        assert_eq!(losses.len(), 6);
    }
}
