//! Loss functions. The paper uses mean squared error (Section 6.1).

/// Supported losses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loss {
    /// J = 1/2 Σ (x_i - y_i)^2  (the 1/2 makes ∇J = x - y).
    Mse,
}

impl Loss {
    /// Loss value J(x, y).
    pub fn value(&self, x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            Loss::Mse => {
                0.5 * x
                    .iter()
                    .zip(y.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
            }
        }
    }

    /// ∇_x J into `out`.
    pub fn gradient(&self, x: &[f32], y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        match self {
            Loss::Mse => {
                for i in 0..x.len() {
                    out[i] = x[i] - y[i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_value_and_gradient() {
        let x = [1.0, 2.0];
        let y = [0.0, 0.0];
        assert!((Loss::Mse.value(&x, &y) - 2.5).abs() < 1e-6);
        let mut g = [0.0; 2];
        Loss::Mse.gradient(&x, &y, &mut g);
        assert_eq!(g, [1.0, 2.0]);
    }

    #[test]
    fn mse_gradient_matches_finite_difference() {
        let x = [0.3f32, -0.7, 1.1];
        let y = [0.1f32, 0.2, -0.5];
        let mut g = [0.0; 3];
        Loss::Mse.gradient(&x, &y, &mut g);
        let h = 1e-3f32;
        for i in 0..3 {
            let mut xp = x;
            xp[i] += h;
            let mut xm = x;
            xm[i] -= h;
            let fd = (Loss::Mse.value(&xp, &y) - Loss::Mse.value(&xm, &y)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-2, "{fd} vs {}", g[i]);
        }
    }

    #[test]
    fn zero_loss_at_target() {
        let x = [0.5, 0.5];
        assert_eq!(Loss::Mse.value(&x, &x), 0.0);
    }
}
