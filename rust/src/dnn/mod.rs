//! DNN substrate: model container, activations, losses, the serial SGD
//! oracle (Alg. 1) and serial/batched inference.

pub mod activation;
pub mod conv;
pub mod inference;
pub mod loss;
pub mod model_io;
pub mod network;
pub mod sgd_serial;

pub use activation::Activation;
pub use loss::Loss;
pub use network::SparseNet;
