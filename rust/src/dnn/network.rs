//! Sparse DNN model container.

use crate::dnn::activation::Activation;
use crate::dnn::loss::Loss;
use crate::sparse::Csr;

/// A feedforward sparse DNN: L layers of sparse weight matrices.
///
/// `layers[k]` is `W^{k+1}` in paper notation: `nrows` = neurons in layer
/// k+1, `ncols` = neurons in layer k. Biases are kept as explicit vectors
/// (the paper folds them into the matrix as column 0; an explicit vector is
/// numerically identical and keeps the hypergraph model cleaner).
#[derive(Debug, Clone)]
pub struct SparseNet {
    pub layers: Vec<Csr>,
    pub biases: Vec<Vec<f32>>,
    pub activation: Activation,
    pub loss: Loss,
}

impl SparseNet {
    pub fn new(layers: Vec<Csr>, activation: Activation) -> Self {
        // default zero biases
        let biases = layers.iter().map(|w| vec![0f32; w.nrows]).collect();
        Self {
            layers,
            biases,
            activation,
            loss: Loss::Mse,
        }
    }

    pub fn with_biases(mut self, biases: Vec<Vec<f32>>) -> Self {
        assert_eq!(biases.len(), self.layers.len());
        for (b, w) in biases.iter().zip(self.layers.iter()) {
            assert_eq!(b.len(), w.nrows);
        }
        self.biases = biases;
        self
    }

    /// Number of layers L.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Input dimension (neurons in layer 0).
    pub fn input_dim(&self) -> usize {
        self.layers.first().map(|w| w.ncols).unwrap_or(0)
    }

    /// Output dimension (neurons in layer L).
    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|w| w.nrows).unwrap_or(0)
    }

    /// Total number of connections (nonzeros) — "edges" in Graph Challenge
    /// throughput terms.
    pub fn total_nnz(&self) -> usize {
        self.layers.iter().map(|w| w.nnz()).sum()
    }

    /// Structural validation: chained dimensions + per-matrix invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err("empty network".into());
        }
        for (k, w) in self.layers.iter().enumerate() {
            w.validate().map_err(|e| format!("layer {k}: {e}"))?;
            if k > 0 && w.ncols != self.layers[k - 1].nrows {
                return Err(format!(
                    "layer {k} ncols {} != layer {} nrows {}",
                    w.ncols,
                    k - 1,
                    self.layers[k - 1].nrows
                ));
            }
            if self.biases[k].len() != w.nrows {
                return Err(format!("layer {k} bias length mismatch"));
            }
        }
        Ok(())
    }

    /// Memory footprint of the model in bytes (CSR arrays + biases). Used by
    /// the Table-2 GB-baseline memory-capacity model.
    pub fn model_bytes(&self) -> usize {
        let mut b = 0usize;
        for w in &self.layers {
            b += w.indptr.len() * 4 + w.indices.len() * 4 + w.vals.len() * 4;
        }
        for bias in &self.biases {
            b += bias.len() * 4;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn tiny_net() -> SparseNet {
        // 2 layers over 3 neurons each
        let mut w1 = Coo::new(3, 3);
        w1.push(0, 0, 0.5);
        w1.push(1, 1, 0.5);
        w1.push(2, 2, 0.5);
        let mut w2 = Coo::new(3, 3);
        w2.push(0, 1, 1.0);
        w2.push(1, 2, 1.0);
        w2.push(2, 0, 1.0);
        SparseNet::new(vec![w1.to_csr(), w2.to_csr()], Activation::Sigmoid)
    }

    #[test]
    fn dims_and_nnz() {
        let n = tiny_net();
        assert_eq!(n.depth(), 2);
        assert_eq!(n.input_dim(), 3);
        assert_eq!(n.output_dim(), 3);
        assert_eq!(n.total_nnz(), 6);
        assert!(n.validate().is_ok());
    }

    #[test]
    fn validate_catches_dim_mismatch() {
        let mut n = tiny_net();
        n.layers[1] = Csr::zeros(3, 4); // ncols 4 != 3
        assert!(n.validate().is_err());
    }

    #[test]
    fn model_bytes_positive() {
        let n = tiny_net();
        assert!(n.model_bytes() > 0);
    }
}
