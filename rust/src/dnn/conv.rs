//! Convolution layers as sparse (Toeplitz) matrices — the §5.1 CNN
//! extension.
//!
//! "These layers can be implemented as matrix-vector multiplications
//! through constructing Toeplitz matrices that capture [the] convolution
//! operation … Application of sparsification/pruning to CNNs induces
//! sparsification on the corresponding Toeplitz matrices, making the
//! proposed hypergraph model applicable to such cases."
//!
//! A 2-D valid convolution over an `h×w` image with a `kh×kw` kernel and
//! stride `s` becomes a `(oh·ow) × (h·w)` doubly-blocked Toeplitz matrix;
//! pruning kernel taps drops the corresponding diagonals. Average pooling
//! is the same construction with a constant kernel.

use crate::sparse::{Coo, Csr};

/// Output side length of a valid convolution.
pub fn conv_out(dim: usize, k: usize, stride: usize) -> usize {
    assert!(dim >= k && stride >= 1);
    (dim - k) / stride + 1
}

/// Build the Toeplitz matrix of a valid 2-D convolution.
///
/// `kernel` is `kh×kw` row-major; taps that are exactly 0.0 are treated as
/// pruned (no nonzero stored — this is how CNN pruning shows up in the
/// matrix, per §5.1). The result maps a flattened `h×w` image to the
/// flattened `oh×ow` output.
pub fn conv2d_toeplitz(
    h: usize,
    w: usize,
    kernel: &[f32],
    kh: usize,
    kw: usize,
    stride: usize,
) -> Csr {
    assert_eq!(kernel.len(), kh * kw);
    let oh = conv_out(h, kh, stride);
    let ow = conv_out(w, kw, stride);
    let mut coo = Coo::with_capacity(oh * ow, h * w, oh * ow * kh * kw);
    for oy in 0..oh {
        for ox in 0..ow {
            let orow = oy * ow + ox;
            for ky in 0..kh {
                for kx in 0..kw {
                    let v = kernel[ky * kw + kx];
                    if v == 0.0 {
                        continue; // pruned tap
                    }
                    let iy = oy * stride + ky;
                    let ix = ox * stride + kx;
                    coo.push(orow, iy * w + ix, v);
                }
            }
        }
    }
    coo.to_csr()
}

/// Average-pooling as a Toeplitz matrix (constant kernel 1/(k·k)).
pub fn avg_pool_toeplitz(h: usize, w: usize, k: usize) -> Csr {
    let kernel = vec![1.0 / (k * k) as f32; k * k];
    conv2d_toeplitz(h, w, &kernel, k, k, k)
}

/// Direct (reference) valid 2-D convolution, for tests.
pub fn conv2d_direct(
    img: &[f32],
    h: usize,
    w: usize,
    kernel: &[f32],
    kh: usize,
    kw: usize,
    stride: usize,
) -> Vec<f32> {
    let oh = conv_out(h, kh, stride);
    let ow = conv_out(w, kw, stride);
    let mut out = vec![0f32; oh * ow];
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0f32;
            for ky in 0..kh {
                for kx in 0..kw {
                    acc += kernel[ky * kw + kx] * img[(oy * stride + ky) * w + ox * stride + kx];
                }
            }
            out[oy * ow + ox] = acc;
        }
    }
    out
}

/// Prune the smallest-magnitude fraction `frac` of a kernel (sets taps to
/// zero) — the sparsification step that makes CNN Toeplitz layers sparse.
pub fn prune_kernel(kernel: &mut [f32], frac: f64) {
    let mut order: Vec<usize> = (0..kernel.len()).collect();
    order.sort_by(|&a, &b| kernel[a].abs().partial_cmp(&kernel[b].abs()).unwrap());
    let cut = ((kernel.len() as f64) * frac).round() as usize;
    for &i in order.iter().take(cut) {
        kernel[i] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn toeplitz_matches_direct_conv() {
        prop::check(|rng| {
            let h = 4 + rng.gen_range(8);
            let w = 4 + rng.gen_range(8);
            let kh = 1 + rng.gen_range(3.min(h));
            let kw = 1 + rng.gen_range(3.min(w));
            let stride = 1 + rng.gen_range(2);
            if h < kh || w < kw {
                return;
            }
            let kernel: Vec<f32> = (0..kh * kw).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let img: Vec<f32> = (0..h * w).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let t = conv2d_toeplitz(h, w, &kernel, kh, kw, stride);
            let mut via_matrix = vec![0f32; t.nrows];
            t.spmv(&img, &mut via_matrix);
            let direct = conv2d_direct(&img, h, w, &kernel, kh, kw, stride);
            for (a, b) in via_matrix.iter().zip(direct.iter()) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn pruned_taps_drop_nonzeros() {
        let mut kernel = vec![0.9, 0.1, -0.5, 0.05];
        prune_kernel(&mut kernel, 0.5);
        assert_eq!(kernel.iter().filter(|&&v| v == 0.0).count(), 2);
        assert_eq!(kernel[0], 0.9);
        assert_eq!(kernel[2], -0.5);
        let t = conv2d_toeplitz(6, 6, &kernel, 2, 2, 1);
        // each output row has exactly 2 nonzeros (the surviving taps)
        for r in 0..t.nrows {
            assert_eq!(t.row_nnz(r), 2);
        }
    }

    #[test]
    fn avg_pool_averages() {
        let t = avg_pool_toeplitz(4, 4, 2);
        assert_eq!(t.nrows, 4);
        let img: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut out = vec![0f32; 4];
        t.spmv(&img, &mut out);
        // top-left 2x2 block: (0+1+4+5)/4 = 2.5
        assert!((out[0] - 2.5).abs() < 1e-6);
        assert!((out[3] - 12.5).abs() < 1e-6);
    }

    #[test]
    fn conv_net_trains_distributed() {
        // Full integration: a conv→conv sparse net (Toeplitz layers) under
        // the hypergraph partitioner + distributed SGD == serial SGD.
        use crate::coordinator::sgd::train_distributed;
        use crate::dnn::{sgd_serial, Activation, SparseNet};
        use crate::partition::phases::{hypergraph_partition, PhaseConfig};

        let mut rng = Rng::new(9);
        let mut k1: Vec<f32> = (0..9).map(|_| rng.gen_f32_range(-0.5, 0.5)).collect();
        prune_kernel(&mut k1, 0.3);
        let w1 = conv2d_toeplitz(8, 8, &k1, 3, 3, 1); // 64 -> 36
        let mut k2: Vec<f32> = (0..4).map(|_| rng.gen_f32_range(-0.5, 0.5)).collect();
        let w2 = conv2d_toeplitz(6, 6, &k2, 2, 2, 1); // 36 -> 25
        prune_kernel(&mut k2, 0.0);
        let net = SparseNet::new(vec![w1, w2], Activation::Sigmoid);
        net.validate().unwrap();

        let part = hypergraph_partition(&net.layers, &PhaseConfig::new(3));
        let inputs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..64).map(|_| rng.gen_f32()).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..3).map(|_| vec![0.5f32; 25]).collect();
        let run = train_distributed(&net, &part, &inputs, &targets, 0.2, 2);
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.2, 2);
        for (a, b) in run.losses.iter().zip(sl.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn out_dims() {
        assert_eq!(conv_out(28, 3, 1), 26);
        assert_eq!(conv_out(28, 2, 2), 14);
        assert_eq!(conv_out(5, 5, 1), 1);
    }
}
