//! Serial inference (feedforward only), single-vector and batched.

use crate::dnn::network::SparseNet;

/// Single-vector inference: returns x^L.
pub fn infer(net: &SparseNet, x0: &[f32]) -> Vec<f32> {
    let acts = crate::dnn::sgd_serial::feedforward(net, x0);
    acts.into_iter().last().unwrap()
}

/// Two ping-pong activation buffers reused across layers — and, on the
/// serving path, across requests. Sized lazily to the widest layer of the
/// networks it has seen; growing a request's batch size just regrows the
/// buffers once. The fused SpMM fully overwrites its output rows, so the
/// buffers never need re-zeroing between uses.
#[derive(Default)]
pub struct InferScratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
}

impl InferScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, len: usize) {
        if self.ping.len() < len {
            self.ping.resize(len, 0.0);
            self.pong.resize(len, 0.0);
        }
    }
}

/// Batched inference via SpMM (§5.1): inputs row-major `[n0 x b]` where
/// column j is input j; returns `[nL x b]` row-major. Uses the cache-tiled
/// SpMM with bias + activation fused into the accumulation pass.
pub fn infer_batch(net: &SparseNet, x0: &[f32], b: usize) -> Vec<f32> {
    let mut scratch = InferScratch::new();
    infer_batch_scratch(net, x0, b, &mut scratch).to_vec()
}

/// Allocation-free form of [`infer_batch`]: all layer activations live in
/// the caller's [`InferScratch`], so a request loop touches the allocator
/// zero times after the first call. Returns the `[nL x b]` output borrowed
/// from the scratch (valid until its next use).
pub fn infer_batch_scratch<'s>(
    net: &SparseNet,
    x0: &[f32],
    b: usize,
    scratch: &'s mut InferScratch,
) -> &'s [f32] {
    assert_eq!(x0.len(), net.input_dim() * b);
    let maxw = net
        .layers
        .iter()
        .map(|w| w.nrows)
        .chain(std::iter::once(net.input_dim()))
        .max()
        .unwrap_or(0);
    scratch.ensure(maxw * b);
    let mut cur_len = x0.len();
    scratch.ping[..cur_len].copy_from_slice(x0);
    for (k, w) in net.layers.iter().enumerate() {
        let epilogue = net.activation.fused_bias_epilogue(&net.biases[k]);
        let out_len = w.nrows * b;
        w.spmm_fused_rowmajor(
            &scratch.ping[..cur_len],
            &mut scratch.pong[..out_len],
            b,
            epilogue,
        );
        std::mem::swap(&mut scratch.ping, &mut scratch.pong);
        cur_len = out_len;
    }
    &scratch.ping[..cur_len]
}

/// Throughput-oriented batched inference on `nranks` OS threads: carves the
/// network into contiguous nnz-balanced row blocks and runs the per-rank
/// tiled SpMM concurrently over the rank-parallel engine's **overlapped**
/// split-CSR path (local-segment compute hides the activation transfers).
/// Numerically identical to [`infer_batch`]; faster whenever cores are
/// available.
///
/// This one-shot form rebuilds the partition, plan, rank states, and
/// threads per call; request loops should use the persistent
/// [`crate::serving::RankPool`] (see `examples/inference_serving.rs`), or
/// at minimum reuse a plan via
/// [`crate::coordinator::sgd::infer_with_plan`].
pub fn infer_batch_parallel(net: &SparseNet, x0: &[f32], b: usize, nranks: usize) -> Vec<f32> {
    infer_batch_parallel_mode(net, x0, b, nranks, crate::coordinator::ExecMode::Overlap)
}

/// [`infer_batch_parallel`] with an explicit engine choice — benches pit
/// the blocking schedule against the overlapped one on identical plans.
pub fn infer_batch_parallel_mode(
    net: &SparseNet,
    x0: &[f32],
    b: usize,
    nranks: usize,
    mode: crate::coordinator::ExecMode,
) -> Vec<f32> {
    assert_eq!(x0.len(), net.input_dim() * b);
    let part = crate::partition::contiguous_partition(&net.layers, nranks);
    let plan = crate::partition::CommPlan::build(&net.layers, &part);
    let (out, _) = crate::coordinator::sgd::infer_with_plan_mode(net, &part, &plan, x0, b, mode);
    out
}

/// Argmax class per batch column (Graph Challenge categorization metric).
pub fn classify_batch(logits: &[f32], nclasses: usize, b: usize) -> Vec<usize> {
    assert!(logits.len() >= nclasses * b);
    (0..b)
        .map(|j| {
            (0..nclasses)
                .max_by(|&a, &c| {
                    logits[a * b + j]
                        .partial_cmp(&logits[c * b + j])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::activation::Activation;
    use crate::sparse::Coo;
    use crate::util::{prop, Rng};

    fn random_net(rng: &mut Rng, dims: &[usize]) -> SparseNet {
        let mut layers = Vec::new();
        for k in 1..dims.len() {
            let mut c = Coo::new(dims[k], dims[k - 1]);
            for r in 0..dims[k] {
                for col in 0..dims[k - 1] {
                    if rng.gen_bool(0.4) {
                        c.push(r, col, rng.gen_f32_range(-1.0, 1.0));
                    }
                }
            }
            layers.push(c.to_csr());
        }
        SparseNet::new(layers, Activation::Sigmoid)
    }

    #[test]
    fn batch_matches_single() {
        prop::check(|rng| {
            let net = random_net(rng, &[5, 7, 4]);
            let b = 1 + rng.gen_range(4);
            let inputs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..5).map(|_| rng.gen_f32()).collect())
                .collect();
            // pack row-major [n0 x b]
            let mut x0 = vec![0f32; 5 * b];
            for (j, inp) in inputs.iter().enumerate() {
                for i in 0..5 {
                    x0[i * b + j] = inp[i];
                }
            }
            let out = infer_batch(&net, &x0, b);
            for (j, inp) in inputs.iter().enumerate() {
                let single = infer(&net, inp);
                for i in 0..4 {
                    assert!(
                        (out[i * b + j] - single[i]).abs() < 1e-5,
                        "batch {j} row {i}"
                    );
                }
            }
        });
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        prop::check(|rng| {
            let net = random_net(rng, &[6, 8, 5]);
            let b = 1 + rng.gen_range(6);
            let nranks = 1 + rng.gen_range(4);
            let x0: Vec<f32> = (0..6 * b).map(|_| rng.gen_f32()).collect();
            let serial = infer_batch(&net, &x0, b);
            let parallel = infer_batch_parallel(&net, &x0, b, nranks);
            for (a, s) in parallel.iter().zip(serial.iter()) {
                assert!((a - s).abs() < 1e-5, "nranks={nranks} b={b}");
            }
        });
    }

    #[test]
    fn scratch_reuse_across_requests_matches_fresh() {
        // One scratch serving a stream of requests with varying batch
        // sizes must give bit-identical results to fresh allocations.
        prop::check(|rng| {
            let net = random_net(rng, &[6, 9, 3, 5]);
            let mut scratch = InferScratch::new();
            for _ in 0..4 {
                let b = 1 + rng.gen_range(7);
                let x0: Vec<f32> = (0..6 * b).map(|_| rng.gen_f32()).collect();
                let fresh = infer_batch(&net, &x0, b);
                let reused = infer_batch_scratch(&net, &x0, b, &mut scratch);
                assert_eq!(fresh.len(), reused.len());
                for (a, c) in reused.iter().zip(fresh.iter()) {
                    assert_eq!(a, c, "b={b}");
                }
            }
        });
    }

    #[test]
    fn classify_picks_max() {
        // logits row-major [3 x 2]
        let logits = vec![
            0.1, 0.9, // class 0 for the two columns
            0.8, 0.2, // class 1
            0.3, 0.3, // class 2
        ];
        assert_eq!(classify_batch(&logits, 3, 2), vec![1, 0]);
    }
}
