//! Serial inference (feedforward only), single-vector and batched.

use crate::dnn::network::SparseNet;

/// Single-vector inference: returns x^L.
pub fn infer(net: &SparseNet, x0: &[f32]) -> Vec<f32> {
    let acts = crate::dnn::sgd_serial::feedforward(net, x0);
    acts.into_iter().last().unwrap()
}

/// Batched inference via SpMM (§5.1): inputs row-major `[n0 x b]` where
/// column j is input j; returns `[nL x b]` row-major. Uses the cache-tiled
/// SpMM with bias + activation fused into the accumulation pass.
pub fn infer_batch(net: &SparseNet, x0: &[f32], b: usize) -> Vec<f32> {
    assert_eq!(x0.len(), net.input_dim() * b);
    let mut cur = x0.to_vec();
    for (k, w) in net.layers.iter().enumerate() {
        let mut z = vec![0f32; w.nrows * b];
        let epilogue = net.activation.fused_bias_epilogue(&net.biases[k]);
        w.spmm_fused_rowmajor(&cur, &mut z, b, epilogue);
        cur = z;
    }
    cur
}

/// Throughput-oriented batched inference on `nranks` OS threads: carves the
/// network into contiguous nnz-balanced row blocks and runs the per-rank
/// tiled SpMM concurrently over the rank-parallel engine. Numerically
/// identical to [`infer_batch`]; faster whenever cores are available.
///
/// This one-shot form rebuilds the partition and communication plan per
/// call; request loops should build them once and call
/// [`crate::coordinator::sgd::infer_with_plan`] instead (see
/// `examples/inference_serving.rs`).
pub fn infer_batch_parallel(net: &SparseNet, x0: &[f32], b: usize, nranks: usize) -> Vec<f32> {
    assert_eq!(x0.len(), net.input_dim() * b);
    let part = crate::partition::contiguous_partition(&net.layers, nranks);
    let (out, _) = crate::coordinator::sgd::infer_distributed(net, &part, x0, b);
    out
}

/// Argmax class per batch column (Graph Challenge categorization metric).
pub fn classify_batch(logits: &[f32], nclasses: usize, b: usize) -> Vec<usize> {
    assert!(logits.len() >= nclasses * b);
    (0..b)
        .map(|j| {
            (0..nclasses)
                .max_by(|&a, &c| {
                    logits[a * b + j]
                        .partial_cmp(&logits[c * b + j])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::activation::Activation;
    use crate::sparse::Coo;
    use crate::util::{prop, Rng};

    fn random_net(rng: &mut Rng, dims: &[usize]) -> SparseNet {
        let mut layers = Vec::new();
        for k in 1..dims.len() {
            let mut c = Coo::new(dims[k], dims[k - 1]);
            for r in 0..dims[k] {
                for col in 0..dims[k - 1] {
                    if rng.gen_bool(0.4) {
                        c.push(r, col, rng.gen_f32_range(-1.0, 1.0));
                    }
                }
            }
            layers.push(c.to_csr());
        }
        SparseNet::new(layers, Activation::Sigmoid)
    }

    #[test]
    fn batch_matches_single() {
        prop::check(|rng| {
            let net = random_net(rng, &[5, 7, 4]);
            let b = 1 + rng.gen_range(4);
            let inputs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..5).map(|_| rng.gen_f32()).collect())
                .collect();
            // pack row-major [n0 x b]
            let mut x0 = vec![0f32; 5 * b];
            for (j, inp) in inputs.iter().enumerate() {
                for i in 0..5 {
                    x0[i * b + j] = inp[i];
                }
            }
            let out = infer_batch(&net, &x0, b);
            for (j, inp) in inputs.iter().enumerate() {
                let single = infer(&net, inp);
                for i in 0..4 {
                    assert!(
                        (out[i * b + j] - single[i]).abs() < 1e-5,
                        "batch {j} row {i}"
                    );
                }
            }
        });
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        prop::check(|rng| {
            let net = random_net(rng, &[6, 8, 5]);
            let b = 1 + rng.gen_range(6);
            let nranks = 1 + rng.gen_range(4);
            let x0: Vec<f32> = (0..6 * b).map(|_| rng.gen_f32()).collect();
            let serial = infer_batch(&net, &x0, b);
            let parallel = infer_batch_parallel(&net, &x0, b, nranks);
            for (a, s) in parallel.iter().zip(serial.iter()) {
                assert!((a - s).abs() < 1e-5, "nranks={nranks} b={b}");
            }
        });
    }

    #[test]
    fn classify_picks_max() {
        // logits row-major [3 x 2]
        let logits = vec![
            0.1, 0.9, // class 0 for the two columns
            0.8, 0.2, // class 1
            0.3, 0.3, // class 2
        ];
        assert_eq!(classify_batch(&logits, 3, 2), vec![1, 0]);
    }
}
