//! Communication substrate: the simulated MPI fabric (live threaded runs)
//! and the α-β network / compute-rate models (replay runs).

pub mod fabric;
pub mod netmodel;

pub use fabric::{fabric, Endpoint, Msg, Phase, Want};
pub use netmodel::{ComputeModel, NetModel};
