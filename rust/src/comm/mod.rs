//! Communication substrate: the simulated MPI fabric (live threaded runs),
//! the wire codecs (f16/int8 compressed payloads), and the α-β network /
//! compute-rate models (replay runs).

pub mod codec;
pub mod fabric;
pub mod netmodel;

pub use codec::Codec;
pub use fabric::{fabric, fabric_with, Endpoint, FabricStats, Msg, PeerCounters, Phase, Want};
pub use netmodel::{ComputeModel, NetModel};
