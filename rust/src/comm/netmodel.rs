//! α-β network cost model + host compute-rate calibration.
//!
//! The paper's running-time results (Fig. 4, Fig. 5, Table 2) were measured
//! on a 512-core InfiniBand cluster we do not have. The combinatorial
//! quantities (volume, messages, loads) are computed exactly; *time* is
//! modeled: per-rank compute from calibrated per-nnz rates (measured on
//! this host), per-layer communication from the classic α-β (latency +
//! inverse-bandwidth) model applied to the exact message sets, and the
//! layer barrier takes the max over ranks (the synchronization the paper
//! discusses in §5.1/§6.2). DESIGN.md §2 documents why the *shape* of the
//! paper's results survives this substitution.

use crate::sparse::Csr;
use crate::util::Stopwatch;

/// Latency/bandwidth parameters of the modeled interconnect.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// End-to-end latency of the layer exchange, seconds (α) — paid once
    /// per layer barrier: non-blocking sends to distinct destinations
    /// pipeline, so wire latencies overlap (Alg. 2 lines 3–5).
    pub alpha: f64,
    /// Per-message software overhead, seconds (o): post/match/completion
    /// cost of each point-to-point message, which does NOT overlap.
    pub overhead: f64,
    /// Per-word transfer time, seconds (β, f32 words).
    pub beta: f64,
}

impl NetModel {
    /// QLogic TrueScale InfiniBand-class defaults (the paper's fabric):
    /// ~2.5 µs MPI latency, ~0.4 µs per-message CPU overhead (PSM),
    /// ~1.2 GB/s effective point-to-point bandwidth.
    pub fn infiniband() -> Self {
        NetModel {
            alpha: 2.5e-6,
            overhead: 0.4e-6,
            beta: 4.0 / 1.2e9,
        }
    }

    /// Cost of one rank sending `msgs` messages totalling `words` words and
    /// receiving `rmsgs`/`rwords` within one layer step: one latency for
    /// the barrier exchange, serialized per-message software overhead on
    /// the busier direction, bandwidth on all bytes through the NIC.
    pub fn layer_cost(&self, msgs: u64, words: u64, rmsgs: u64, rwords: u64) -> f64 {
        if msgs == 0 && rmsgs == 0 {
            return 0.0;
        }
        self.alpha
            + self.overhead * (msgs.max(rmsgs) as f64)
            + self.beta * ((words + rwords) as f64)
    }

    /// [`NetModel::layer_cost`] with **byte** totals instead of f32 word
    /// counts — the form the wire-codec layer feeds: each payload's
    /// [`crate::comm::Codec::wire_bytes`] footprint rather than its raw
    /// element count. `β` is per f32 word, so bytes cost `β/4` each;
    /// under `Codec::F32` (bytes = 4 × words) this is exactly
    /// `layer_cost`.
    pub fn layer_cost_bytes(&self, msgs: u64, bytes: u64, rmsgs: u64, rbytes: u64) -> f64 {
        if msgs == 0 && rmsgs == 0 {
            return 0.0;
        }
        self.alpha
            + self.overhead * (msgs.max(rmsgs) as f64)
            + self.beta / 4.0 * ((bytes + rbytes) as f64)
    }

    /// Predicted seconds of one ring all-reduce of a length-`m` flat
    /// gradient across `groups` replicas under `codec`
    /// ([`crate::replica`]'s schedule): `2(R−1)` dependent hops, each
    /// carrying one segment of at most `⌈m/R⌉` elements — every segment
    /// is in flight at every hop, so the hop's critical path is the
    /// largest segment's wire footprint. `R = 1` exchanges nothing.
    pub fn ring_allreduce_cost(&self, groups: usize, m: usize, codec: crate::comm::Codec) -> f64 {
        if groups <= 1 {
            return 0.0;
        }
        let per_hop = self.alpha
            + self.overhead
            + self.beta / 4.0 * codec.wire_bytes(m.div_ceil(groups)) as f64;
        (2 * (groups - 1)) as f64 * per_hop
    }
}

/// Calibrated per-element compute rates of this host (seconds).
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Seconds per nonzero for CSR SpMV (fwd z = Wx).
    pub spmv_per_nnz: f64,
    /// Seconds per nonzero for the transpose product (bwd s = Wᵀδ).
    pub spmvt_per_nnz: f64,
    /// Seconds per nonzero for the gradient update (W -= η δ⊗x on pattern).
    pub update_per_nnz: f64,
    /// Seconds per vector element for activation/elementwise work.
    pub elem: f64,
}

impl ComputeModel {
    /// Reasonable defaults for a ~2.4 GHz Haswell-class core (the paper's
    /// E5-2630 v3); used when calibration is skipped.
    pub fn haswell_defaults() -> Self {
        ComputeModel {
            spmv_per_nnz: 1.6e-9,
            spmvt_per_nnz: 2.2e-9,
            update_per_nnz: 2.0e-9,
            elem: 1.2e-9,
        }
    }

    /// Measure the real rates on this host with a short microbenchmark.
    pub fn calibrate() -> Self {
        let mut rng = crate::util::Rng::new(42);
        // a CSR matrix shaped like a RadiX-Net layer block
        let n = 4096usize;
        let deg = 32usize;
        let mut coo = crate::sparse::Coo::with_capacity(n, n, n * deg);
        for r in 0..n {
            for c in rng.sample_distinct(n, deg) {
                coo.push(r, c as usize, rng.gen_f32_range(-1.0, 1.0));
            }
        }
        let mut m = coo.to_csr();
        let x: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let mut y = vec![0f32; n];
        let reps = 20;

        let warm = Stopwatch::start();
        m.spmv(&x, &mut y); // warm caches
        let _ = warm.elapsed_secs();

        let sw = Stopwatch::start();
        for _ in 0..reps {
            m.spmv(&x, &mut y);
        }
        let spmv = sw.elapsed_secs() / (reps * m.nnz()) as f64;

        let mut s = vec![0f32; n];
        let sw = Stopwatch::start();
        for _ in 0..reps {
            s.fill(0.0);
            m.spmv_t_add(&y, &mut s);
        }
        let spmvt = sw.elapsed_secs() / (reps * m.nnz()) as f64;

        let sw = Stopwatch::start();
        for _ in 0..reps {
            m.sgd_update(&y, &x, 1e-6);
        }
        let update = sw.elapsed_secs() / (reps * m.nnz()) as f64;

        let mut z = y.clone();
        let act = crate::dnn::Activation::Sigmoid;
        let sw = Stopwatch::start();
        for _ in 0..reps * 10 {
            act.apply(&mut z);
        }
        let elem = sw.elapsed_secs() / (reps * 10 * n) as f64;

        ComputeModel {
            spmv_per_nnz: spmv.max(1e-11),
            spmvt_per_nnz: spmvt.max(1e-11),
            update_per_nnz: update.max(1e-11),
            elem: elem.max(1e-12),
        }
    }

    /// Forward compute time of a rank owning `nnz` nonzeros and `rows`
    /// output rows in one layer (SpMV + bias + activation).
    pub fn fwd_time(&self, nnz: u64, rows: u64) -> f64 {
        self.spmv_per_nnz * nnz as f64 + self.elem * rows as f64
    }

    /// Backward transpose-product time.
    pub fn bwd_time(&self, nnz: u64, rows: u64) -> f64 {
        self.spmvt_per_nnz * nnz as f64 + self.elem * rows as f64
    }

    /// Gradient-update time.
    pub fn update_time(&self, nnz: u64) -> f64 {
        self.update_per_nnz * nnz as f64
    }
}

/// SpMV-shaped load of one rank in one layer (precomputed by the replay).
#[derive(Debug, Clone, Copy, Default)]
pub struct RankLayerLoad {
    pub nnz: u64,
    pub rows: u64,
}

/// Per-rank per-layer loads for a partitioned network.
pub fn layer_loads(structure: &[Csr], parts: &[Vec<u32>], nparts: usize) -> Vec<Vec<RankLayerLoad>> {
    structure
        .iter()
        .enumerate()
        .map(|(k, w)| {
            let mut loads = vec![RankLayerLoad::default(); nparts];
            for r in 0..w.nrows {
                let p = parts[k][r] as usize;
                loads[p].nnz += w.row_nnz(r) as u64;
                loads[p].rows += 1;
            }
            loads
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_cost_monotone() {
        let net = NetModel::infiniband();
        let base = net.layer_cost(1, 100, 1, 100);
        assert!(net.layer_cost(2, 100, 1, 100) > base);
        assert!(net.layer_cost(1, 200, 1, 100) > base);
        assert!(net.layer_cost(1, 100, 5, 100) > base);
        assert_eq!(net.layer_cost(0, 0, 0, 0), 0.0);
    }

    #[test]
    fn layer_cost_bytes_agrees_with_words_under_f32() {
        use crate::comm::Codec;
        let net = NetModel::infiniband();
        for &(m, w, rm, rw) in &[(1u64, 100u64, 1u64, 100u64), (3, 7, 0, 0), (0, 0, 5, 999)] {
            let words = net.layer_cost(m, w, rm, rw);
            let bytes = net.layer_cost_bytes(
                m,
                Codec::F32.wire_bytes(w as usize),
                rm,
                Codec::F32.wire_bytes(rw as usize),
            );
            assert!((words - bytes).abs() < 1e-18, "{words} vs {bytes}");
        }
        // f16 payloads cost measurably less wire time at equal word count
        let wb32 = Codec::F32.wire_bytes(4096);
        let wb16 = Codec::F16.wire_bytes(4096);
        let w32 = net.layer_cost_bytes(2, wb32, 2, wb32);
        let w16 = net.layer_cost_bytes(2, wb16, 2, wb16);
        assert!(w16 < w32);
    }

    #[test]
    fn ring_cost_scales_with_groups_and_compression() {
        use crate::comm::Codec;
        let net = NetModel::infiniband();
        assert_eq!(net.ring_allreduce_cost(1, 1 << 20, Codec::F32), 0.0);
        let r2 = net.ring_allreduce_cost(2, 1 << 20, Codec::F32);
        let r4 = net.ring_allreduce_cost(4, 1 << 20, Codec::F32);
        assert!(r2 > 0.0);
        // more groups: more hops but smaller segments — bandwidth-bound
        // at this size, the totals stay within ~2(R−1)/R of each other
        assert!(r4 < r2 * 1.6, "r4 {r4} vs r2 {r2}");
        let q = net.ring_allreduce_cost(2, 1 << 20, Codec::int8());
        assert!(q < 0.35 * r2, "int8 ring {q} not under 0.35× of f32 {r2}");
    }

    #[test]
    fn calibration_produces_sane_rates() {
        let c = ComputeModel::calibrate();
        // between 0.05 ns and 1 µs per nnz on any plausible host
        assert!(c.spmv_per_nnz > 5e-11 && c.spmv_per_nnz < 1e-6, "{c:?}");
        assert!(c.spmvt_per_nnz > 5e-11 && c.spmvt_per_nnz < 1e-6);
        assert!(c.update_per_nnz > 5e-11 && c.update_per_nnz < 1e-6);
    }

    #[test]
    fn loads_partition_totals() {
        use crate::partition::random::random_partition;
        use crate::radixnet::{generate_structure, RadixNetConfig};
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 4).unwrap());
        let part = random_partition(&structure, 4, 1);
        let loads = layer_loads(&structure, &part.layer_parts, 4);
        for (k, w) in structure.iter().enumerate() {
            let nnz: u64 = loads[k].iter().map(|l| l.nnz).sum();
            let rows: u64 = loads[k].iter().map(|l| l.rows).sum();
            assert_eq!(nnz, w.nnz() as u64);
            assert_eq!(rows, w.nrows as u64);
        }
    }
}
