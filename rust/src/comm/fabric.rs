//! Simulated message-passing fabric: the crate's MPI substitute.
//!
//! Ranks are threads; each rank holds an [`Endpoint`] with channels to every
//! other rank. Sends are non-blocking (like `MPI_Isend` in Alg. 2 line 5);
//! receives match on (layer, phase, transfer-id) with out-of-order stashing,
//! which gives the same semantics as tag-matched MPI point-to-point.
//! Every endpoint counts words/messages sent so live runs can be checked
//! against the precomputed [`crate::partition::CommPlan`].
//!
//! All endpoints of one fabric share a **fault flag**: when a rank fails,
//! the parallel engine ([`crate::runtime::parallel`]) poisons the fabric and
//! every peer blocked in [`Endpoint::recv`] wakes up and unwinds instead of
//! deadlocking on a message that will never arrive.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Communication phase tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
}

/// A tagged message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub layer: u32,
    pub phase: Phase,
    pub from: u32,
    /// Transfer id within the layer plan (unique per (from,to) pair).
    pub transfer: u32,
    pub payload: Vec<f32>,
}

type Key = (u32, Phase, u32, u32); // layer, phase, from, transfer

/// How long a blocked receive sleeps between checks of the fault flag.
const FAULT_POLL: Duration = Duration::from_millis(50);

/// Per-rank endpoint.
pub struct Endpoint {
    pub rank: u32,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    stash: HashMap<Key, Vec<f32>>,
    fault: Arc<AtomicBool>,
    /// Counters: words sent, messages sent.
    pub sent_words: u64,
    pub sent_msgs: u64,
}

impl Endpoint {
    /// Non-blocking send of `payload` to `to`.
    pub fn send(&mut self, to: u32, layer: u32, phase: Phase, transfer: u32, payload: Vec<f32>) {
        self.sent_words += payload.len() as u64;
        self.sent_msgs += 1;
        let msg = Msg {
            layer,
            phase,
            from: self.rank,
            transfer,
            payload,
        };
        // A disconnected peer means that rank panicked; propagate.
        self.senders[to as usize]
            .send(msg)
            .expect("peer rank hung up");
    }

    /// Blocking receive of the uniquely-tagged message; out-of-order
    /// arrivals for other tags are stashed. Panics if the fabric is
    /// poisoned while waiting (a peer rank failed).
    pub fn recv(&mut self, from: u32, layer: u32, phase: Phase, transfer: u32) -> Vec<f32> {
        let key: Key = (layer, phase, from, transfer);
        if let Some(p) = self.stash.remove(&key) {
            return p;
        }
        loop {
            match self.inbox.recv_timeout(FAULT_POLL) {
                Ok(m) => {
                    let k: Key = (m.layer, m.phase, m.from, m.transfer);
                    if k == key {
                        return m.payload;
                    }
                    self.stash.insert(k, m.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.poisoned() {
                        panic!(
                            "fabric poisoned: a peer rank failed while rank {} waited",
                            self.rank
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving");
                }
            }
        }
    }

    /// Mark the whole fabric as failed, waking every blocked receiver.
    pub fn poison(&self) {
        self.fault.store(true, Ordering::Release);
    }

    /// True once any endpoint of this fabric called [`Endpoint::poison`].
    pub fn poisoned(&self) -> bool {
        self.fault.load(Ordering::Acquire)
    }

    /// True if no unconsumed messages remain (end-of-run check). Pulls
    /// anything still sitting in the channel into the stash first, so
    /// messages that were sent but never received also count as leaks.
    pub fn drained(&mut self) -> bool {
        while let Ok(m) = self.inbox.try_recv() {
            self.stash
                .insert((m.layer, m.phase, m.from, m.transfer), m.payload);
        }
        self.stash.is_empty()
    }
}

/// Build a fully-connected fabric of `n` endpoints sharing one fault flag.
pub fn fabric(n: usize) -> Vec<Endpoint> {
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let fault = Arc::new(AtomicBool::new(false));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank: rank as u32,
            senders: senders.clone(),
            inbox,
            stash: HashMap::new(),
            fault: fault.clone(),
            sent_words: 0,
            sent_msgs: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_roundtrip() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            e1.send(0, 3, Phase::Forward, 7, vec![1.0, 2.0]);
            e1
        });
        let p = e0.recv(1, 3, Phase::Forward, 7);
        assert_eq!(p, vec![1.0, 2.0]);
        let e1 = t.join().unwrap();
        assert_eq!(e1.sent_words, 2);
        assert_eq!(e1.sent_msgs, 1);
    }

    #[test]
    fn out_of_order_stash() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            // send layer 1 before layer 0
            e1.send(0, 1, Phase::Forward, 0, vec![10.0]);
            e1.send(0, 0, Phase::Forward, 0, vec![20.0]);
            e1.send(0, 0, Phase::Backward, 0, vec![30.0]);
        });
        assert_eq!(e0.recv(1, 0, Phase::Forward, 0), vec![20.0]);
        assert_eq!(e0.recv(1, 0, Phase::Backward, 0), vec![30.0]);
        assert_eq!(e0.recv(1, 1, Phase::Forward, 0), vec![10.0]);
        assert!(e0.drained());
        t.join().unwrap();
    }

    #[test]
    fn many_ranks_all_to_all() {
        let n = 8;
        let eps = fabric(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    let me = e.rank;
                    for to in 0..n as u32 {
                        if to != me {
                            e.send(to, 0, Phase::Forward, me, vec![me as f32]);
                        }
                    }
                    let mut sum = 0.0;
                    for from in 0..n as u32 {
                        if from != me {
                            sum += e.recv(from, 0, Phase::Forward, from)[0];
                        }
                    }
                    sum
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let sum = h.join().unwrap();
            let expect: f32 = (0..n as u32).filter(|&x| x != i as u32).map(|x| x as f32).sum();
            assert_eq!(sum, expect);
        }
    }

    #[test]
    fn poison_unblocks_blocked_receiver() {
        let mut eps = fabric(2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e0.recv(1, 0, Phase::Forward, 0)
            }));
            r.is_err()
        });
        // let the receiver block, then poison instead of sending
        std::thread::sleep(Duration::from_millis(10));
        e1.poison();
        assert!(e1.poisoned());
        assert!(t.join().unwrap(), "blocked receiver did not unwind");
    }
}
