//! Simulated message-passing fabric: the crate's MPI substitute.
//!
//! Ranks are threads; each rank holds an [`Endpoint`] with channels to every
//! other rank. Sends are non-blocking (like `MPI_Isend` in Alg. 2 line 5);
//! receives match on (layer, phase, transfer-id) with out-of-order stashing,
//! which gives the same semantics as tag-matched MPI point-to-point.
//! Every endpoint counts words/messages sent so live runs can be checked
//! against the precomputed [`crate::partition::CommPlan`].
//!
//! All endpoints of one fabric share a **fault flag**: when a rank fails,
//! the parallel engine ([`crate::runtime::parallel`]) poisons the fabric and
//! every peer blocked in [`Endpoint::recv`] wakes up and unwinds instead of
//! deadlocking on a message that will never arrive.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Communication phase tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
}

/// A tagged message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub layer: u32,
    pub phase: Phase,
    pub from: u32,
    /// Transfer id within the layer plan (unique per (from,to) pair).
    pub transfer: u32,
    pub payload: Vec<f32>,
}

type Key = (u32, Phase, u32, u32); // layer, phase, from, transfer

/// How long a blocked receive sleeps between checks of the fault flag.
const FAULT_POLL: Duration = Duration::from_millis(50);

/// Cap on recycled payload buffers kept per endpoint (bounds memory while
/// still covering every in-flight transfer of a layer step).
const MAX_SPARE_BUFS: usize = 32;

/// Per-rank endpoint.
pub struct Endpoint {
    pub rank: u32,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Out-of-order arrivals, FIFO **per tag**: unsynchronized steady-state
    /// loops (e.g. a rank lapping a slower peer in a forward-only request
    /// stream) legitimately put two messages with the same tag in flight,
    /// and per-sender channel order guarantees the earlier pass's payload
    /// is queued first.
    stash: HashMap<Key, VecDeque<Vec<f32>>>,
    fault: Arc<AtomicBool>,
    /// Recycled payload buffers: consumed receives return their allocation
    /// here and send sites reuse it, so a steady-state layer loop (and a
    /// pool rank serving a stream of requests) stops touching the
    /// allocator for payloads entirely.
    spare: Vec<Vec<f32>>,
    /// Counters: words sent, messages sent.
    pub sent_words: u64,
    pub sent_msgs: u64,
}

impl Endpoint {
    /// Non-blocking send of `payload` to `to`.
    pub fn send(&mut self, to: u32, layer: u32, phase: Phase, transfer: u32, payload: Vec<f32>) {
        self.sent_words += payload.len() as u64;
        self.sent_msgs += 1;
        let msg = Msg {
            layer,
            phase,
            from: self.rank,
            transfer,
            payload,
        };
        // A disconnected peer means that rank panicked; propagate.
        self.senders[to as usize]
            .send(msg)
            .expect("peer rank hung up");
    }

    /// Pop the oldest stashed payload for `key`, dropping empty queues so
    /// [`Endpoint::drained`] stays a plain emptiness check.
    fn stash_pop(&mut self, key: &Key) -> Option<Vec<f32>> {
        let (payload, now_empty) = match self.stash.get_mut(key) {
            Some(q) => (q.pop_front(), q.is_empty()),
            None => return None,
        };
        if now_empty {
            self.stash.remove(key);
        }
        payload
    }

    fn stash_push(&mut self, key: Key, payload: Vec<f32>) {
        self.stash.entry(key).or_default().push_back(payload);
    }

    /// Blocking receive of the tagged message (oldest first if the tag is
    /// in flight more than once); out-of-order arrivals for other tags are
    /// stashed. Panics if the fabric is poisoned while waiting (a peer
    /// rank failed).
    pub fn recv(&mut self, from: u32, layer: u32, phase: Phase, transfer: u32) -> Vec<f32> {
        let key: Key = (layer, phase, from, transfer);
        if let Some(p) = self.stash_pop(&key) {
            return p;
        }
        loop {
            match self.inbox.recv_timeout(FAULT_POLL) {
                Ok(m) => {
                    let k: Key = (m.layer, m.phase, m.from, m.transfer);
                    if k == key {
                        return m.payload;
                    }
                    self.stash_push(k, m.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.poisoned() {
                        panic!(
                            "fabric poisoned: a peer rank failed while rank {} waited",
                            self.rank
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving");
                }
            }
        }
    }

    /// Non-blocking receive: the payload if the uniquely-tagged message is
    /// already here (stashed or sitting in the channel), else `None`.
    /// Everything drained from the channel on the way is stashed, so no
    /// message is ever lost to a miss.
    pub fn try_recv(
        &mut self,
        from: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
    ) -> Option<Vec<f32>> {
        let key: Key = (layer, phase, from, transfer);
        if let Some(p) = self.stash_pop(&key) {
            return Some(p);
        }
        while let Ok(m) = self.inbox.try_recv() {
            let k: Key = (m.layer, m.phase, m.from, m.transfer);
            if k == key {
                return Some(m.payload);
            }
            self.stash_push(k, m.payload);
        }
        None
    }

    /// Block until **any** of the wanted `(from, transfer)` messages of
    /// `(layer, phase)` arrives; returns its index in `wants` plus the
    /// payload. Arrival order, not plan order — the overlapped engine
    /// applies each remote segment the moment its activations land.
    /// Panics if the fabric is poisoned while waiting.
    pub fn recv_any(
        &mut self,
        layer: u32,
        phase: Phase,
        wants: &[(u32, u32)],
    ) -> (usize, Vec<f32>) {
        assert!(!wants.is_empty(), "recv_any needs at least one want");
        for (i, &(from, transfer)) in wants.iter().enumerate() {
            let key: Key = (layer, phase, from, transfer);
            if let Some(p) = self.stash_pop(&key) {
                return (i, p);
            }
        }
        loop {
            match self.inbox.recv_timeout(FAULT_POLL) {
                Ok(m) => {
                    if m.layer == layer && m.phase == phase {
                        if let Some(i) = wants
                            .iter()
                            .position(|&(f, t)| f == m.from && t == m.transfer)
                        {
                            return (i, m.payload);
                        }
                    }
                    self.stash_push((m.layer, m.phase, m.from, m.transfer), m.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.poisoned() {
                        panic!(
                            "fabric poisoned: a peer rank failed while rank {} waited",
                            self.rank
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving");
                }
            }
        }
    }

    /// An empty payload buffer, reusing a recycled allocation when one is
    /// available. Pair with [`Endpoint::recycle`] on the receive side.
    pub fn take_buf(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_default()
    }

    /// Return a consumed payload's allocation for reuse by later sends.
    pub fn recycle(&mut self, mut buf: Vec<f32>) {
        if self.spare.len() < MAX_SPARE_BUFS {
            buf.clear();
            self.spare.push(buf);
        }
    }

    /// Mark the whole fabric as failed, waking every blocked receiver.
    pub fn poison(&self) {
        self.fault.store(true, Ordering::Release);
    }

    /// True once any endpoint of this fabric called [`Endpoint::poison`].
    pub fn poisoned(&self) -> bool {
        self.fault.load(Ordering::Acquire)
    }

    /// True if no unconsumed messages remain (end-of-run check). Pulls
    /// anything still sitting in the channel into the stash first, so
    /// messages that were sent but never received also count as leaks.
    pub fn drained(&mut self) -> bool {
        while let Ok(m) = self.inbox.try_recv() {
            self.stash_push((m.layer, m.phase, m.from, m.transfer), m.payload);
        }
        self.stash.is_empty()
    }
}

/// Build a fully-connected fabric of `n` endpoints sharing one fault flag.
pub fn fabric(n: usize) -> Vec<Endpoint> {
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let fault = Arc::new(AtomicBool::new(false));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank: rank as u32,
            senders: senders.clone(),
            inbox,
            stash: HashMap::new(),
            fault: fault.clone(),
            spare: Vec::new(),
            sent_words: 0,
            sent_msgs: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_roundtrip() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            e1.send(0, 3, Phase::Forward, 7, vec![1.0, 2.0]);
            e1
        });
        let p = e0.recv(1, 3, Phase::Forward, 7);
        assert_eq!(p, vec![1.0, 2.0]);
        let e1 = t.join().unwrap();
        assert_eq!(e1.sent_words, 2);
        assert_eq!(e1.sent_msgs, 1);
    }

    #[test]
    fn out_of_order_stash() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            // send layer 1 before layer 0
            e1.send(0, 1, Phase::Forward, 0, vec![10.0]);
            e1.send(0, 0, Phase::Forward, 0, vec![20.0]);
            e1.send(0, 0, Phase::Backward, 0, vec![30.0]);
        });
        assert_eq!(e0.recv(1, 0, Phase::Forward, 0), vec![20.0]);
        assert_eq!(e0.recv(1, 0, Phase::Backward, 0), vec![30.0]);
        assert_eq!(e0.recv(1, 1, Phase::Forward, 0), vec![10.0]);
        assert!(e0.drained());
        t.join().unwrap();
    }

    #[test]
    fn many_ranks_all_to_all() {
        let n = 8;
        let eps = fabric(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    let me = e.rank;
                    for to in 0..n as u32 {
                        if to != me {
                            e.send(to, 0, Phase::Forward, me, vec![me as f32]);
                        }
                    }
                    let mut sum = 0.0;
                    for from in 0..n as u32 {
                        if from != me {
                            sum += e.recv(from, 0, Phase::Forward, from)[0];
                        }
                    }
                    sum
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let sum = h.join().unwrap();
            let expect: f32 = (0..n as u32).filter(|&x| x != i as u32).map(|x| x as f32).sum();
            assert_eq!(sum, expect);
        }
    }

    #[test]
    fn try_recv_misses_then_hits_and_stashes() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert!(e0.try_recv(1, 0, Phase::Forward, 0).is_none());
        e1.send(0, 1, Phase::Forward, 5, vec![9.0]); // wrong tag: stashed
        e1.send(0, 0, Phase::Forward, 0, vec![1.0, 2.0]);
        // give the in-process channel a moment to flush
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let p = loop {
            if let Some(p) = e0.try_recv(1, 0, Phase::Forward, 0) {
                break p;
            }
            assert!(std::time::Instant::now() < deadline, "message never arrived");
            std::thread::yield_now();
        };
        assert_eq!(p, vec![1.0, 2.0]);
        // the mis-tagged message was stashed, not dropped
        assert_eq!(e0.recv(1, 1, Phase::Forward, 5), vec![9.0]);
        assert!(e0.drained());
    }

    #[test]
    fn recv_any_returns_in_arrival_order() {
        let mut eps = fabric(3);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // rank 2 sends immediately; rank 1 sends late
        let t2 = std::thread::spawn(move || e2.send(0, 0, Phase::Forward, 7, vec![2.0]));
        let t1 = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            e1.send(0, 0, Phase::Forward, 3, vec![1.0]);
        });
        let wants = [(1u32, 3u32), (2u32, 7u32)];
        let (i, p) = e0.recv_any(0, Phase::Forward, &wants);
        assert_eq!((i, p), (1, vec![2.0]), "late sender must not block the early one");
        let (i, p) = e0.recv_any(0, Phase::Forward, &wants);
        assert_eq!((i, p), (0, vec![1.0]));
        t1.join().unwrap();
        t2.join().unwrap();
        assert!(e0.drained());
    }

    #[test]
    fn recv_any_checks_stash_and_ignores_other_tags() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 9, Phase::Backward, 0, vec![5.0]); // unrelated tag
        e1.send(0, 2, Phase::Forward, 1, vec![6.0]);
        // blocking recv of the unrelated tag stashes the wanted one
        assert_eq!(e0.recv(1, 9, Phase::Backward, 0), vec![5.0]);
        let (i, p) = e0.recv_any(2, Phase::Forward, &[(1, 1)]);
        assert_eq!((i, p), (0, vec![6.0]));
        assert!(e0.drained());
    }

    #[test]
    fn duplicate_tags_deliver_in_fifo_order() {
        // A rank lapping a slower peer reuses tags; the stash must queue
        // duplicates (never overwrite) and deliver oldest-first.
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 0, Phase::Forward, 0, vec![1.0]); // pass 1
        e1.send(0, 0, Phase::Forward, 0, vec![2.0]); // pass 2, same tag
        e1.send(0, 1, Phase::Forward, 0, vec![9.0]);
        // receiving the unrelated tag stashes BOTH same-key duplicates
        assert_eq!(e0.recv(1, 1, Phase::Forward, 0), vec![9.0]);
        assert_eq!(e0.recv(1, 0, Phase::Forward, 0), vec![1.0]);
        assert_eq!(e0.try_recv(1, 0, Phase::Forward, 0), Some(vec![2.0]));
        assert!(e0.drained());
        // and via recv_any too
        e1.send(0, 2, Phase::Backward, 3, vec![4.0]);
        e1.send(0, 2, Phase::Backward, 3, vec![5.0]);
        e1.send(0, 7, Phase::Forward, 0, vec![8.0]);
        assert_eq!(e0.recv(1, 7, Phase::Forward, 0), vec![8.0]);
        let wants = [(1u32, 3u32)];
        assert_eq!(e0.recv_any(2, Phase::Backward, &wants), (0, vec![4.0]));
        assert_eq!(e0.recv_any(2, Phase::Backward, &wants), (0, vec![5.0]));
        assert!(e0.drained());
    }

    #[test]
    fn recycled_buffers_are_reused_and_bounded() {
        let mut eps = fabric(1);
        let mut e = eps.pop().unwrap();
        let mut buf = e.take_buf();
        assert!(buf.is_empty());
        buf.extend_from_slice(&[1.0, 2.0, 3.0]);
        let cap = buf.capacity();
        e.recycle(buf);
        let again = e.take_buf();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap, "allocation must be reused");
        e.recycle(again);
        for _ in 0..100 {
            e.recycle(Vec::with_capacity(8));
        }
        assert!(e.spare.len() <= MAX_SPARE_BUFS);
    }

    #[test]
    fn poison_unblocks_blocked_receiver() {
        let mut eps = fabric(2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e0.recv(1, 0, Phase::Forward, 0)
            }));
            r.is_err()
        });
        // let the receiver block, then poison instead of sending
        std::thread::sleep(Duration::from_millis(10));
        e1.poison();
        assert!(e1.poisoned());
        assert!(t.join().unwrap(), "blocked receiver did not unwind");
    }
}
