//! Simulated message-passing fabric: the crate's MPI substitute.
//!
//! Ranks are threads; each rank holds an [`Endpoint`] with channels to every
//! other rank. Sends are non-blocking (like `MPI_Isend` in Alg. 2 line 5);
//! receives match on (layer, phase, transfer-id, chunk-id) with out-of-order
//! stashing, which gives the same semantics as tag-matched MPI
//! point-to-point. The chunk id carries **sub-transfer pipelining**: the
//! pipelined send schedule splits one logical transfer into several chunks
//! posted as each row range finishes, and [`Endpoint::recv_any`] lets the
//! receiver apply those partial payloads in arrival order. Whole-transfer
//! senders use chunk 0 ([`Endpoint::send`]).
//! Every endpoint counts words/messages sent so live runs can be checked
//! against the precomputed [`crate::partition::CommPlan`].
//!
//! All endpoints of one fabric share a **fault flag**: when a rank fails,
//! the parallel engine ([`crate::runtime::parallel`]) poisons the fabric and
//! every peer blocked in [`Endpoint::recv`] wakes up and unwinds instead of
//! deadlocking on a message that will never arrive.
//!
//! **Chaos.** A fabric built while a fault plan is installed
//! (`SPDNN_FAULT`, or an explicit plan via [`fabric_with`]) arms three
//! defenses-under-test: every endpoint carries a deterministic
//! [`FaultInjector`] with failpoints on the send path (delay,
//! drop-then-poison) and the payload envelope (bit-flip); payloads travel
//! the *checked* codec envelope so corruption is caught at decode and
//! poisons the generation with a typed `Corrupt` cause; and blocking
//! receives honor a **stall watchdog** deadline that converts a silent
//! hang into a typed `Stall` poisoning instead of blocking forever. A
//! plain fabric pays one `Option` branch per failpoint site — no clock
//! reads, no checksum arithmetic.

use super::codec::Codec;
use crate::runtime::fault::{self, FaultCause, FaultInjector, FaultPlan};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Communication phase tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
}

/// A tagged message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub layer: u32,
    pub phase: Phase,
    pub from: u32,
    /// Transfer id within the layer plan (unique per (from,to) pair).
    pub transfer: u32,
    /// Sub-transfer chunk id (0 for whole-transfer sends).
    pub chunk: u32,
    pub payload: Vec<f32>,
}

type Key = (u32, Phase, u32, u32, u32); // layer, phase, from, transfer, chunk

/// One entry of a [`Endpoint::recv_any`] want-list:
/// `(source rank, transfer id, chunk id)`.
pub type Want = (u32, u32, u32);

/// How long a blocked receive sleeps between checks of the fault flag.
const FAULT_POLL: Duration = Duration::from_millis(50);

/// Leading `try_recv` attempts of a blocking receive that spin with a CPU
/// hint — on the hot path the wanted message is usually already in
/// flight, and a spin beats parking the thread.
const SPIN_TRIES: usize = 16;

/// Further `try_recv` attempts that yield the core before the receive
/// falls back to a blocking timed wait, so a watchdog-length stall never
/// busy-burns a CPU.
const YIELD_TRIES: usize = 48;

/// Cap on recycled payload buffers kept per endpoint (bounds memory while
/// still covering every in-flight transfer of a layer step).
const MAX_SPARE_BUFS: usize = 32;

/// A recycled buffer whose capacity exceeds this multiple of the largest
/// payload the endpoint has recently handled is dropped instead of kept:
/// one spike of oversized batches must not pin worst-case allocations in
/// the spare list forever.
const SPARE_CAP_MULTIPLE: usize = 8;

/// Floor for the recent-payload watermark, so tiny control-sized payloads
/// don't make the spare list reject every normal buffer.
const SPARE_CAP_FLOOR: usize = 64;

/// Per-peer traffic counters kept by each [`Endpoint`] (one slot per
/// rank of the fabric, self included and always zero). Bytes are wire
/// bytes — what actually traveled, post-codec.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeerCounters {
    /// Messages this endpoint sent to the peer.
    pub sent_msgs: u64,
    /// Wire bytes this endpoint sent to the peer.
    pub sent_bytes: u64,
    /// Messages received **and consumed** from the peer (see
    /// [`Endpoint::stats`] for the consumption-time caveat).
    pub recv_msgs: u64,
    /// Wire bytes received and consumed from the peer.
    pub recv_bytes: u64,
}

/// Point-in-time copy of one endpoint's traffic counters, aggregate and
/// per-peer — the fabric's contribution to the
/// [`crate::obs::MetricsRegistry`].
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    /// Words sent as they traveled the wire (encoded payloads count
    /// encoded words).
    pub sent_words: u64,
    /// Messages sent.
    pub sent_msgs: u64,
    /// Pre-encoding payload bytes of every send.
    pub sent_raw_bytes: u64,
    /// Bytes actually put on the wire.
    pub sent_wire_bytes: u64,
    /// Messages received and consumed.
    pub recv_msgs: u64,
    /// Wire bytes received and consumed.
    pub recv_wire_bytes: u64,
    /// Per-peer breakdown, indexed by peer rank.
    pub peers: Vec<PeerCounters>,
}

/// Per-rank endpoint.
pub struct Endpoint {
    pub rank: u32,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Out-of-order arrivals, FIFO **per tag**: unsynchronized steady-state
    /// loops (e.g. a rank lapping a slower peer in a forward-only request
    /// stream) legitimately put two messages with the same tag in flight,
    /// and per-sender channel order guarantees the earlier pass's payload
    /// is queued first.
    stash: HashMap<Key, VecDeque<Vec<f32>>>,
    fault: Arc<AtomicBool>,
    /// Chaos failpoints ([`crate::runtime::fault`]); `None` (the plain
    /// build) costs one branch per failpoint site.
    faults: Option<FaultInjector>,
    /// Stall-watchdog deadline for blocking receives; `None` waits
    /// forever and never reads the clock.
    watchdog: Option<Duration>,
    /// True when payloads travel the checked (checksummed) codec
    /// envelope. Armed iff the fabric was built with a fault plan, and
    /// symmetric across endpoints so decoders know what to expect.
    wire_checked: bool,
    /// Recycled payload buffers: consumed receives return their allocation
    /// here and send sites reuse it, so a steady-state layer loop (and a
    /// pool rank serving a stream of requests) stops touching the
    /// allocator for payloads entirely.
    spare: Vec<Vec<f32>>,
    /// Decaying watermark of recently recycled payload lengths — the
    /// capacity bound for the spare list.
    recent_payload: usize,
    /// Counters: words sent (as they travel the wire — encoded payloads
    /// count encoded words), messages sent.
    pub sent_words: u64,
    pub sent_msgs: u64,
    /// Pre-encoding payload bytes of every send (element count × 4).
    pub sent_raw_bytes: u64,
    /// Bytes actually put on the wire (payload words × 4). Equal to
    /// `sent_raw_bytes` under [`Codec::F32`]; smaller under lossy codecs —
    /// the ratio is the live compression factor.
    pub sent_wire_bytes: u64,
    /// Messages received and consumed by this endpoint.
    pub recv_msgs: u64,
    /// Wire bytes received and consumed by this endpoint.
    pub recv_wire_bytes: u64,
    /// Per-peer send/recv breakdown, indexed by peer rank.
    peers: Vec<PeerCounters>,
}

impl Endpoint {
    /// Non-blocking send of a whole-transfer `payload` to `to` (chunk 0).
    pub fn send(&mut self, to: u32, layer: u32, phase: Phase, transfer: u32, payload: Vec<f32>) {
        self.send_chunk(to, layer, phase, transfer, 0, payload);
    }

    /// Non-blocking send of one sub-transfer chunk — the pipelined engine
    /// posts each chunk the moment its row range finishes computing.
    pub fn send_chunk(
        &mut self,
        to: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
        chunk: u32,
        payload: Vec<f32>,
    ) {
        let raw = 4 * payload.len() as u64;
        self.send_wire(to, layer, phase, transfer, chunk, payload, raw);
    }

    /// Encode `raw` with `codec` and send the wire payload. The raw buffer
    /// is recycled (it came from [`Endpoint::take_buf`] at the gather
    /// site); [`Codec::F32`] skips the copy entirely and sends `raw`
    /// itself — bit-identical to [`Endpoint::send_chunk`].
    ///
    /// On a chaos fabric every payload — F32 included — instead travels
    /// the checked codec envelope (checksummed, detectable at decode),
    /// and may be hit by the bit-flip failpoint on the way out.
    #[allow(clippy::too_many_arguments)]
    pub fn send_encoded(
        &mut self,
        to: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
        chunk: u32,
        codec: Codec,
        raw: Vec<f32>,
    ) {
        let raw_bytes = 4 * raw.len() as u64;
        if !self.wire_checked {
            if codec == Codec::F32 {
                self.send_wire(to, layer, phase, transfer, chunk, raw, raw_bytes);
                return;
            }
            let mut wire = self.take_buf();
            codec.encode_into(&raw, &mut wire);
            self.recycle(raw);
            self.send_wire(to, layer, phase, transfer, chunk, wire, raw_bytes);
            return;
        }
        let mut wire = self.take_buf();
        codec.encode_into_checked(&raw, &mut wire);
        self.recycle(raw);
        self.flip_failpoint(&mut wire);
        self.send_wire(to, layer, phase, transfer, chunk, wire, raw_bytes);
    }

    /// Decode an arrived payload with the codec its sender used. Returns a
    /// pool buffer holding the f32 values; the wire buffer is recycled.
    /// [`Codec::F32`] hands the payload back untouched.
    ///
    /// On a chaos fabric the payload arrives in the checked envelope: its
    /// checksum is verified before any decode, and a mismatch poisons the
    /// fabric with a typed `Corrupt` root cause instead of silently
    /// producing wrong activations.
    pub fn decode_payload(&mut self, codec: Codec, wire: Vec<f32>) -> Vec<f32> {
        if !self.wire_checked {
            if codec == Codec::F32 {
                return wire;
            }
            let mut out = self.take_buf();
            codec.decode_into(&wire, &mut out);
            self.recycle(wire);
            return out;
        }
        if !Codec::verify_checksum(&wire) {
            let cause = FaultCause::Corrupt {
                rank: self.rank,
                codec: codec.label().into(),
                words: wire.len(),
            };
            self.poison();
            panic!("{cause}");
        }
        let mut out = self.take_buf();
        codec.decode_checked_into(&wire, &mut out);
        self.recycle(wire);
        out
    }

    /// Encode `raw` into a wire payload **without sending it**, honoring
    /// the fabric's checked-envelope setting. The replica ring all-reduce
    /// uses this in its allgather phase: the owner of a fully-reduced
    /// gradient segment encodes it exactly once, keeps the buffer, and
    /// forwards the identical bytes around the ring
    /// ([`Endpoint::send_wire_payload`]) — so under a lossy codec every
    /// group decodes the *same* post-quantization values and replicas
    /// stay deterministically in sync.
    pub fn encode_wire(&mut self, codec: Codec, raw: &[f32]) -> Vec<f32> {
        let mut wire = self.take_buf();
        if self.wire_checked {
            codec.encode_into_checked(raw, &mut wire);
        } else {
            codec.encode_into(raw, &mut wire);
        }
        wire
    }

    /// Decode a wire payload **without consuming it** — the counterpart
    /// of [`Endpoint::encode_wire`] for ring stations that must both
    /// absorb a payload's values and forward its bytes verbatim. Checksum
    /// semantics match [`Endpoint::decode_payload`]: on a chaos fabric a
    /// corrupted payload poisons the generation with a typed `Corrupt`
    /// cause before any decode.
    pub fn decode_wire(&mut self, codec: Codec, wire: &[f32]) -> Vec<f32> {
        let mut out = self.take_buf();
        if !self.wire_checked {
            codec.decode_into(wire, &mut out);
            return out;
        }
        if !Codec::verify_checksum(wire) {
            let cause = FaultCause::Corrupt {
                rank: self.rank,
                codec: codec.label().into(),
                words: wire.len(),
            };
            self.poison();
            panic!("{cause}");
        }
        codec.decode_checked_into(wire, &mut out);
        out
    }

    /// Send an already-encoded wire payload **verbatim**. `raw_len` is
    /// the pre-encoding element count, so the raw-vs-wire byte counters
    /// (the live compression factor) stay truthful for forwarded
    /// payloads. The bit-flip failpoint still applies per hop on a chaos
    /// fabric — a forwarded payload can be corrupted in flight like any
    /// other, and the checked envelope catches it at the next decode.
    #[allow(clippy::too_many_arguments)]
    pub fn send_wire_payload(
        &mut self,
        to: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
        chunk: u32,
        mut wire: Vec<f32>,
        raw_len: usize,
    ) {
        if self.wire_checked {
            self.flip_failpoint(&mut wire);
        }
        let raw_bytes = 4 * raw_len as u64;
        self.send_wire(to, layer, phase, transfer, chunk, wire, raw_bytes);
    }

    /// True when payloads travel the checked (checksummed) codec
    /// envelope — wire-word accounting must then use
    /// [`Codec::checked_wire_words`] instead of [`Codec::wire_words`].
    pub fn wire_checked(&self) -> bool {
        self.wire_checked
    }

    /// The payload bit-flip failpoint: on a budgeted hit, XOR one random
    /// bit of one random non-header wire word, so the corruption is
    /// always detectable (the checked flag in word 0 survives).
    fn flip_failpoint(&mut self, wire: &mut [f32]) {
        let Some(inj) = self.faults.as_mut() else {
            return;
        };
        let spec = *inj.spec();
        if wire.len() > 1 && inj.roll_fault(spec.flip_p) {
            let word = 1 + inj.gen_range(wire.len() - 1);
            let bit = inj.gen_range(32);
            wire[word] = f32::from_bits(wire[word].to_bits() ^ (1u32 << bit));
        }
    }

    /// The send-path failpoints: an injected delay (free) and an injected
    /// drop (budgeted — the message never leaves, and the sender poisons
    /// the fabric with a typed `DroppedSend` cause so peers wake up).
    fn send_failpoints(&mut self, to: u32, layer: u32, phase: Phase) {
        let Some(inj) = self.faults.as_mut() else {
            return;
        };
        let spec = *inj.spec();
        if inj.roll_free(spec.delay_p) {
            std::thread::sleep(Duration::from_micros(spec.delay_us));
        }
        if inj.roll_fault(spec.drop_p) {
            let cause = FaultCause::DroppedSend {
                rank: self.rank,
                to: to as usize,
                wanted: format!("layer {layer} {phase:?}"),
            };
            self.poison();
            panic!("{cause}");
        }
    }

    /// The receive-path delay failpoint (free roll, shared `delay_p`).
    fn recv_delay_failpoint(&mut self) {
        let Some(inj) = self.faults.as_mut() else {
            return;
        };
        let spec = *inj.spec();
        if inj.roll_free(spec.delay_p) {
            std::thread::sleep(Duration::from_micros(spec.delay_us));
        }
    }

    /// The rank compute-loop failpoints, rolled once per job by the pool
    /// rank loop: an injected stall (sleep past the peers' watchdog) and
    /// an injected panic with a typed `ComputePanic` cause. Both are
    /// budgeted; inert without an armed plan.
    pub fn compute_failpoint(&mut self) {
        let Some(inj) = self.faults.as_mut() else {
            return;
        };
        let spec = *inj.spec();
        let stall = inj.roll_fault(spec.stall_p);
        let panic_now = inj.roll_fault(spec.panic_p);
        if stall {
            std::thread::sleep(Duration::from_millis(spec.stall_ms));
        }
        if panic_now {
            let cause = FaultCause::ComputePanic { rank: self.rank };
            self.poison();
            panic!("{cause}");
        }
    }

    /// The pool scheduler's dispatch-delay failpoint (free roll, shared
    /// `delay_p`, sleeping `dispatch_delay_us`); inert without a plan.
    pub fn dispatch_delay_failpoint(&mut self) {
        let Some(inj) = self.faults.as_mut() else {
            return;
        };
        let spec = *inj.spec();
        if inj.roll_free(spec.delay_p) {
            std::thread::sleep(Duration::from_micros(spec.dispatch_delay_us));
        }
    }

    /// Arm (or disarm, with `None`) the stall watchdog for this
    /// endpoint's blocking receives.
    pub fn set_watchdog(&mut self, deadline: Option<Duration>) {
        self.watchdog = deadline;
    }

    /// Innermost send: counts the payload as it travels the wire plus the
    /// raw (pre-encoding) bytes it represents, then pushes to the peer.
    #[allow(clippy::too_many_arguments)]
    fn send_wire(
        &mut self,
        to: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
        chunk: u32,
        payload: Vec<f32>,
        raw_bytes: u64,
    ) {
        if self.faults.is_some() {
            self.send_failpoints(to, layer, phase);
        }
        let wire_bytes = 4 * payload.len() as u64;
        self.sent_words += payload.len() as u64;
        self.sent_msgs += 1;
        self.sent_raw_bytes += raw_bytes;
        self.sent_wire_bytes += wire_bytes;
        let peer = &mut self.peers[to as usize];
        peer.sent_msgs += 1;
        peer.sent_bytes += wire_bytes;
        let msg = Msg {
            layer,
            phase,
            from: self.rank,
            transfer,
            chunk,
            payload,
        };
        // A disconnected peer means that rank died. During a poisoned
        // teardown that is an *expected consequence* of the root-cause
        // failure, not news: unwind with the standard secondary message so
        // the failure triage ([`crate::runtime::parallel`], the serving
        // pool) never mistakes this for an independent fault.
        if self.senders[to as usize].send(msg).is_err() {
            if self.poisoned() {
                panic!(
                    "fabric poisoned: a peer rank failed while rank {} was sending",
                    self.rank
                );
            }
            panic!("peer rank hung up");
        }
    }

    /// Count one consumed incoming message. Receives are counted when a
    /// recv call hands the payload to the engine, not when the message
    /// lands in the stash — so the counters always describe work the
    /// rank actually absorbed (stashed-but-never-consumed leaks show up
    /// in [`Endpoint::drained`], not here).
    #[inline]
    fn note_recv(&mut self, from: u32, words: usize) {
        if self.faults.is_some() {
            self.recv_delay_failpoint();
        }
        let wire_bytes = 4 * words as u64;
        self.recv_msgs += 1;
        self.recv_wire_bytes += wire_bytes;
        let peer = &mut self.peers[from as usize];
        peer.recv_msgs += 1;
        peer.recv_bytes += wire_bytes;
    }

    /// Point-in-time copy of the endpoint's traffic counters (aggregate
    /// send/recv plus the per-peer breakdown). Receive-side numbers count
    /// **consumed** messages: a payload stashed out-of-order is counted
    /// when the engine finally receives it, and one that is never
    /// consumed (a leak) is never counted.
    pub fn stats(&self) -> FabricStats {
        FabricStats {
            sent_words: self.sent_words,
            sent_msgs: self.sent_msgs,
            sent_raw_bytes: self.sent_raw_bytes,
            sent_wire_bytes: self.sent_wire_bytes,
            recv_msgs: self.recv_msgs,
            recv_wire_bytes: self.recv_wire_bytes,
            peers: self.peers.clone(),
        }
    }

    /// Pop the oldest stashed payload for `key`, dropping empty queues so
    /// [`Endpoint::drained`] stays a plain emptiness check.
    fn stash_pop(&mut self, key: &Key) -> Option<Vec<f32>> {
        let (payload, now_empty) = match self.stash.get_mut(key) {
            Some(q) => (q.pop_front(), q.is_empty()),
            None => return None,
        };
        if now_empty {
            self.stash.remove(key);
        }
        payload
    }

    fn stash_push(&mut self, key: Key, payload: Vec<f32>) {
        self.stash.entry(key).or_default().push_back(payload);
    }

    /// One bounded wait for the next inbox message: a short
    /// spin-then-yield burst over `try_recv` (cheap when the message is
    /// already in flight, core-friendly when it isn't), then a blocking
    /// timed wait of one fault-poll slice.
    fn next_msg(&mut self) -> Result<Msg, RecvTimeoutError> {
        for spin in 0..SPIN_TRIES + YIELD_TRIES {
            match self.inbox.try_recv() {
                Ok(m) => return Ok(m),
                Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => {
                    if spin < SPIN_TRIES {
                        std::hint::spin_loop();
                    } else {
                        std::thread::yield_now();
                    }
                }
            }
        }
        self.inbox.recv_timeout(FAULT_POLL)
    }

    /// Handle one timed-out wait slice of a blocking receive: unwind as a
    /// *secondary* failure if a peer already poisoned the fabric (checked
    /// first, so triage keeps preferring the root cause), then trip the
    /// stall watchdog — poison plus a typed `Stall` root cause — once the
    /// deadline set at call entry has passed.
    fn wait_tick(&mut self, deadline: &Option<(Instant, Duration)>, wanted: impl Fn() -> String) {
        if self.poisoned() {
            panic!(
                "fabric poisoned: a peer rank failed while rank {} waited",
                self.rank
            );
        }
        if let Some((start, limit)) = deadline {
            let waited = start.elapsed();
            if waited >= *limit {
                let cause = FaultCause::Stall {
                    rank: self.rank,
                    waited_ms: waited.as_millis() as u64,
                    wanted: wanted(),
                };
                self.poison();
                panic!("{cause}");
            }
        }
    }

    /// Blocking receive of the tagged message (oldest first if the tag is
    /// in flight more than once); out-of-order arrivals for other tags are
    /// stashed. Panics if the fabric is poisoned while waiting (a peer
    /// rank failed) or, with a watchdog armed, once the deadline expires.
    pub fn recv(&mut self, from: u32, layer: u32, phase: Phase, transfer: u32) -> Vec<f32> {
        let key: Key = (layer, phase, from, transfer, 0);
        if let Some(p) = self.stash_pop(&key) {
            self.note_recv(from, p.len());
            return p;
        }
        let deadline = self.watchdog.map(|limit| (Instant::now(), limit));
        loop {
            match self.next_msg() {
                Ok(m) => {
                    let k: Key = (m.layer, m.phase, m.from, m.transfer, m.chunk);
                    if k == key {
                        self.note_recv(from, m.payload.len());
                        return m.payload;
                    }
                    self.stash_push(k, m.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    self.wait_tick(&deadline, || {
                        format!("layer {layer} {phase:?} transfer {transfer} (from rank {from})")
                    });
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving");
                }
            }
        }
    }

    /// Non-blocking receive of a whole-transfer message (chunk 0): the
    /// payload if the uniquely-tagged message is already here (stashed or
    /// sitting in the channel), else `None`. Everything drained from the
    /// channel on the way is stashed, so no message is ever lost to a miss.
    pub fn try_recv(
        &mut self,
        from: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
    ) -> Option<Vec<f32>> {
        self.try_recv_chunk(from, layer, phase, transfer, 0)
    }

    /// [`Endpoint::try_recv`] for one sub-transfer chunk.
    pub fn try_recv_chunk(
        &mut self,
        from: u32,
        layer: u32,
        phase: Phase,
        transfer: u32,
        chunk: u32,
    ) -> Option<Vec<f32>> {
        let key: Key = (layer, phase, from, transfer, chunk);
        if let Some(p) = self.stash_pop(&key) {
            self.note_recv(from, p.len());
            return Some(p);
        }
        while let Ok(m) = self.inbox.try_recv() {
            let k: Key = (m.layer, m.phase, m.from, m.transfer, m.chunk);
            if k == key {
                self.note_recv(from, m.payload.len());
                return Some(m.payload);
            }
            self.stash_push(k, m.payload);
        }
        None
    }

    /// Block until **any** of the wanted `(from, transfer, chunk)` messages
    /// of `(layer, phase)` arrives; returns its index in `wants` plus the
    /// payload. Arrival order, not plan order — the overlapped engine
    /// applies each remote segment (and the pipelined engine each partial
    /// chunk payload) the moment its activations land.
    /// Panics if the fabric is poisoned while waiting or, with a watchdog
    /// armed, once the deadline expires with none of the wants arrived.
    pub fn recv_any(&mut self, layer: u32, phase: Phase, wants: &[Want]) -> (usize, Vec<f32>) {
        assert!(!wants.is_empty(), "recv_any needs at least one want");
        for (i, &(from, transfer, chunk)) in wants.iter().enumerate() {
            let key: Key = (layer, phase, from, transfer, chunk);
            if let Some(p) = self.stash_pop(&key) {
                self.note_recv(from, p.len());
                return (i, p);
            }
        }
        let deadline = self.watchdog.map(|limit| (Instant::now(), limit));
        loop {
            match self.next_msg() {
                Ok(m) => {
                    if m.layer == layer && m.phase == phase {
                        if let Some(i) = wants
                            .iter()
                            .position(|&(f, t, c)| f == m.from && t == m.transfer && c == m.chunk)
                        {
                            self.note_recv(m.from, m.payload.len());
                            return (i, m.payload);
                        }
                    }
                    self.stash_push((m.layer, m.phase, m.from, m.transfer, m.chunk), m.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    self.wait_tick(&deadline, || {
                        format!("layer {layer} {phase:?} (any of {wants:?})")
                    });
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving");
                }
            }
        }
    }

    /// An empty payload buffer, reusing a recycled allocation when one is
    /// available. Pair with [`Endpoint::recycle`] on the receive side.
    pub fn take_buf(&mut self) -> Vec<f32> {
        self.spare.pop().unwrap_or_default()
    }

    /// Return a consumed payload's allocation for reuse by later sends.
    ///
    /// The spare list is bounded in **count** (`MAX_SPARE_BUFS`) and in
    /// **capacity**: a decaying watermark tracks recent payload lengths,
    /// and buffers whose capacity exceeds `SPARE_CAP_MULTIPLE` times
    /// that watermark are dropped — so one spike of oversized batches
    /// through a long-lived pool endpoint cannot pin worst-case payload
    /// allocations forever. Because [`Endpoint::take_buf`] pops from the
    /// top of the stack, spares buried under it never re-enter `recycle`
    /// on their own — so every call also evicts stored spares the decayed
    /// watermark no longer justifies.
    pub fn recycle(&mut self, mut buf: Vec<f32>) {
        // decay by 1/16 per recycle, then absorb the new sample
        self.recent_payload = (self.recent_payload - self.recent_payload / 16).max(buf.len());
        let cap_bound = SPARE_CAP_MULTIPLE * self.recent_payload.max(SPARE_CAP_FLOOR);
        self.spare.retain(|b| b.capacity() <= cap_bound);
        if self.spare.len() < MAX_SPARE_BUFS && buf.capacity() <= cap_bound {
            buf.clear();
            self.spare.push(buf);
        }
    }

    /// Mark the whole fabric as failed, waking every blocked receiver.
    pub fn poison(&self) {
        self.fault.store(true, Ordering::Release);
    }

    /// True once any endpoint of this fabric called [`Endpoint::poison`].
    pub fn poisoned(&self) -> bool {
        self.fault.load(Ordering::Acquire)
    }

    /// True if no unconsumed messages remain (end-of-run check). Pulls
    /// anything still sitting in the channel into the stash first, so
    /// messages that were sent but never received also count as leaks.
    pub fn drained(&mut self) -> bool {
        while let Ok(m) = self.inbox.try_recv() {
            self.stash_push((m.layer, m.phase, m.from, m.transfer, m.chunk), m.payload);
        }
        self.stash.is_empty()
    }
}

/// Build a fully-connected fabric of `n` endpoints sharing one fault
/// flag, armed with the process-wide `SPDNN_FAULT` chaos plan (if any)
/// and that plan's watchdog deadline.
pub fn fabric(n: usize) -> Vec<Endpoint> {
    let plan = fault::from_env();
    let watchdog = plan.as_ref().and_then(|p| p.spec().watchdog());
    fabric_with(n, plan, watchdog)
}

/// [`fabric`] with an explicit chaos plan and stall-watchdog deadline.
/// Each endpoint derives its own deterministic injector stream from its
/// rank, and the checked wire envelope is armed iff a plan is installed
/// (symmetric across all endpoints), so a chaos-free fabric pays no
/// integrity cost.
pub fn fabric_with(
    n: usize,
    plan: Option<Arc<FaultPlan>>,
    watchdog: Option<Duration>,
) -> Vec<Endpoint> {
    let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let fault = Arc::new(AtomicBool::new(false));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank: rank as u32,
            senders: senders.clone(),
            inbox,
            stash: HashMap::new(),
            fault: fault.clone(),
            faults: plan
                .as_ref()
                .map(|p| FaultInjector::new(Arc::clone(p), rank as u64)),
            watchdog,
            wire_checked: plan.is_some(),
            spare: Vec::new(),
            recent_payload: 0,
            sent_words: 0,
            sent_msgs: 0,
            sent_raw_bytes: 0,
            sent_wire_bytes: 0,
            recv_msgs: 0,
            recv_wire_bytes: 0,
            peers: vec![PeerCounters::default(); n],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_roundtrip() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            e1.send(0, 3, Phase::Forward, 7, vec![1.0, 2.0]);
            e1
        });
        let p = e0.recv(1, 3, Phase::Forward, 7);
        assert_eq!(p, vec![1.0, 2.0]);
        let e1 = t.join().unwrap();
        assert_eq!(e1.sent_words, 2);
        assert_eq!(e1.sent_msgs, 1);
    }

    #[test]
    fn out_of_order_stash() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            // send layer 1 before layer 0
            e1.send(0, 1, Phase::Forward, 0, vec![10.0]);
            e1.send(0, 0, Phase::Forward, 0, vec![20.0]);
            e1.send(0, 0, Phase::Backward, 0, vec![30.0]);
        });
        assert_eq!(e0.recv(1, 0, Phase::Forward, 0), vec![20.0]);
        assert_eq!(e0.recv(1, 0, Phase::Backward, 0), vec![30.0]);
        assert_eq!(e0.recv(1, 1, Phase::Forward, 0), vec![10.0]);
        assert!(e0.drained());
        t.join().unwrap();
    }

    #[test]
    fn many_ranks_all_to_all() {
        let n = 8;
        let eps = fabric(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    let me = e.rank;
                    for to in 0..n as u32 {
                        if to != me {
                            e.send(to, 0, Phase::Forward, me, vec![me as f32]);
                        }
                    }
                    let mut sum = 0.0;
                    for from in 0..n as u32 {
                        if from != me {
                            sum += e.recv(from, 0, Phase::Forward, from)[0];
                        }
                    }
                    sum
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let sum = h.join().unwrap();
            let expect: f32 = (0..n as u32).filter(|&x| x != i as u32).map(|x| x as f32).sum();
            assert_eq!(sum, expect);
        }
    }

    #[test]
    fn try_recv_misses_then_hits_and_stashes() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert!(e0.try_recv(1, 0, Phase::Forward, 0).is_none());
        e1.send(0, 1, Phase::Forward, 5, vec![9.0]); // wrong tag: stashed
        e1.send(0, 0, Phase::Forward, 0, vec![1.0, 2.0]);
        // give the in-process channel a moment to flush
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let p = loop {
            if let Some(p) = e0.try_recv(1, 0, Phase::Forward, 0) {
                break p;
            }
            assert!(std::time::Instant::now() < deadline, "message never arrived");
            std::thread::yield_now();
        };
        assert_eq!(p, vec![1.0, 2.0]);
        // the mis-tagged message was stashed, not dropped
        assert_eq!(e0.recv(1, 1, Phase::Forward, 5), vec![9.0]);
        assert!(e0.drained());
    }

    #[test]
    fn recv_any_returns_in_arrival_order() {
        let mut eps = fabric(3);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // rank 2 sends immediately; rank 1 sends late
        let t2 = std::thread::spawn(move || e2.send(0, 0, Phase::Forward, 7, vec![2.0]));
        let t1 = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            e1.send(0, 0, Phase::Forward, 3, vec![1.0]);
        });
        let wants = [(1u32, 3u32, 0u32), (2u32, 7u32, 0u32)];
        let (i, p) = e0.recv_any(0, Phase::Forward, &wants);
        assert_eq!((i, p), (1, vec![2.0]), "late sender must not block the early one");
        let (i, p) = e0.recv_any(0, Phase::Forward, &wants);
        assert_eq!((i, p), (0, vec![1.0]));
        t1.join().unwrap();
        t2.join().unwrap();
        assert!(e0.drained());
    }

    #[test]
    fn recv_any_checks_stash_and_ignores_other_tags() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 9, Phase::Backward, 0, vec![5.0]); // unrelated tag
        e1.send(0, 2, Phase::Forward, 1, vec![6.0]);
        // blocking recv of the unrelated tag stashes the wanted one
        assert_eq!(e0.recv(1, 9, Phase::Backward, 0), vec![5.0]);
        let (i, p) = e0.recv_any(2, Phase::Forward, &[(1, 1, 0)]);
        assert_eq!((i, p), (0, vec![6.0]));
        assert!(e0.drained());
    }

    #[test]
    fn duplicate_tags_deliver_in_fifo_order() {
        // A rank lapping a slower peer reuses tags; the stash must queue
        // duplicates (never overwrite) and deliver oldest-first.
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 0, Phase::Forward, 0, vec![1.0]); // pass 1
        e1.send(0, 0, Phase::Forward, 0, vec![2.0]); // pass 2, same tag
        e1.send(0, 1, Phase::Forward, 0, vec![9.0]);
        // receiving the unrelated tag stashes BOTH same-key duplicates
        assert_eq!(e0.recv(1, 1, Phase::Forward, 0), vec![9.0]);
        assert_eq!(e0.recv(1, 0, Phase::Forward, 0), vec![1.0]);
        assert_eq!(e0.try_recv(1, 0, Phase::Forward, 0), Some(vec![2.0]));
        assert!(e0.drained());
        // and via recv_any too
        e1.send(0, 2, Phase::Backward, 3, vec![4.0]);
        e1.send(0, 2, Phase::Backward, 3, vec![5.0]);
        e1.send(0, 7, Phase::Forward, 0, vec![8.0]);
        assert_eq!(e0.recv(1, 7, Phase::Forward, 0), vec![8.0]);
        let wants = [(1u32, 3u32, 0u32)];
        assert_eq!(e0.recv_any(2, Phase::Backward, &wants), (0, vec![4.0]));
        assert_eq!(e0.recv_any(2, Phase::Backward, &wants), (0, vec![5.0]));
        assert!(e0.drained());
    }

    #[test]
    fn chunked_subtransfers_match_by_chunk_id_in_arrival_order() {
        // One logical transfer posted as three chunks, deliberately out of
        // chunk order: recv_any must hand them back as they arrive, keyed
        // by (from, transfer, chunk), and try_recv_chunk must hit too.
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send_chunk(0, 4, Phase::Forward, 2, 1, vec![10.0]);
        e1.send_chunk(0, 4, Phase::Forward, 2, 0, vec![20.0]);
        e1.send_chunk(0, 4, Phase::Forward, 2, 2, vec![30.0]);
        let mut wants = vec![(1u32, 2u32, 0u32), (1, 2, 1), (1, 2, 2)];
        let mut got = vec![0f32; 3];
        while !wants.is_empty() {
            let (i, p) = e0.recv_any(4, Phase::Forward, &wants);
            got[wants[i].2 as usize] = p[0];
            wants.swap_remove(i);
        }
        assert_eq!(got, vec![20.0, 10.0, 30.0]);
        assert!(e0.drained());
        // a chunked send is NOT visible to a chunk-0 (whole-transfer) recv
        e1.send_chunk(0, 5, Phase::Forward, 0, 3, vec![7.0]);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            assert!(e0.try_recv(1, 5, Phase::Forward, 0).is_none());
            if let Some(p) = e0.try_recv_chunk(1, 5, Phase::Forward, 0, 3) {
                assert_eq!(p, vec![7.0]);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "chunk never arrived");
            std::thread::yield_now();
        }
        assert!(e0.drained());
    }

    #[test]
    fn send_to_gone_peer_on_poisoned_fabric_reports_poisoning() {
        // A peer endpoint dropped during a poisoned teardown must surface
        // the standard secondary "fabric poisoned" message, not the
        // misleading independent "peer rank hung up" panic.
        let mut eps = fabric(2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.poison();
        drop(e1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.send(1, 0, Phase::Forward, 0, vec![1.0])
        }))
        .expect_err("send to a dropped peer must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("fabric poisoned"), "{msg}");
        // without poisoning, the hang-up is an independent fault
        let mut eps = fabric(2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.send(1, 0, Phase::Forward, 0, vec![1.0])
        }))
        .expect_err("send to a dropped peer must panic");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .unwrap_or_default();
        assert!(msg.contains("peer rank hung up"), "{msg}");
    }

    #[test]
    fn recycle_drops_buffers_far_above_recent_payload_size() {
        let mut eps = fabric(1);
        let mut e = eps.pop().unwrap();
        // steady small traffic establishes the watermark
        for _ in 0..32 {
            let mut b = e.take_buf();
            b.resize(100, 0.0);
            e.recycle(b);
        }
        let spare_before = e.spare.len();
        // an over-reserved allocation far above the watermark must not be
        // retained by the spare list
        let huge = Vec::with_capacity(100 * SPARE_CAP_MULTIPLE * 100);
        e.recycle(huge);
        assert_eq!(e.spare.len(), spare_before, "oversized buffer was pinned");
        assert!(e.spare.iter().all(|b| b.capacity() < 100 * SPARE_CAP_MULTIPLE * 100));
        // steady LARGE traffic is retained: the watermark follows the load
        for _ in 0..8 {
            e.recycle(vec![0.0f32; 50_000]);
        }
        assert!(
            e.spare.iter().any(|b| b.capacity() >= 50_000),
            "legitimate steady-state large buffers must be reusable"
        );
    }

    #[test]
    fn recycle_unpins_spike_buffers_after_traffic_shrinks() {
        // The regression ISSUE names: a spike of genuinely large payloads
        // (len == capacity) is retained at spike time, sinks below the
        // LIFO top, and would otherwise stay pinned forever once traffic
        // returns to small batches — the watermark decay must evict it.
        let mut eps = fabric(1);
        let mut e = eps.pop().unwrap();
        for _ in 0..4 {
            e.recycle(vec![0.0f32; 50_000]);
        }
        assert!(
            e.spare.iter().any(|b| b.capacity() >= 50_000),
            "spike buffers are retained while the load looks large"
        );
        // small traffic resumes; the watermark decays by 1/16 per recycle,
        // and once 8x the watermark drops below the spike capacity the
        // stored spares are evicted even though they never re-enter
        // recycle themselves
        for _ in 0..200 {
            let mut b = e.take_buf();
            b.resize(100, 0.0);
            e.recycle(b);
        }
        assert!(
            e.spare.iter().all(|b| b.capacity() < 50_000),
            "spike allocations stayed pinned after the load shrank"
        );
        assert!(!e.spare.is_empty(), "normal-size buffers are still pooled");
    }

    #[test]
    fn recycled_buffers_are_reused_and_bounded() {
        let mut eps = fabric(1);
        let mut e = eps.pop().unwrap();
        let mut buf = e.take_buf();
        assert!(buf.is_empty());
        buf.extend_from_slice(&[1.0, 2.0, 3.0]);
        let cap = buf.capacity();
        e.recycle(buf);
        let again = e.take_buf();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap, "allocation must be reused");
        e.recycle(again);
        for _ in 0..100 {
            e.recycle(Vec::with_capacity(8));
        }
        assert!(e.spare.len() <= MAX_SPARE_BUFS);
    }

    #[test]
    fn encoded_send_recv_roundtrip_and_byte_counters() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let vals: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.03).collect();
        // F32: bit-identical wire payload, raw == wire bytes
        e1.send_encoded(0, 0, Phase::Forward, 0, 0, Codec::F32, vals.clone());
        assert_eq!(e1.sent_wire_bytes, 400);
        assert_eq!(e1.sent_raw_bytes, 400);
        let p = e0.recv(1, 0, Phase::Forward, 0);
        let p = e0.decode_payload(Codec::F32, p);
        assert_eq!(p, vals);
        e0.recycle(p);
        // F16: ~half the wire bytes, raw bytes still count the elements
        e1.send_encoded(0, 1, Phase::Forward, 0, 0, Codec::F16, vals.clone());
        assert_eq!(e1.sent_raw_bytes, 800);
        assert_eq!(e1.sent_wire_bytes, 400 + Codec::F16.wire_bytes(100));
        assert!(Codec::F16.wire_bytes(100) <= 220, "f16 must ~halve bytes");
        let p = e0.recv(1, 1, Phase::Forward, 0);
        assert_eq!(p.len(), Codec::F16.wire_words(100));
        let p = e0.decode_payload(Codec::F16, p);
        assert_eq!(p.len(), 100);
        for (a, b) in p.iter().zip(vals.iter()) {
            assert!((a - b).abs() <= b.abs() * 5e-4 + 1e-6);
        }
        e0.recycle(p);
        assert!(e0.drained());
    }

    #[test]
    fn wire_helpers_forward_identical_bytes() {
        use crate::runtime::fault::{FaultPlan, FaultSpec};
        // the replica allgather contract: encode once, decode without
        // consuming, forward the identical bytes — the receiver decodes
        // the exact same values the owner kept. Plain and chaos fabrics.
        let vals: Vec<f32> = (0..70).map(|i| (i as f32 - 35.0) * 0.11).collect();
        for plan in [None, Some(FaultPlan::new(FaultSpec::default()))] {
            for codec in [Codec::F32, Codec::F16, Codec::int8()] {
                let mut eps = fabric_with(2, plan.clone(), None);
                let mut e1 = eps.pop().unwrap();
                let mut e0 = eps.pop().unwrap();
                let wire = e0.encode_wire(codec, &vals);
                let kept = e0.decode_wire(codec, &wire);
                let wire_bits: Vec<u32> = wire.iter().map(|w| w.to_bits()).collect();
                e0.send_wire_payload(1, 3, Phase::Backward, 2, 0, wire, vals.len());
                assert_eq!(e0.sent_raw_bytes, 4 * vals.len() as u64);
                let arrived = e1.recv(0, 3, Phase::Backward, 2);
                let got_bits: Vec<u32> = arrived.iter().map(|w| w.to_bits()).collect();
                assert_eq!(got_bits, wire_bits, "{codec:?}: forward must be verbatim");
                let decoded = e1.decode_wire(codec, &arrived);
                for (a, b) in decoded.iter().zip(kept.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?}: groups must agree");
                }
                e1.recycle(arrived);
                assert!(e1.drained());
            }
        }
    }

    #[test]
    fn per_peer_counters_track_consumed_traffic() {
        let mut eps = fabric(3);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 0, Phase::Forward, 0, vec![1.0, 2.0]);
        e1.send(0, 1, Phase::Forward, 0, vec![3.0]);
        e2.send(0, 0, Phase::Forward, 1, vec![4.0, 5.0, 6.0]);
        let s1 = e1.stats();
        assert_eq!(s1.peers[0].sent_msgs, 2);
        assert_eq!(s1.peers[0].sent_bytes, 12);
        assert_eq!(s1.peers[2], PeerCounters::default());
        assert_eq!(s1.sent_msgs, 2);
        // nothing consumed yet: recv side still zero even though the
        // messages are in flight
        assert_eq!(e0.stats().recv_msgs, 0);
        let _ = e0.recv(1, 0, Phase::Forward, 0);
        let _ = e0.recv(2, 0, Phase::Forward, 1);
        // the layer-1 message was drained into the stash by the receives
        // above but not consumed — it must not be counted yet
        let s0 = e0.stats();
        assert_eq!(s0.recv_msgs, 2);
        assert_eq!(s0.recv_wire_bytes, 8 + 12);
        assert_eq!(s0.peers[1].recv_msgs, 1);
        assert_eq!(s0.peers[1].recv_bytes, 8);
        assert_eq!(s0.peers[2].recv_msgs, 1);
        assert_eq!(s0.peers[2].recv_bytes, 12);
        // consuming the stashed message counts it, from the stash path
        let _ = e0.recv(1, 1, Phase::Forward, 0);
        let s0 = e0.stats();
        assert_eq!(s0.recv_msgs, 3);
        assert_eq!(s0.peers[1].recv_msgs, 2);
        assert_eq!(s0.peers[1].recv_bytes, 12);
        assert!(e0.drained());
    }

    #[test]
    fn watchdog_converts_silent_stall_to_typed_poison() {
        // no plan, just a watchdog: a receive nobody will answer must trip
        // within the deadline, poison the fabric, and name what it waited
        // for — instead of hanging forever.
        let mut eps = fabric_with(2, None, Some(Duration::from_millis(60)));
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let start = std::time::Instant::now();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.recv(1, 3, Phase::Forward, 2)
        }))
        .expect_err("unanswered recv must trip the watchdog");
        assert!(start.elapsed() < Duration::from_secs(5), "trip must be prompt");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("stall watchdog"), "{msg}");
        assert!(msg.contains("layer 3"), "{msg}");
        assert!(e0.poisoned(), "the trip must poison the fabric");
        // recv_any trips too, listing its wants
        let mut eps = fabric_with(2, None, Some(Duration::from_millis(60)));
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.recv_any(1, Phase::Backward, &[(1, 0, 0)])
        }))
        .expect_err("unanswered recv_any must trip the watchdog");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("stall watchdog"), "{msg}");
    }

    #[test]
    fn poisoning_beats_the_watchdog() {
        // a rank observing a peer's poison while its own watchdog is armed
        // must unwind as a *secondary* failure, preserving triage order
        let mut eps = fabric_with(2, None, Some(Duration::from_secs(30)));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.poison();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.recv(1, 0, Phase::Forward, 0)
        }))
        .expect_err("poisoned wait must unwind");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("fabric poisoned"), "{msg}");
    }

    #[test]
    fn checked_envelope_roundtrips_all_codecs() {
        use crate::runtime::fault::{FaultPlan, FaultSpec};
        // an inert plan (all probabilities zero) still arms the checked
        // envelope; payloads must roundtrip losslessly through it
        let vals: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.03).collect();
        for codec in [Codec::F32, Codec::F16, Codec::int8()] {
            let plan = FaultPlan::new(FaultSpec::default());
            let mut eps = fabric_with(2, Some(plan), None);
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            e1.send_encoded(0, 0, Phase::Forward, 0, 0, codec, vals.clone());
            // F32 loses zero-copy under chaos: header + body + checksum
            assert_eq!(
                e1.sent_wire_bytes,
                4 * codec.checked_wire_words(vals.len()) as u64
            );
            assert_eq!(e1.sent_raw_bytes, 400);
            let p = e0.recv(1, 0, Phase::Forward, 0);
            assert!(Codec::payload_checked(&p));
            let p = e0.decode_payload(codec, p);
            assert_eq!(p.len(), vals.len());
            if codec == Codec::F32 {
                assert_eq!(p, vals, "checked F32 must stay lossless");
            }
            e0.recycle(p);
            assert!(e0.drained());
        }
    }

    #[test]
    fn corrupted_payload_is_detected_at_decode() {
        use crate::runtime::fault::{FaultPlan, FaultSpec};
        let plan = FaultPlan::new(FaultSpec::default());
        let mut eps = fabric_with(2, Some(plan), None);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let vals: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        e1.send_encoded(0, 0, Phase::Forward, 0, 0, Codec::F16, vals);
        let mut p = e0.recv(1, 0, Phase::Forward, 0);
        p[3] = f32::from_bits(p[3].to_bits() ^ (1 << 9)); // in-flight bit-flip
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e0.decode_payload(Codec::F16, p)
        }))
        .expect_err("corrupt payload must not decode");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(e0.poisoned(), "corruption must poison the generation");
    }

    #[test]
    fn flip_failpoint_produces_detectable_corruption() {
        use crate::runtime::fault::{FaultPlan, FaultSpec};
        // a certain flip with budget 1: the first encoded send is
        // corrupted (detectably), the second is clean
        let plan = FaultPlan::new(FaultSpec {
            flip_p: 1.0,
            budget: 1,
            ..FaultSpec::default()
        });
        let mut eps = fabric_with(2, Some(Arc::clone(&plan)), None);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let vals: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        e1.send_encoded(0, 0, Phase::Forward, 0, 0, Codec::F32, vals.clone());
        e1.send_encoded(0, 1, Phase::Forward, 0, 0, Codec::F32, vals.clone());
        assert_eq!(plan.injected(), 1);
        let p = e0.recv(1, 0, Phase::Forward, 0);
        assert!(!Codec::verify_checksum(&p), "flip must break the checksum");
        assert!(Codec::payload_checked(&p), "header flag must survive the flip");
        let clean = e0.recv(1, 1, Phase::Forward, 0);
        let clean = e0.decode_payload(Codec::F32, clean);
        assert_eq!(clean, vals, "budget-exhausted sends are untouched");
    }

    #[test]
    fn drop_failpoint_poisons_with_root_cause() {
        use crate::runtime::fault::{FaultPlan, FaultSpec};
        let plan = FaultPlan::new(FaultSpec {
            drop_p: 1.0,
            budget: 1,
            ..FaultSpec::default()
        });
        let mut eps = fabric_with(2, Some(plan), None);
        let mut e1 = eps.pop().unwrap();
        let _e0 = eps.pop().unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e1.send(0, 2, Phase::Forward, 0, vec![1.0])
        }))
        .expect_err("a dropped send must panic the sender");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("dropped send"), "{msg}");
        assert!(msg.contains("layer 2"), "{msg}");
        assert!(e1.poisoned(), "the drop must poison the fabric");
    }

    #[test]
    fn poison_unblocks_blocked_receiver() {
        let mut eps = fabric(2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e0.recv(1, 0, Phase::Forward, 0)
            }));
            r.is_err()
        });
        // let the receiver block, then poison instead of sending
        std::thread::sleep(Duration::from_millis(10));
        e1.poison();
        assert!(e1.poisoned());
        assert!(t.join().unwrap(), "blocked receiver did not unwind");
    }
}
