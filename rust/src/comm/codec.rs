//! Wire codecs: compressed activation / gradient payloads for the fabric.
//!
//! The partitioner minimizes communication volume in *words*; the codec
//! layer shrinks the bytes each word costs on the wire, composing
//! multiplicatively with the cut reduction. Three codecs:
//!
//! - [`Codec::F32`] — lossless passthrough. The wire payload is the raw
//!   `f32` slice, bit-identical to the pre-codec fabric (no header, no
//!   reshaping), so the default path costs nothing and live word counters
//!   still equal the plan's volume exactly.
//! - [`Codec::F16`] — IEEE 754 binary16 with round-to-nearest-even,
//!   two halves packed per wire word: ~2× fewer bytes, ≤ 2⁻¹¹ relative
//!   error over the normal range (sigmoid activations and SGD gradients
//!   sit comfortably inside it).
//! - [`Codec::Int8`] — symmetric absmax-scaled 8-bit quantization, four
//!   lanes per wire word, one f32 scale per `group` elements carried in
//!   the header: ~4× fewer bytes, error ≤ half a quantization step of the
//!   group's absmax. A group whose absmax is 0 (or non-finite) encodes
//!   scale 0 and decodes to exact zeros — decode never manufactures NaN.
//!
//! **Wire format.** The fabric transports `Vec<f32>` payloads, so encoded
//! bytes are packed into `f32` words via bit-casts (the buffer pool and
//! channel plumbing stay untouched). Lossy codecs are self-describing:
//!
//! ```text
//! word 0   MAGIC (upper 16 bits) | checked flag (bit 15) | codec id
//! word 1   element count
//! words 2… Int8 only: one f32 scale per group
//! rest     packed elements (2 halves / 4 int8 lanes per word)
//! ```
//!
//! [`Codec::wire_words`] / [`Codec::wire_bytes`] give the exact on-wire
//! footprint for any payload length — the same arithmetic the α-β network
//! model ([`crate::comm::netmodel`]) and the live byte counters use, so
//! predicted and measured volumes agree.
//!
//! **Checked envelope.** Chaos builds (an armed `SPDNN_FAULT` plan)
//! transport every payload through [`Codec::encode_into_checked`]: the
//! standard encoding with the header's checked flag set — [`Codec::F32`],
//! normally headerless, gains header framing — plus one trailing FNV-1a
//! checksum word, so a corrupted payload is detected at decode instead of
//! silently producing wrong activations. The unchecked hot path is
//! byte-identical to before and pays no checksum arithmetic.

/// Bit pattern marking an encoded payload's header word.
const MAGIC: u32 = 0xC0DE_0000;
const MAGIC_MASK: u32 = 0xFFFF_0000;
/// Header words before the (per-codec) scale block.
const HDR_WORDS: usize = 2;
/// Checked-envelope flag, set in the id halfword of header word 0 (codec
/// ids occupy the low bits; bit 15 is free).
const CHECKED_FLAG: u32 = 0x8000;

/// Elements per Int8 scale group when none is given (`group == 0`).
pub const DEFAULT_INT8_GROUP: usize = 256;

/// A wire codec for fabric payloads. `Copy` and tiny: the plan stores one
/// per layer per phase and the engines read it on every transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Lossless raw-f32 passthrough (the pre-codec wire format).
    #[default]
    F32,
    /// IEEE binary16, round-to-nearest-even, 2 elements per wire word.
    F16,
    /// Symmetric absmax int8, 4 elements per wire word, one f32 scale per
    /// `group` elements (0 = [`DEFAULT_INT8_GROUP`]).
    Int8 {
        /// Elements sharing one absmax scale. Smaller groups track local
        /// dynamic range better but spend more header words.
        group: usize,
    },
}

impl Codec {
    /// The int8 codec with the default scale-group size.
    pub fn int8() -> Self {
        Codec::Int8 { group: 0 }
    }

    /// Wire id carried in the header (and the CLI/env spelling).
    pub fn id(&self) -> u16 {
        match self {
            Codec::F32 => 0,
            Codec::F16 => 1,
            Codec::Int8 { .. } => 2,
        }
    }

    /// Parse a CLI/env spelling (`f32` | `f16` | `int8`). `None` on
    /// anything else.
    pub fn parse(s: &str) -> Option<Codec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "raw" => Some(Codec::F32),
            "f16" | "half" => Some(Codec::F16),
            "int8" | "i8" | "q8" => Some(Codec::int8()),
            _ => None,
        }
    }

    /// Display spelling, matching [`Codec::parse`].
    pub fn label(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::F16 => "f16",
            Codec::Int8 { .. } => "int8",
        }
    }

    fn int8_group(group: usize) -> usize {
        if group == 0 {
            DEFAULT_INT8_GROUP
        } else {
            group
        }
    }

    /// Exact wire footprint, in f32 words, of a `len`-element payload.
    pub fn wire_words(&self, len: usize) -> usize {
        match *self {
            Codec::F32 => len,
            Codec::F16 => HDR_WORDS + len.div_ceil(2),
            Codec::Int8 { group } => {
                let g = Self::int8_group(group);
                HDR_WORDS + len.div_ceil(g) + len.div_ceil(4)
            }
        }
    }

    /// Exact wire footprint in bytes (wire words × 4).
    pub fn wire_bytes(&self, len: usize) -> u64 {
        4 * self.wire_words(len) as u64
    }

    /// Exact wire footprint, in f32 words, of a `len`-element payload in
    /// the checked envelope: the standard encoding plus the trailing
    /// checksum word ([`Codec::F32`] additionally gains header framing).
    pub fn checked_wire_words(&self, len: usize) -> usize {
        match *self {
            Codec::F32 => HDR_WORDS + len + 1,
            _ => self.wire_words(len) + 1,
        }
    }

    /// Encode `src` into `dst` (cleared first). On return `dst.len()`
    /// equals [`Codec::wire_words`]`(src.len())`.
    pub fn encode_into(&self, src: &[f32], dst: &mut Vec<f32>) {
        dst.clear();
        match *self {
            Codec::F32 => dst.extend_from_slice(src),
            Codec::F16 => {
                dst.reserve(self.wire_words(src.len()));
                push_header(dst, self.id(), src.len());
                for pair in src.chunks(2) {
                    let lo = f32_to_f16_bits(pair[0]) as u32;
                    let hi = if pair.len() > 1 {
                        f32_to_f16_bits(pair[1]) as u32
                    } else {
                        0
                    };
                    dst.push(f32::from_bits(lo | (hi << 16)));
                }
            }
            Codec::Int8 { group } => {
                let g = Self::int8_group(group);
                dst.reserve(self.wire_words(src.len()));
                push_header(dst, self.id(), src.len());
                // scales live in the header block of dst itself — no
                // scratch allocation on the send path
                for grp in src.chunks(g) {
                    dst.push(int8_scale_of(grp));
                }
                for (qi, quad) in src.chunks(4).enumerate() {
                    let mut word = 0u32;
                    for (lane, &x) in quad.iter().enumerate() {
                        let scale = dst[HDR_WORDS + (qi * 4 + lane) / g];
                        let q = quantize_i8(x, scale);
                        word |= ((q as u8) as u32) << (8 * lane);
                    }
                    dst.push(f32::from_bits(word));
                }
            }
        }
    }

    /// Decode a wire payload into `dst` (cleared first). Panics if the
    /// header does not match this codec — a tagging bug upstream, never a
    /// recoverable condition on the hot path.
    pub fn decode_into(&self, wire: &[f32], dst: &mut Vec<f32>) {
        dst.clear();
        match *self {
            Codec::F32 => dst.extend_from_slice(wire),
            Codec::F16 => {
                let count = read_header(wire, self.id());
                dst.reserve(count);
                for i in 0..count {
                    let word = wire[HDR_WORDS + i / 2].to_bits();
                    let half = if i % 2 == 0 { word } else { word >> 16 } as u16;
                    dst.push(f16_bits_to_f32(half));
                }
            }
            Codec::Int8 { group } => {
                let g = Self::int8_group(group);
                let count = read_header(wire, self.id());
                let nscales = count.div_ceil(g);
                dst.reserve(count);
                for i in 0..count {
                    let scale = wire[HDR_WORDS + i / g];
                    let word = wire[HDR_WORDS + nscales + i / 4].to_bits();
                    let q = ((word >> (8 * (i % 4))) & 0xFF) as u8 as i8;
                    dst.push(q as f32 * scale);
                }
            }
        }
    }

    /// Encode `src` into the *checked* wire envelope (cleared first):
    /// the standard encoding with the header's checked flag set, plus a
    /// trailing FNV-1a checksum word over every preceding wire word. On
    /// return `dst.len()` equals
    /// [`Codec::checked_wire_words`]`(src.len())`.
    pub fn encode_into_checked(&self, src: &[f32], dst: &mut Vec<f32>) {
        match *self {
            Codec::F32 => {
                dst.clear();
                dst.reserve(self.checked_wire_words(src.len()));
                push_header(dst, self.id(), src.len());
                dst.extend_from_slice(src);
            }
            _ => self.encode_into(src, dst),
        }
        dst[0] = f32::from_bits(dst[0].to_bits() | CHECKED_FLAG);
        let h = fnv1a(dst);
        dst.push(f32::from_bits(h));
    }

    /// Decode a checked-envelope payload (see
    /// [`Codec::encode_into_checked`]) into `dst` (cleared first). The
    /// caller must have validated integrity with
    /// [`Codec::verify_checksum`] first — this routine only unwraps the
    /// framing (the element count is header-driven, so the trailing
    /// checksum word is naturally ignored).
    pub fn decode_checked_into(&self, wire: &[f32], dst: &mut Vec<f32>) {
        match *self {
            Codec::F32 => {
                let count = read_header(wire, self.id());
                dst.clear();
                dst.extend_from_slice(&wire[HDR_WORDS..HDR_WORDS + count]);
            }
            _ => self.decode_into(wire, dst),
        }
    }

    /// True when a wire payload carries the checked-envelope flag.
    pub fn payload_checked(wire: &[f32]) -> bool {
        wire.first().is_some_and(|w| {
            let bits = w.to_bits();
            bits & MAGIC_MASK == MAGIC && bits & CHECKED_FLAG != 0
        })
    }

    /// Recompute the FNV-1a checksum of a checked-envelope payload and
    /// compare it against the trailing word: false on any corruption
    /// (including payloads too short to carry an envelope at all).
    pub fn verify_checksum(wire: &[f32]) -> bool {
        wire.len() > HDR_WORDS && fnv1a(&wire[..wire.len() - 1]) == wire[wire.len() - 1].to_bits()
    }
}

/// FNV-1a (32-bit) over the little-endian bytes of each wire word.
fn fnv1a(words: &[f32]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for w in words {
        for b in w.to_bits().to_le_bytes() {
            h ^= b as u32;
            h = h.wrapping_mul(16_777_619);
        }
    }
    h
}

fn push_header(dst: &mut Vec<f32>, id: u16, count: usize) {
    dst.push(f32::from_bits(MAGIC | id as u32));
    dst.push(f32::from_bits(count as u32));
}

fn read_header(wire: &[f32], expect_id: u16) -> usize {
    assert!(wire.len() >= HDR_WORDS, "encoded payload shorter than header");
    let w0 = wire[0].to_bits();
    assert_eq!(w0 & MAGIC_MASK, MAGIC, "payload is not codec-encoded");
    assert_eq!(
        (w0 & !MAGIC_MASK & !CHECKED_FLAG) as u16,
        expect_id,
        "payload encoded with a different codec"
    );
    wire[1].to_bits() as usize
}

/// Absmax-derived quantization scale of one group; 0 when the group is
/// all-zero or contains nothing finite to calibrate against.
fn int8_scale_of(grp: &[f32]) -> f32 {
    let absmax = grp
        .iter()
        .map(|x| x.abs())
        .filter(|x| x.is_finite())
        .fold(0f32, f32::max);
    if absmax > 0.0 {
        absmax / 127.0
    } else {
        0.0
    }
}

/// Quantize one element symmetrically; saturating, NaN-free.
fn quantize_i8(x: f32, scale: f32) -> i8 {
    if scale == 0.0 || x.is_nan() {
        return 0;
    }
    // `as` saturates (+inf → 127); the max keeps -inf at the symmetric
    // -127 instead of i8::MIN
    ((x / scale).round() as i8).max(-127)
}

/// f32 → IEEE binary16 bits, round-to-nearest-even. Handles subnormals,
/// overflow to ±inf, and NaN (preserved as a quiet NaN).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN: keep NaN-ness with a quiet mantissa bit
        return sign | 0x7C00 | if frac != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent, rebiased for f16 (bias 15)
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e <= 0 {
        // subnormal (or underflow to zero): shift the implicit-1 mantissa
        if e < -10 {
            return sign; // rounds to ±0
        }
        let mant = frac | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32; // bits dropped below the f16 ulp
        let half = mant >> shift;
        // round to nearest, ties to even
        let rem = mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = match rem.cmp(&halfway) {
            std::cmp::Ordering::Greater => half + 1,
            std::cmp::Ordering::Equal => half + (half & 1),
            std::cmp::Ordering::Less => half,
        };
        return sign | rounded as u16;
    }
    // normal range: 23 → 10 mantissa bits with RNE (carry may bump the
    // exponent, including into infinity — the +1 propagates correctly
    // because the fields are adjacent)
    let base = (sign as u32) << 16 | (e as u32) << 10 | (frac >> 13);
    let rem = frac & 0x1FFF;
    let rounded = match rem.cmp(&0x1000) {
        std::cmp::Ordering::Greater => base + 1,
        std::cmp::Ordering::Equal => base + (base & 1),
        std::cmp::Ordering::Less => base,
    };
    (rounded & 0xFFFF) as u16 | sign
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = (h as u32 & 0x8000) << 16;
    let exp = (h >> 10) & 0x1F;
    let frac = (h & 0x03FF) as u32;
    let bits = match exp {
        0 => {
            if frac == 0 {
                sign // ±0
            } else {
                // subnormal: normalize into f32's much wider exponent
                let mut e = 0i32;
                let mut f = frac;
                while f & 0x0400 == 0 {
                    f <<= 1;
                    e -= 1;
                }
                let exp32 = (127 - 15 + e + 1) as u32;
                sign | (exp32 << 23) | ((f & 0x03FF) << 13)
            }
        }
        0x1F => sign | 0x7F80_0000 | (frac << 13), // inf / NaN
        _ => sign | ((exp as u32 + (127 - 15)) << 23) | (frac << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(codec: Codec, src: &[f32]) -> Vec<f32> {
        let mut wire = Vec::new();
        codec.encode_into(src, &mut wire);
        assert_eq!(wire.len(), codec.wire_words(src.len()), "{codec:?}");
        let mut out = Vec::new();
        codec.decode_into(&wire, &mut out);
        assert_eq!(out.len(), src.len(), "{codec:?}");
        out
    }

    #[test]
    fn f32_roundtrip_is_bit_identical_and_headerless() {
        prop::check(|rng| {
            let n = rng.gen_range(200);
            let src: Vec<f32> = (0..n).map(|_| rng.gen_f32_range(-1e6, 1e6)).collect();
            let mut wire = Vec::new();
            Codec::F32.encode_into(&src, &mut wire);
            assert_eq!(wire.len(), src.len(), "F32 must add zero overhead");
            for (a, b) in wire.iter().zip(src.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let out = roundtrip(Codec::F32, &src);
            for (a, b) in out.iter().zip(src.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn f16_roundtrip_bounded_relative_error() {
        prop::check(|rng| {
            let n = 1 + rng.gen_range(99);
            let src: Vec<f32> = (0..n).map(|_| rng.gen_f32_range(-100.0, 100.0)).collect();
            let out = roundtrip(Codec::F16, &src);
            for (a, b) in out.iter().zip(src.iter()) {
                // RNE in the normal range: error ≤ 2^-11 relative
                let tol = b.abs() * 4.9e-4 + 6.0e-8; // + subnormal ulp
                assert!((a - b).abs() <= tol, "{b} -> {a}");
            }
        });
    }

    #[test]
    fn f16_adversarial_values() {
        // subnormals (f16 subnormal range is ~6e-8 .. 6.1e-5), exact
        // halves, the largest normal, overflow, signed zeros, NaN
        let cases = [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            65504.0,   // f16 max normal — exact
            65505.0,   // rounds back to 65504
            1e30,      // overflow → inf
            -1e30,     // → -inf
            6.1e-5,    // smallest f16 normal neighborhood
            5.96e-8,   // smallest f16 subnormal neighborhood
            1e-8,      // underflows to 0
            -3.1e-5,   // negative subnormal range
            f32::from_bits(1), // smallest f32 subnormal → 0
        ];
        let out = roundtrip(Codec::F16, &cases);
        assert_eq!(out[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(out[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(out[2], 1.0);
        assert_eq!(out[3], -1.0);
        assert_eq!(out[4], 0.5);
        assert_eq!(out[5], 65504.0);
        assert_eq!(out[6], 65504.0, "RNE keeps 65505 at max normal");
        assert_eq!(out[7], f32::INFINITY);
        assert_eq!(out[8], f32::NEG_INFINITY);
        for (i, (&src, &dec)) in cases.iter().zip(out.iter()).enumerate().skip(9) {
            if i == 11 || i == 13 {
                assert_eq!(dec, 0.0, "underflow must flush to zero");
            } else {
                let rel = (dec - src).abs() / src.abs();
                // subnormal range: absolute error one f16-subnormal ulp
                assert!(rel < 0.05 || (dec - src).abs() <= 6e-8, "{src} -> {dec}");
            }
        }
        let nan = roundtrip(Codec::F16, &[f32::NAN]);
        assert!(nan[0].is_nan(), "NaN must survive, not become a number");
    }

    #[test]
    fn int8_roundtrip_bounded_by_group_absmax() {
        prop::check(|rng| {
            let n = 1 + rng.gen_range(300);
            let group = 1 + rng.gen_range(40);
            let codec = Codec::Int8 { group };
            let src: Vec<f32> = (0..n).map(|_| rng.gen_f32_range(-8.0, 8.0)).collect();
            let out = roundtrip(codec, &src);
            for (g, (sg, og)) in src.chunks(group).zip(out.chunks(group)).enumerate() {
                let absmax = sg.iter().fold(0f32, |m, x| m.max(x.abs()));
                let step = absmax / 127.0;
                for (a, b) in og.iter().zip(sg.iter()) {
                    assert!(
                        (a - b).abs() <= step * 0.5 + 1e-7,
                        "group {g}: {b} -> {a} (step {step})"
                    );
                }
            }
        });
    }

    #[test]
    fn int8_adversarial_groups_are_nan_free() {
        // absmax = 0 group, a NaN/inf-contaminated group, and a subnormal
        // group must all decode to finite values (zeros where calibration
        // was impossible)
        let codec = Codec::Int8 { group: 4 };
        let src = [
            0.0f32, 0.0, -0.0, 0.0, // absmax = 0 → scale 0 → exact zeros
            f32::NAN, f32::INFINITY, -1.0, 2.0, // contaminated
            1e-39, -1e-39, 0.0, 1e-40, // subnormal absmax
        ];
        let out = roundtrip(codec, &src);
        assert!(out.iter().all(|x| !x.is_nan()), "decode must be NaN-free");
        assert_eq!(&out[..4], &[0.0; 4]);
        // finite lanes of the contaminated group still quantize against
        // the finite absmax (2.0); inf saturates to ±absmax
        assert_eq!(out[4], 0.0, "NaN lane quantizes to 0");
        assert_eq!(out[5], 2.0, "+inf saturates to +absmax");
        assert!((out[6] + 1.0).abs() <= 2.0 / 127.0 * 0.5 + 1e-7);
        assert!((out[7] - 2.0).abs() <= 1e-6);
        // subnormal group: scale is subnormal but finite; error bounded by
        // half a step of its absmax
        for (a, b) in out[8..].iter().zip(src[8..].iter()) {
            assert!((a - b).abs() <= 1e-39 / 127.0 * 0.5 + 1e-42, "{b} -> {a}");
        }
    }

    #[test]
    fn wire_words_accounting_is_exact() {
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 255, 256, 257, 1000] {
            let src: Vec<f32> = (0..len).map(|i| i as f32 * 0.25 - 3.0).collect();
            for codec in [
                Codec::F32,
                Codec::F16,
                Codec::int8(),
                Codec::Int8 { group: 3 },
            ] {
                let mut wire = Vec::new();
                codec.encode_into(&src, &mut wire);
                assert_eq!(wire.len(), codec.wire_words(len), "{codec:?} len {len}");
                assert_eq!(codec.wire_bytes(len), 4 * codec.wire_words(len) as u64);
            }
            // F16 halves, Int8 quarters (asymptotically)
            if len >= 256 {
                assert!(Codec::F16.wire_bytes(len) < 4 * len as u64 * 6 / 10);
                assert!(Codec::int8().wire_bytes(len) < 4 * len as u64 * 4 / 10);
            }
        }
    }

    #[test]
    fn decode_rejects_foreign_payloads() {
        let mut wire = Vec::new();
        Codec::F16.encode_into(&[1.0, 2.0], &mut wire);
        let mut out = Vec::new();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Codec::int8().decode_into(&wire, &mut out)
        }));
        assert!(err.is_err(), "int8 decode of an f16 payload must panic");
        let raw = [1.0f32, 2.0, 3.0];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Codec::F16.decode_into(&raw, &mut out)
        }));
        assert!(err.is_err(), "decode of an unencoded payload must panic");
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for c in [Codec::F32, Codec::F16, Codec::int8()] {
            assert_eq!(Codec::parse(c.label()), Some(c));
        }
        assert_eq!(Codec::parse("HALF"), Some(Codec::F16));
        assert_eq!(Codec::parse("bogus"), None);
    }

    #[test]
    fn checked_roundtrip_all_codecs() {
        for codec in [
            Codec::F32,
            Codec::F16,
            Codec::int8(),
            Codec::Int8 { group: 3 },
        ] {
            for len in [0usize, 1, 2, 5, 101] {
                let src: Vec<f32> = (0..len).map(|i| (i as f32 - 50.0) * 0.17).collect();
                let mut wire = Vec::new();
                codec.encode_into_checked(&src, &mut wire);
                assert_eq!(wire.len(), codec.checked_wire_words(len), "{codec:?} len {len}");
                assert!(Codec::payload_checked(&wire));
                assert!(Codec::verify_checksum(&wire), "{codec:?} len {len}");
                let mut out = Vec::new();
                codec.decode_checked_into(&wire, &mut out);
                assert_eq!(out.len(), len, "{codec:?}");
                if codec == Codec::F32 {
                    for (a, b) in out.iter().zip(src.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "checked F32 must be lossless");
                    }
                }
            }
        }
    }

    #[test]
    fn checksum_rejects_any_single_bit_flip() {
        let src: Vec<f32> = (0..40).map(|i| i as f32 * 0.5 - 7.0).collect();
        for codec in [Codec::F32, Codec::F16, Codec::int8()] {
            let mut wire = Vec::new();
            codec.encode_into_checked(&src, &mut wire);
            for word in 0..wire.len() {
                for bit in [0u32, 13, 15, 31] {
                    let mut bad = wire.clone();
                    bad[word] = f32::from_bits(bad[word].to_bits() ^ (1 << bit));
                    assert!(
                        !Codec::verify_checksum(&bad),
                        "{codec:?} word {word} bit {bit} undetected"
                    );
                }
            }
            assert!(Codec::verify_checksum(&wire), "unflipped wire stays valid");
        }
    }

    #[test]
    fn checked_flag_does_not_confuse_plain_decode_or_detection() {
        // a checked f16 payload still decodes through the plain
        // count-driven path (flag masked in the header, trailing checksum
        // word ignored)
        let src = [1.0f32, -2.0, 3.5];
        let mut wire = Vec::new();
        Codec::F16.encode_into_checked(&src, &mut wire);
        let mut out = Vec::new();
        Codec::F16.decode_into(&wire, &mut out);
        assert_eq!(out.len(), src.len());
        // unchecked payloads carry no flag and fail verification
        let mut plain = Vec::new();
        Codec::F16.encode_into(&src, &mut plain);
        assert!(!Codec::payload_checked(&plain));
        assert!(!Codec::verify_checksum(&plain));
        // a raw headerless F32 payload is never mistaken for an envelope
        assert!(!Codec::payload_checked(&[1.0, 2.0, 3.0]));
        assert!(!Codec::verify_checksum(&[]));
    }
}
