//! Shared utilities: deterministic RNG, stats, timers, CLI args, mini-prop,
//! and the crate's dependency-free error type.

pub mod args;
pub mod error;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use args::Args;
pub use error::{Context, Error, Result};
pub use rng::Rng;
pub use timer::{PhaseTimer, Stopwatch};
