//! Shared utilities: deterministic RNG, stats, timers, CLI args, mini-prop.

pub mod args;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use args::Args;
pub use rng::Rng;
pub use timer::{PhaseTimer, Stopwatch};
