//! Minimal CLI argument parser (no external deps).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    present: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.insert(k.to_string());
                } else {
                    // lookahead: `--key value` unless next is another flag
                    let key = rest.to_string();
                    out.present.insert(key.clone());
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(key, v);
                        }
                        _ => {
                            out.flags.insert(key, "true".to_string());
                        }
                    }
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.contains(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Comma-separated list of usizes, e.g. `--parts 2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--n", "1024", "--layers=120"]);
        assert_eq!(a.get_usize("n", 0), 1024);
        assert_eq!(a.get_usize("layers", 0), 120);
    }

    #[test]
    fn boolean_flags() {
        // subcommand-first convention: `spdnn train --full --verbose`
        let a = parse(&["train", "--full", "--verbose"]);
        assert!(a.has("full"));
        assert!(a.get_bool("full", false));
        assert!(!a.get_bool("absent", false));
        assert_eq!(a.positionals, vec!["train"]);
        // a flag directly followed by a non-flag consumes it as its value
        let b = parse(&["--verbose", "train"]);
        assert_eq!(b.get_str("verbose", ""), "train");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "7"]);
        assert!(a.get_bool("a", false));
        assert_eq!(a.get_usize("b", 0), 7);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--parts", "2,4,8"]);
        assert_eq!(a.get_usize_list("parts", &[]), vec![2, 4, 8]);
        assert_eq!(a.get_usize_list("missing", &[1]), vec![1]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_str("engine", "native"), "native");
        assert_eq!(a.get_f64("eps", 0.01), 0.01);
    }

    #[test]
    fn negative_number_as_value() {
        // values never start with "--", a single dash is fine
        let a = parse(&["--lr", "-0.5"]);
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}
