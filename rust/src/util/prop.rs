//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `check(cases, |rng| { ... })` runs the closure for `cases` independent
//! seeds; a panic inside the closure is re-raised with the failing seed so
//! the case can be replayed deterministically with `replay(seed, f)`.

use super::rng::Rng;

/// Run `f` for `cases` deterministic seeds derived from `base_seed`.
/// On failure, panics with the failing seed embedded in the message.
pub fn check_seeded(base_seed: u64, cases: usize, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (replay seed {seed}): {msg}");
        }
    }
}

/// Default 64-case run with a fixed base seed.
pub fn check(f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    check_seeded(0xD15EA5E, 64, f);
}

/// Replay a single failing case.
pub fn replay(seed: u64, f: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(|rng| {
            let a = rng.gen_range(100);
            assert!(a < 100);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check_seeded(1, 16, |rng| {
                assert!(rng.gen_range(10) < 100); // always true
                assert!(rng.gen_range(2) == 0 || rng.gen_range(2) == 0 || false || flaky());
            });
        });
        // flaky() always false => some case fails; message carries "replay seed"
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "got: {msg}");
    }

    fn flaky() -> bool {
        false
    }
}
