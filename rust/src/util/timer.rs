//! Phase timers for the breakdown experiments (Fig. 5: SpMV / Updt / Comm).

use std::time::{Duration, Instant};

/// Accumulates wall-clock time into named phases.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: std::collections::BTreeMap<&'static str, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    /// Add an externally measured duration to `phase`.
    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
    }

    /// Add seconds (used by the replay simulator's modeled times).
    pub fn add_secs(&mut self, phase: &'static str, secs: f64) {
        self.add(phase, Duration::from_secs_f64(secs.max(0.0)));
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.acc.get(phase).copied().unwrap_or_default()
    }

    pub fn get_secs(&self, phase: &str) -> f64 {
        self.get(phase).as_secs_f64()
    }

    pub fn total(&self) -> Duration {
        self.acc.values().sum()
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, *v))
    }

    /// Merge another timer into this one (used when reducing per-rank timers).
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in other.acc.iter() {
            *self.acc.entry(k).or_default() += *v;
        }
    }

    /// Keep, per phase, the max of self and other (per-layer critical path).
    pub fn merge_max(&mut self, other: &PhaseTimer) {
        for (k, v) in other.acc.iter() {
            let e = self.acc.entry(k).or_default();
            if *v > *e {
                *e = *v;
            }
        }
    }
}

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut t = PhaseTimer::new();
        t.add_secs("spmv", 1.0);
        t.add_secs("spmv", 0.5);
        t.add_secs("comm", 2.0);
        assert!((t.get_secs("spmv") - 1.5).abs() < 1e-9);
        assert!((t.get_secs("comm") - 2.0).abs() < 1e-9);
        assert!((t.total().as_secs_f64() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_and_merge_max_maxes() {
        let mut a = PhaseTimer::new();
        a.add_secs("x", 1.0);
        let mut b = PhaseTimer::new();
        b.add_secs("x", 2.0);
        b.add_secs("y", 3.0);
        let mut m = a.clone();
        m.merge(&b);
        assert!((m.get_secs("x") - 3.0).abs() < 1e-9);
        assert!((m.get_secs("y") - 3.0).abs() < 1e-9);
        a.merge_max(&b);
        assert!((a.get_secs("x") - 2.0).abs() < 1e-9);
    }

    #[test]
    fn time_closure_runs() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(t.get("work") > Duration::ZERO);
    }

    #[test]
    fn negative_secs_clamped() {
        let mut t = PhaseTimer::new();
        t.add_secs("x", -1.0);
        assert_eq!(t.get_secs("x"), 0.0);
    }
}
