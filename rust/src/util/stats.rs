//! Summary statistics used by the experiment tables (avg/max/imbalance).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Maximum of a slice (0.0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(0.0, f64::max)
}

/// Minimum of a slice (0.0 for empty).
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Load imbalance = max / mean (1.0 means perfectly balanced).
/// This is the "imb" column of the paper's Table 1.
pub fn imbalance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 1.0;
    }
    max(xs) / m
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100), nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Convenience: (mean, max, imbalance) of integer counters.
pub fn summarize_u64(xs: &[u64]) -> (f64, f64, f64) {
    let f: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    (mean(&f), max(&f), imbalance(&f))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_max_basic() {
        let xs = [1.0, 2.0, 3.0, 6.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert_eq!(max(&xs), 6.0);
        assert_eq!(min(&xs), 1.0);
    }

    #[test]
    fn imbalance_balanced_is_one() {
        let xs = [4.0, 4.0, 4.0];
        assert!((imbalance(&xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_skewed() {
        let xs = [1.0, 1.0, 4.0];
        assert!((imbalance(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_median() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_constant_zero() {
        assert_eq!(std_dev(&[2.0, 2.0, 2.0]), 0.0);
        assert!((std_dev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }
}
