//! Minimal string-backed error type — a vendored stand-in for `anyhow`
//! that keeps the crate dependency-free (the build must work offline).
//!
//! Provides the same surface the I/O and runtime modules use: an opaque
//! [`Error`], a defaulted [`Result`] alias, a [`Context`] extension trait
//! for `Result`/`Option`, and the `bail!` / `ensure!` / `format_err!`
//! macros. Any `std::error::Error` converts into [`Error`] via `?`.

use std::fmt;

/// Opaque error carrying a human-readable message (and the context chain
/// folded into it).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` intentionally does NOT implement `std::error::Error`, so this
// blanket conversion does not overlap the reflexive `From<T> for T`
// (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format arguments (`anyhow::anyhow!` stand-in).
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        r?;
        Ok(())
    }

    #[test]
    fn std_errors_convert() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing header").unwrap_err();
        assert!(e.to_string().starts_with("writing header: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "slot 7");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).unwrap_err().to_string().contains("three"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f(x: usize) -> Result<()> {
            ensure!(x == 0);
            Ok(())
        }
        assert!(f(1).unwrap_err().to_string().contains("x == 0"));
    }

    #[test]
    fn debug_matches_display() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
