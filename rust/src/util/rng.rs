//! Small, fast, deterministic PRNGs (no external deps).
//!
//! All experiment code in this crate must be reproducible from a seed, so we
//! carry our own splitmix64 (seeding) + xoshiro256** (bulk) generators.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// approximation (bias negligible for n << 2^64).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct values from 0..n (k <= n), unsorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        if k * 3 >= n {
            let mut p = self.permutation(n);
            p.truncate(k);
            return p;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.gen_range(n) as u32;
            if seen.insert(v) {
                out.push(v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn gen_f32_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.gen_range(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 10usize), (10, 10), (1000, 3), (50, 40)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }
}
