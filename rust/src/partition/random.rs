//! Random row partitioning — the paper's baseline ("SGD" rows of Table 1).
//!
//! "Random partitioning evenly splits weight matrices by assigning rows to
//! processors uniformly at random and provides competitive
//! computation/communication balance" (§6.1): we shuffle the rows of each
//! layer and deal them round-robin, which is exactly an even random split.

use super::DnnPartition;
use crate::sparse::Csr;
use crate::util::Rng;

/// Evenly-split random assignment per layer (and for the input vector).
pub fn random_partition(structure: &[Csr], nparts: usize, seed: u64) -> DnnPartition {
    let mut rng = Rng::new(seed);
    let deal = |n: usize, rng: &mut Rng| -> Vec<u32> {
        let perm = rng.permutation(n);
        let mut parts = vec![0u32; n];
        for (i, &v) in perm.iter().enumerate() {
            parts[v as usize] = (i % nparts) as u32;
        }
        parts
    };
    let input_parts = deal(structure[0].ncols, &mut rng);
    let layer_parts = structure
        .iter()
        .map(|w| deal(w.nrows, &mut rng))
        .collect();
    DnnPartition {
        nparts,
        input_parts,
        layer_parts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    #[test]
    fn even_split_per_layer() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 4).unwrap());
        let p = random_partition(&structure, 8, 1);
        p.validate(&structure).unwrap();
        for parts in &p.layer_parts {
            let mut counts = vec![0usize; 8];
            for &x in parts {
                counts[x as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c == 32), "{counts:?}");
        }
    }

    #[test]
    fn uneven_division_remainder_spread() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 2).unwrap());
        let p = random_partition(&structure, 5, 2); // 64 / 5 = 12..13
        for parts in &p.layer_parts {
            let mut counts = vec![0usize; 5];
            for &x in parts {
                counts[x as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c == 12 || c == 13), "{counts:?}");
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 3).unwrap());
        let a = random_partition(&structure, 4, 1);
        let b = random_partition(&structure, 4, 1);
        let c = random_partition(&structure, 4, 2);
        assert_eq!(a.layer_parts, b.layer_parts);
        assert_ne!(a.layer_parts, c.layer_parts);
    }
}
