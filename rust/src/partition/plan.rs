//! Communication plans — the Xsend/Xrecv (Eq. 8–9) and Ssend/Srecv maps.
//!
//! For layer k, rank m must receive x^{k-1}(j) for every column j of its
//! row block that it does not own; the owner is the rank that computed
//! x^{k-1}(j) in the previous layer. SpBP is the exact mirror: if m
//! receives x^{k-1}(j) from n forward, m sends the partial gradient s^k(j)
//! to n backward (Section 4.2). Plans are precomputed once from structure +
//! partition and are never touched on the hot path (Section 6.4).

use super::DnnPartition;
use crate::comm::{Codec, Phase};
use crate::sparse::Csr;

/// One directed transfer: `indices` of the activation vector x^{k-1}
/// flowing `from → to` during SpFF of layer k (and s^k flowing `to → from`
/// during SpBP of layer k).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Transfer {
    pub from: u32,
    pub to: u32,
    /// Global x^{k-1} indices, ascending.
    pub indices: Vec<u32>,
}

impl Transfer {
    /// The transfer's **chunk schedule**: its index list cut into
    /// sub-transfers of at most `chunk_acts` activation entries each —
    /// the unit the pipelined engine posts the moment a chunk's source
    /// rows finish computing. `chunk_acts == 0` means unchunked (one
    /// chunk covering the whole transfer).
    pub fn chunks(&self, chunk_acts: usize) -> impl Iterator<Item = (u32, &[u32])> {
        let size = if chunk_acts == 0 {
            self.indices.len().max(1)
        } else {
            chunk_acts
        };
        self.indices
            .chunks(size)
            .enumerate()
            .map(|(c, idx)| (c as u32, idx))
    }
}

/// All transfers of one layer, plus per-rank views.
#[derive(Debug, Clone, Default)]
pub struct LayerPlan {
    pub transfers: Vec<Transfer>,
    /// Indices into `transfers` of messages sent by each rank (SpFF).
    pub send_of: Vec<Vec<u32>>,
    /// Indices into `transfers` of messages received by each rank (SpFF).
    pub recv_of: Vec<Vec<u32>>,
    /// Wire codec for this layer's forward activation payloads.
    pub codec_fwd: Codec,
    /// Wire codec for this layer's backward partial-gradient payloads —
    /// carried separately because gradients often need more precision
    /// than activations (quantize forward harder than backward).
    pub codec_bwd: Codec,
}

impl LayerPlan {
    pub fn volume(&self) -> u64 {
        self.transfers.iter().map(|t| t.indices.len() as u64).sum()
    }

    pub fn message_count(&self) -> u64 {
        self.transfers.len() as u64
    }

    /// The wire codec of one communication phase.
    pub fn codec(&self, phase: Phase) -> Codec {
        match phase {
            Phase::Forward => self.codec_fwd,
            Phase::Backward => self.codec_bwd,
        }
    }

    /// Messages this layer ships when every transfer is posted as chunked
    /// sub-transfers of at most `chunk_acts` activation entries (0 =
    /// unchunked). The pipelined engine's expected message count.
    pub fn message_count_chunked(&self, chunk_acts: usize) -> u64 {
        self.transfers
            .iter()
            .map(|t| t.chunks(chunk_acts).count() as u64)
            .sum()
    }

    /// Exact forward bytes-on-wire of this layer for a batch of `b`
    /// columns, under its codec and chunk schedule: each sub-transfer
    /// chunk pays its own header.
    pub fn fwd_wire_bytes(&self, b: usize, chunk_acts: usize) -> u64 {
        self.transfers
            .iter()
            .flat_map(|t| t.chunks(chunk_acts))
            .map(|(_, idx)| self.codec_fwd.wire_bytes(idx.len() * b))
            .sum()
    }

    /// Inbound transfers of `rank` in receive order, as
    /// `(source rank, transfer id, activation indices)` — the segment
    /// recipe consumed by [`crate::sparse::SplitCsr::build`] when the
    /// rank's row block is reordered for the overlapped engine.
    pub fn inbound_of(&self, rank: usize) -> Vec<(u32, u32, &[u32])> {
        self.recv_of[rank]
            .iter()
            .map(|&tid| {
                let t = &self.transfers[tid as usize];
                (t.from, tid, t.indices.as_slice())
            })
            .collect()
    }

    /// Outbound transfers of `rank` in send order, as
    /// `(destination rank, transfer id, activation indices)`.
    pub fn outbound_of(&self, rank: usize) -> Vec<(u32, u32, &[u32])> {
        self.send_of[rank]
            .iter()
            .map(|&tid| {
                let t = &self.transfers[tid as usize];
                (t.to, tid, t.indices.as_slice())
            })
            .collect()
    }

    /// Chunk-granular inbound view: one entry per sub-transfer of every
    /// inbound transfer of `rank`, in receive order, as
    /// `(source rank, transfer id, chunk id, activation indices)` — the
    /// segment recipe the pipelined engine feeds to
    /// [`crate::sparse::SplitCsr::build`] so each partial payload can be
    /// applied the moment it lands.
    pub fn inbound_chunks_of(
        &self,
        rank: usize,
        chunk_acts: usize,
    ) -> Vec<(u32, u32, u32, &[u32])> {
        self.recv_of[rank]
            .iter()
            .flat_map(|&tid| {
                let t = &self.transfers[tid as usize];
                t.chunks(chunk_acts).map(move |(c, idx)| (t.from, tid, c, idx))
            })
            .collect()
    }

    /// Chunk-granular outbound view of `rank`, mirroring
    /// [`LayerPlan::inbound_chunks_of`]: the **row ranges** the sender
    /// posts as each finishes, as
    /// `(destination rank, transfer id, chunk id, activation indices)`.
    pub fn outbound_chunks_of(
        &self,
        rank: usize,
        chunk_acts: usize,
    ) -> Vec<(u32, u32, u32, &[u32])> {
        self.send_of[rank]
            .iter()
            .flat_map(|&tid| {
                let t = &self.transfers[tid as usize];
                t.chunks(chunk_acts).map(move |(c, idx)| (t.to, tid, c, idx))
            })
            .collect()
    }
}

/// The full per-layer communication plan of one (structure, partition) pair.
#[derive(Debug, Clone)]
pub struct CommPlan {
    pub nparts: usize,
    pub layers: Vec<LayerPlan>,
}

impl CommPlan {
    /// Build the plan from the sparsity structure and a partition.
    pub fn build(structure: &[Csr], part: &DnnPartition) -> CommPlan {
        let nparts = part.nparts;
        let mut layers = Vec::with_capacity(structure.len());
        // reusable scratch: consumer parts per column
        for (k, w) in structure.iter().enumerate() {
            // consumers[j] = sorted distinct ranks needing x^{k-1}(j)
            let mut consumers: Vec<Vec<u32>> = vec![Vec::new(); w.ncols];
            for r in 0..w.nrows {
                let p = part.layer_parts[k][r];
                for &c in w.row(r).0 {
                    let list = &mut consumers[c as usize];
                    if !list.contains(&p) {
                        list.push(p);
                    }
                }
            }
            // aggregate (owner → consumer) index lists
            use std::collections::BTreeMap;
            let mut pairs: BTreeMap<(u32, u32), Vec<u32>> = BTreeMap::new();
            for j in 0..w.ncols {
                if consumers[j].is_empty() {
                    continue;
                }
                let owner = part.owner_of_activation(k, j);
                for &q in &consumers[j] {
                    if q != owner {
                        pairs.entry((owner, q)).or_default().push(j as u32);
                    }
                }
            }
            let mut plan = LayerPlan {
                transfers: Vec::with_capacity(pairs.len()),
                send_of: vec![Vec::new(); nparts],
                recv_of: vec![Vec::new(); nparts],
                codec_fwd: Codec::F32,
                codec_bwd: Codec::F32,
            };
            for ((from, to), indices) in pairs {
                let id = plan.transfers.len() as u32;
                plan.send_of[from as usize].push(id);
                plan.recv_of[to as usize].push(id);
                plan.transfers.push(Transfer { from, to, indices });
            }
            layers.push(plan);
        }
        CommPlan { nparts, layers }
    }

    /// Build the plan and set one wire codec pair on every layer.
    pub fn build_with_codec(
        structure: &[Csr],
        part: &DnnPartition,
        fwd: Codec,
        bwd: Codec,
    ) -> CommPlan {
        let mut plan = Self::build(structure, part);
        plan.set_codec(fwd, bwd);
        plan
    }

    /// Set the forward/backward wire codecs on every layer. Layers can
    /// also be tuned individually through `layers[k].codec_*`.
    pub fn set_codec(&mut self, fwd: Codec, bwd: Codec) {
        for l in &mut self.layers {
            l.codec_fwd = fwd;
            l.codec_bwd = bwd;
        }
    }

    /// Total one-way (SpFF) volume in words for one input vector.
    pub fn fwd_volume(&self) -> u64 {
        self.layers.iter().map(|l| l.volume()).sum()
    }

    /// Exact forward bytes-on-wire for one batch of `b` columns under the
    /// per-layer codecs and the chunk schedule (`chunk_acts` = 0 for the
    /// whole-transfer engines) — the number the live
    /// [`crate::comm::Endpoint::sent_wire_bytes`] counters reproduce.
    pub fn fwd_wire_bytes(&self, b: usize, chunk_acts: usize) -> u64 {
        self.layers.iter().map(|l| l.fwd_wire_bytes(b, chunk_acts)).sum()
    }

    /// Total one-way (SpFF) message count for one input vector.
    pub fn fwd_messages(&self) -> u64 {
        self.layers.iter().map(|l| l.message_count()).sum()
    }

    /// Per-rank words sent during SpFF (per input). SpBP send volume is the
    /// mirror: rank m's backward sends equal its forward receives.
    pub fn fwd_send_volume_per_rank(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.from as usize] += t.indices.len() as u64;
            }
        }
        v
    }

    /// Per-rank words received during SpFF (== SpBP sends per rank).
    pub fn fwd_recv_volume_per_rank(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.to as usize] += t.indices.len() as u64;
            }
        }
        v
    }

    /// Per-rank message counts sent during SpFF.
    pub fn fwd_send_msgs_per_rank(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.from as usize] += 1;
            }
        }
        v
    }

    /// Per-rank message counts received during SpFF (== SpBP sends).
    pub fn fwd_recv_msgs_per_rank(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.to as usize] += 1;
            }
        }
        v
    }

    /// Per-rank SpFF message counts **under the chunked sub-transfer
    /// schedule**: every transfer ships `ceil(len / chunk_acts)` messages
    /// (1 when `chunk_acts` = 0). The pipelined engine's live counters
    /// cross-check against these instead of the whole-transfer counts.
    pub fn fwd_send_msgs_per_rank_chunked(&self, chunk_acts: usize) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.from as usize] += t.chunks(chunk_acts).count() as u64;
            }
        }
        v
    }

    /// Chunked mirror of [`CommPlan::fwd_recv_msgs_per_rank`] (== the
    /// pipelined engine's per-rank SpBP send counts).
    pub fn fwd_recv_msgs_per_rank_chunked(&self, chunk_acts: usize) -> Vec<u64> {
        let mut v = vec![0u64; self.nparts];
        for l in &self.layers {
            for t in &l.transfers {
                v[t.to as usize] += t.chunks(chunk_acts).count() as u64;
            }
        }
        v
    }

    /// Total SpFF+SpBP volume (the paper's Vol = Σ 2·(λ−1)).
    pub fn total_volume(&self) -> u64 {
        2 * self.fwd_volume()
    }
}

/// A partition bundled with its communication plan — everything the serving
/// path reuses across a stream of requests. Plans depend only on the model
/// structure (never on inputs), so one `ServingPlan` built at startup is
/// valid for the lifetime of the weights; both the one-shot
/// [`crate::coordinator::sgd::infer_with_plan`] path and the persistent
/// [`crate::serving::RankPool`] consume it.
#[derive(Debug, Clone)]
pub struct ServingPlan {
    pub part: DnnPartition,
    pub plan: CommPlan,
}

impl ServingPlan {
    /// Contiguous nnz-balanced row blocks + plan (the default serving
    /// partition: zero partitioning latency at pool startup).
    pub fn contiguous(structure: &[Csr], nranks: usize) -> Self {
        Self::from_partition(structure, crate::partition::contiguous_partition(structure, nranks))
    }

    /// Bundle a caller-chosen partition (e.g. hypergraph) with its plan.
    /// Panics if the partition is invalid for `structure`.
    pub fn from_partition(structure: &[Csr], part: DnnPartition) -> Self {
        part.validate(structure).expect("invalid partition");
        let plan = CommPlan::build(structure, &part);
        Self { part, plan }
    }

    pub fn nranks(&self) -> usize {
        self.part.nparts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate_structure, RadixNetConfig};
    use crate::sparse::Coo;
    use crate::util::prop;

    fn two_rank_example() -> (Vec<Csr>, DnnPartition) {
        // W^1: 4x4; rows 0,1 → rank 0, rows 2,3 → rank 1.
        // row r reads columns {r, (r+1)%4}
        let mut coo = Coo::new(4, 4);
        for r in 0..4 {
            coo.push(r, r, 1.0);
            coo.push(r, (r + 1) % 4, 1.0);
        }
        let w = coo.to_csr();
        let part = DnnPartition {
            nparts: 2,
            input_parts: vec![0, 0, 1, 1],
            layer_parts: vec![vec![0, 0, 1, 1]],
        };
        (vec![w], part)
    }

    #[test]
    fn plan_matches_hand_computation() {
        let (structure, part) = two_rank_example();
        let plan = CommPlan::build(&structure, &part);
        // consumers: col0→{0,3? no}: rows reading col0 = row0 (r=0) and row3 ((3+1)%4=0)
        //   col0: rows {0,3} → ranks {0,1}; owner(col0)=0 ⇒ 0→1 send idx 0
        //   col1: rows {0,1} → rank {0}; owner 0 ⇒ none
        //   col2: rows {1,2} → ranks {0,1}; owner 1 ⇒ 1→0 send idx 2
        //   col3: rows {2,3} → rank {1}; owner 1 ⇒ none
        let l = &plan.layers[0];
        assert_eq!(l.transfers.len(), 2);
        let t01 = l.transfers.iter().find(|t| t.from == 0).unwrap();
        assert_eq!(t01.to, 1);
        assert_eq!(t01.indices, vec![0]);
        let t10 = l.transfers.iter().find(|t| t.from == 1).unwrap();
        assert_eq!(t10.to, 0);
        assert_eq!(t10.indices, vec![2]);
        assert_eq!(plan.fwd_volume(), 2);
        assert_eq!(plan.total_volume(), 4);
    }

    #[test]
    fn volume_equals_cutsize_of_phase_hypergraphs() {
        // The paper's central modeling claim: Σ_k cutsize(H(φ^k)) with
        // cost 2 == total SpFF+SpBP communication volume.
        prop::check(|rng| {
            let n = 16 + rng.gen_range(48);
            let layers = 2 + rng.gen_range(4);
            let mut structure = Vec::new();
            for _ in 0..layers {
                let mut coo = Coo::new(n, n);
                for r in 0..n {
                    let deg = 1 + rng.gen_range(4);
                    for c in rng.sample_distinct(n, deg) {
                        coo.push(r, c as usize, 1.0);
                    }
                }
                structure.push(coo.to_csr());
            }
            let nparts = 2 + rng.gen_range(5);
            let part = random_partition(&structure, nparts, rng.next_u64());
            let plan = CommPlan::build(&structure, &part);

            // cutsize: build phase hypergraphs with fixed vertices from the
            // actual previous assignment (input_parts for k=0) and fix ALL
            // vertices to their partition - cutsize must equal volume.
            let mut total_cut = 0u64;
            for (k, w) in structure.iter().enumerate() {
                let prev: Vec<u32> = (0..w.ncols)
                    .map(|j| part.owner_of_activation(k, j))
                    .collect();
                let hg = crate::partition::phases::build_phase_hypergraph(w, Some(&prev));
                let mut parts_vec = vec![0u32; hg.nv];
                for r in 0..w.nrows {
                    parts_vec[r] = part.layer_parts[k][r];
                }
                for j in 0..w.ncols {
                    parts_vec[w.nrows + j] = prev[j];
                }
                total_cut += hg.cutsize(&parts_vec, nparts);
            }
            assert_eq!(
                total_cut,
                plan.total_volume(),
                "cutsize != comm volume (n={n}, P={nparts})"
            );
        });
    }

    #[test]
    fn per_rank_sums_match_totals() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 5).unwrap());
        let part = random_partition(&structure, 8, 11);
        let plan = CommPlan::build(&structure, &part);
        assert_eq!(
            plan.fwd_send_volume_per_rank().iter().sum::<u64>(),
            plan.fwd_volume()
        );
        assert_eq!(
            plan.fwd_recv_volume_per_rank().iter().sum::<u64>(),
            plan.fwd_volume()
        );
        assert_eq!(
            plan.fwd_send_msgs_per_rank().iter().sum::<u64>(),
            plan.fwd_messages()
        );
    }

    #[test]
    fn transfers_have_sorted_indices_and_no_self_sends() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 6).unwrap());
        let part = random_partition(&structure, 4, 5);
        let plan = CommPlan::build(&structure, &part);
        for l in &plan.layers {
            for t in &l.transfers {
                assert_ne!(t.from, t.to);
                assert!(t.indices.windows(2).all(|w| w[0] < w[1]));
                assert!(!t.indices.is_empty());
            }
        }
    }

    #[test]
    fn inbound_outbound_views_mirror_transfer_lists() {
        let (structure, part) = two_rank_example();
        let plan = CommPlan::build(&structure, &part);
        let l = &plan.layers[0];
        let in1 = l.inbound_of(1);
        assert_eq!(in1.len(), 1);
        assert_eq!(in1[0].0, 0, "rank 1 receives from rank 0");
        assert_eq!(in1[0].2, &[0][..]);
        let out0 = l.outbound_of(0);
        assert_eq!(out0.len(), 1);
        assert_eq!(out0[0].0, 1, "rank 0 sends to rank 1");
        assert_eq!(out0[0].1, in1[0].1, "same transfer id on both views");
        assert!(l.inbound_of(0).len() == 1 && l.outbound_of(1).len() == 1);
    }

    #[test]
    fn chunked_views_partition_each_transfer_exactly() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(128, 5).unwrap());
        let part = random_partition(&structure, 4, 9);
        let plan = CommPlan::build(&structure, &part);
        for chunk_acts in [0usize, 1, 3, 7, 1024] {
            for l in &plan.layers {
                for rank in 0..4usize {
                    let whole = l.inbound_of(rank);
                    let chunked = l.inbound_chunks_of(rank, chunk_acts);
                    // reassembling the chunks of each tid gives the transfer
                    for &(src, tid, idx) in &whole {
                        let glued: Vec<u32> = chunked
                            .iter()
                            .filter(|&&(s, t, _, _)| s == src && t == tid)
                            .flat_map(|&(_, _, _, i)| i.iter().copied())
                            .collect();
                        assert_eq!(glued.as_slice(), idx, "tid {tid} chunk_acts {chunk_acts}");
                    }
                    // chunk ids are dense from 0 and sized to chunk_acts
                    for &(_, tid, c, idx) in &chunked {
                        assert!(!idx.is_empty());
                        if chunk_acts > 0 {
                            assert!(idx.len() <= chunk_acts);
                            let t = &l.transfers[tid as usize];
                            let nchunks = t.indices.len().div_ceil(chunk_acts);
                            assert!((c as usize) < nchunks);
                        } else {
                            assert_eq!(c, 0);
                        }
                    }
                    // outbound view mirrors inbound on the sending side
                    let out = l.outbound_chunks_of(rank, chunk_acts);
                    for &(_, tid, c, idx) in &out {
                        let t = &l.transfers[tid as usize];
                        assert_eq!(t.from as usize, rank);
                        let found = t
                            .chunks(chunk_acts)
                            .find(|&(cc, _)| cc == c)
                            .expect("chunk exists");
                        assert_eq!(found.1, idx);
                    }
                }
            }
        }
    }

    #[test]
    fn single_rank_has_no_communication() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 4).unwrap());
        let part = random_partition(&structure, 1, 1);
        let plan = CommPlan::build(&structure, &part);
        assert_eq!(plan.fwd_volume(), 0);
        assert_eq!(plan.fwd_messages(), 0);
    }
}
