//! DNN partitioning (Section 5): who owns which neuron in which layer.
//!
//! A [`DnnPartition`] assigns every row of every weight matrix (= every
//! neuron of layers 1..L) and every input-vector entry (layer 0) to a rank.
//! Two constructions are provided:
//! - [`random::random_partition`] — the paper's baseline "SGD": rows dealt
//!   to ranks uniformly at random, evenly split per layer;
//! - [`phases::hypergraph_partition`] — the paper's contribution "H-SGD":
//!   the multi-phase hypergraph model with fixed vertices.

pub mod metrics;
pub mod phases;
pub mod plan;
pub mod random;

pub use metrics::PartitionMetrics;
pub use plan::{CommPlan, ServingPlan};

use crate::sparse::Csr;

/// Row→rank assignment for every layer of a sparse DNN.
#[derive(Debug, Clone)]
pub struct DnnPartition {
    pub nparts: usize,
    /// Rank owning each entry of the input vector x^0.
    pub input_parts: Vec<u32>,
    /// `layer_parts[k][r]` = rank owning row r of weight matrix k (i.e.
    /// neuron r of layer k+1).
    pub layer_parts: Vec<Vec<u32>>,
}

impl DnnPartition {
    /// Owner of x^k(j): layer 0 = input assignment, else the row owner of
    /// layer k-1 (the rank that computed the activation).
    pub fn owner_of_activation(&self, k: usize, j: usize) -> u32 {
        if k == 0 {
            self.input_parts[j]
        } else {
            self.layer_parts[k - 1][j]
        }
    }

    /// Rows owned by `rank` in weight layer `k`, in ascending order.
    pub fn rows_of(&self, k: usize, rank: u32) -> Vec<u32> {
        self.layer_parts[k]
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == rank)
            .map(|(r, _)| r as u32)
            .collect()
    }

    /// Validate against a network structure: lengths match, ranks in range.
    pub fn validate(&self, structure: &[Csr]) -> Result<(), String> {
        if self.layer_parts.len() != structure.len() {
            return Err("layer count mismatch".into());
        }
        if self.input_parts.len() != structure[0].ncols {
            return Err("input length mismatch".into());
        }
        for (k, (parts, w)) in self.layer_parts.iter().zip(structure.iter()).enumerate() {
            if parts.len() != w.nrows {
                return Err(format!("layer {k} row count mismatch"));
            }
            if parts.iter().any(|&p| p as usize >= self.nparts) {
                return Err(format!("layer {k} rank out of range"));
            }
        }
        if self
            .input_parts
            .iter()
            .any(|&p| p as usize >= self.nparts)
        {
            return Err("input rank out of range".into());
        }
        Ok(())
    }

    /// Computational load per rank: total nnz of owned rows over all layers
    /// (the paper's vertex weight, Section 5).
    pub fn comp_loads(&self, structure: &[Csr]) -> Vec<u64> {
        let mut loads = vec![0u64; self.nparts];
        for (k, w) in structure.iter().enumerate() {
            for r in 0..w.nrows {
                loads[self.layer_parts[k][r] as usize] += w.row_nnz(r) as u64;
            }
        }
        loads
    }
}

/// Contiguous nnz-balanced row blocks per layer — the shared-memory
/// serving default. No cut minimization: on one node every "message" is a
/// memcpy, so locality and balance are what matter, and contiguous blocks
/// keep each rank's rows adjacent in memory for the tiled SpMM.
pub fn contiguous_partition(structure: &[Csr], nparts: usize) -> DnnPartition {
    assert!(nparts > 0);
    fn balance(weights: &[u64], nparts: usize) -> Vec<u32> {
        let total: u64 = weights.iter().sum();
        let n = weights.len();
        let mut parts = vec![0u32; n];
        let mut acc = 0u64;
        for (i, &w) in weights.iter().enumerate() {
            // place each item by the midpoint of its cumulative weight span
            let p = if total == 0 {
                (i * nparts / n.max(1)) as u32
            } else {
                (((acc + w / 2) as u128 * nparts as u128) / total as u128) as u32
            };
            parts[i] = p.min(nparts as u32 - 1);
            acc += w;
        }
        parts
    }
    let input_weights = vec![1u64; structure[0].ncols];
    let input_parts = balance(&input_weights, nparts);
    let layer_parts = structure
        .iter()
        .map(|w| {
            let weights: Vec<u64> = (0..w.nrows).map(|r| w.row_nnz(r) as u64 + 1).collect();
            balance(&weights, nparts)
        })
        .collect();
    DnnPartition {
        nparts,
        input_parts,
        layer_parts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    #[test]
    fn contiguous_partition_is_valid_contiguous_and_balanced() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 4).unwrap());
        for &p in &[1usize, 3, 4, 8] {
            let part = contiguous_partition(&structure, p);
            part.validate(&structure).unwrap();
            // contiguity: rank ids are non-decreasing over rows
            for parts in std::iter::once(&part.input_parts).chain(part.layer_parts.iter()) {
                for w in parts.windows(2) {
                    assert!(w[0] <= w[1], "non-contiguous block (P={p})");
                }
            }
            // balance: within 2x of the mean nnz load (structure is uniform)
            let loads = part.comp_loads(&structure);
            let avg = loads.iter().sum::<u64>() as f64 / p as f64;
            for &l in &loads {
                assert!((l as f64) < avg * 2.0 + 1.0, "P={p}: loads {loads:?}");
            }
        }
    }

    #[test]
    fn contiguous_partition_covers_all_ranks_when_possible() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 2).unwrap());
        let part = contiguous_partition(&structure, 4);
        for parts in &part.layer_parts {
            let mut seen = vec![false; 4];
            for &x in parts {
                seen[x as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "some rank owns no rows");
        }
    }

    #[test]
    fn owner_of_activation_chains_layers() {
        let p = DnnPartition {
            nparts: 2,
            input_parts: vec![0, 1],
            layer_parts: vec![vec![1, 0], vec![0, 1]],
        };
        assert_eq!(p.owner_of_activation(0, 0), 0);
        assert_eq!(p.owner_of_activation(0, 1), 1);
        assert_eq!(p.owner_of_activation(1, 0), 1); // row 0 of layer 0
        assert_eq!(p.owner_of_activation(2, 1), 1); // row 1 of layer 1
    }

    #[test]
    fn rows_of_filters_by_rank() {
        let p = DnnPartition {
            nparts: 2,
            input_parts: vec![0, 0],
            layer_parts: vec![vec![1, 0, 1, 0]],
        };
        assert_eq!(p.rows_of(0, 1), vec![0, 2]);
        assert_eq!(p.rows_of(0, 0), vec![1, 3]);
    }

    #[test]
    fn validate_catches_mismatch() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 2).unwrap());
        let p = DnnPartition {
            nparts: 2,
            input_parts: vec![0; 64],
            layer_parts: vec![vec![0; 64]], // only 1 layer, structure has 2
        };
        assert!(p.validate(&structure).is_err());
    }

    #[test]
    fn comp_loads_sum_to_total_nnz() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 3).unwrap());
        let p = super::random::random_partition(&structure, 4, 7);
        let loads = p.comp_loads(&structure);
        let total: u64 = structure.iter().map(|w| w.nnz() as u64).sum();
        assert_eq!(loads.iter().sum::<u64>(), total);
    }
}
