//! Partition quality metrics — the quantities of the paper's Table 1.
//!
//! Volume = words a rank sends during one full SGD iteration (SpFF + SpBP
//! over all L layers; SpBP mirrors SpFF, so a rank's backward sends equal
//! its forward receives). Messages = point-to-point messages a rank sends
//! per iteration. Imbalance = max/avg computational load (nnz of owned
//! rows).

use super::plan::CommPlan;
use super::DnnPartition;
use crate::sparse::Csr;
use crate::util::stats;

/// Aggregated Table-1 metrics of one partition.
#[derive(Debug, Clone)]
pub struct PartitionMetrics {
    pub nparts: usize,
    pub layers: usize,
    /// Words sent per rank per iteration (SpFF sends + SpBP sends).
    pub send_volume_per_rank: Vec<u64>,
    /// Messages sent per rank per iteration (SpFF + SpBP).
    pub send_msgs_per_rank: Vec<u64>,
    /// Computational load per rank (total nnz owned).
    pub comp_load_per_rank: Vec<u64>,
}

impl PartitionMetrics {
    pub fn compute(structure: &[Csr], part: &DnnPartition) -> Self {
        let plan = CommPlan::build(structure, part);
        Self::from_plan(structure, part, &plan)
    }

    /// Compute from a pre-built plan (avoids rebuilding when both are
    /// needed).
    pub fn from_plan(structure: &[Csr], part: &DnnPartition, plan: &CommPlan) -> Self {
        let fwd_send = plan.fwd_send_volume_per_rank();
        let fwd_recv = plan.fwd_recv_volume_per_rank();
        let fwd_smsg = plan.fwd_send_msgs_per_rank();
        let fwd_rmsg = plan.fwd_recv_msgs_per_rank();
        // SpBP mirror: backward sends of rank m == forward receives of m.
        let send_volume_per_rank: Vec<u64> = fwd_send
            .iter()
            .zip(fwd_recv.iter())
            .map(|(s, r)| s + r)
            .collect();
        let send_msgs_per_rank: Vec<u64> = fwd_smsg
            .iter()
            .zip(fwd_rmsg.iter())
            .map(|(s, r)| s + r)
            .collect();
        Self {
            nparts: part.nparts,
            layers: structure.len(),
            send_volume_per_rank,
            send_msgs_per_rank,
            comp_load_per_rank: part.comp_loads(structure),
        }
    }

    /// Total volume over all ranks (== paper's Σ_k Vol(k)).
    pub fn total_volume(&self) -> u64 {
        self.send_volume_per_rank.iter().sum()
    }

    pub fn avg_volume(&self) -> f64 {
        stats::summarize_u64(&self.send_volume_per_rank).0
    }

    pub fn max_volume(&self) -> f64 {
        stats::summarize_u64(&self.send_volume_per_rank).1
    }

    pub fn avg_msgs(&self) -> f64 {
        stats::summarize_u64(&self.send_msgs_per_rank).0
    }

    pub fn max_msgs(&self) -> f64 {
        stats::summarize_u64(&self.send_msgs_per_rank).1
    }

    /// Computational imbalance: max load / avg load (Table 1 "imb").
    pub fn comp_imbalance(&self) -> f64 {
        stats::summarize_u64(&self.comp_load_per_rank).2
    }

    /// Messages per rank per layer (both phases), a latency-per-barrier
    /// view used in EXPERIMENTS.md discussion.
    pub fn avg_msgs_per_layer(&self) -> f64 {
        self.avg_msgs() / (2.0 * self.layers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::phases::{hypergraph_partition, PhaseConfig};
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    #[test]
    fn totals_consistent_with_plan() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 6).unwrap());
        let part = random_partition(&structure, 8, 1);
        let plan = CommPlan::build(&structure, &part);
        let m = PartitionMetrics::from_plan(&structure, &part, &plan);
        assert_eq!(m.total_volume(), plan.total_volume());
        assert_eq!(
            m.send_msgs_per_rank.iter().sum::<u64>(),
            2 * plan.fwd_messages()
        );
    }

    #[test]
    fn hypergraph_beats_random_on_all_metrics() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap());
        let h = PartitionMetrics::compute(
            &structure,
            &hypergraph_partition(&structure, &PhaseConfig::new(4)),
        );
        let r = PartitionMetrics::compute(&structure, &random_partition(&structure, 4, 2));
        assert!(h.avg_volume() < r.avg_volume());
        assert!(h.max_volume() <= r.max_volume());
        // computational balance comparable or better
        assert!(h.comp_imbalance() < r.comp_imbalance() * 1.3 + 0.05);
    }

    #[test]
    fn imbalance_at_least_one() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 3).unwrap());
        let m = PartitionMetrics::compute(&structure, &random_partition(&structure, 4, 9));
        assert!(m.comp_imbalance() >= 1.0);
    }
}
