//! The paper's multi-phase hypergraph partitioning model (Section 5).
//!
//! Phase φ^k partitions the rows of W^k. The hypergraph H(φ^k) has:
//! - a vertex per row (weight = row nnz — the neuron's computational load);
//! - a net per column j (cost 2: one word in SpFF + one in SpBP, Eq. Vol(k));
//! - for k > 1, a zero-weight *fixed vertex* per column j, pinned to the
//!   part that received row j in phase φ^{k-1} — the producer of x^{k-1}(j).
//!
//! Phase φ^1 has no fixed vertices (x^0 is the input vector); after
//! partitioning, each input entry is assigned to the part owning the most
//! consumers of that entry (any part in Λ(n_j) is volume-optimal, the
//! majority pick also balances input storage).

use super::DnnPartition;
use crate::hypergraph::{partition, Hypergraph, PartitionConfig};
use crate::sparse::Csr;

/// Configuration for the multi-phase model.
#[derive(Debug, Clone)]
pub struct PhaseConfig {
    pub nparts: usize,
    /// Imbalance ε per phase (paper: 0.01).
    pub epsilon: f64,
    pub seed: u64,
}

impl PhaseConfig {
    pub fn new(nparts: usize) -> Self {
        Self {
            nparts,
            epsilon: 0.01,
            seed: 0xA11CE,
        }
    }
}

/// Build the phase hypergraph for one layer.
///
/// Vertex ids: `0..nrows` are row vertices; when `prev` is given, vertex
/// `nrows + j` is the fixed vertex of column j (only materialized for
/// columns with at least one nonzero).
pub fn build_phase_hypergraph(w: &Csr, prev: Option<&[u32]>) -> Hypergraph {
    let nrows = w.nrows;
    let ncols = w.ncols;
    // column -> pin rows (build via transpose walk)
    let mut col_pins: Vec<Vec<u32>> = vec![Vec::new(); ncols];
    for r in 0..nrows {
        let (cols, _) = w.row(r);
        for &c in cols {
            col_pins[c as usize].push(r as u32);
        }
    }
    let has_fixed = prev.is_some();
    let nv = nrows + if has_fixed { ncols } else { 0 };
    let mut vwgt = vec![0u32; nv];
    for r in 0..nrows {
        vwgt[r] = w.row_nnz(r).max(1) as u32;
    }
    // fixed vertices keep weight 0: they carry no computation (Section 5)
    let mut nets: Vec<Vec<u32>> = Vec::with_capacity(ncols);
    let mut ncost: Vec<u32> = Vec::with_capacity(ncols);
    for j in 0..ncols {
        if col_pins[j].is_empty() {
            continue; // column never read: no communication, no net
        }
        let mut pins = col_pins[j].clone();
        if has_fixed {
            pins.push((nrows + j) as u32);
        }
        nets.push(pins);
        ncost.push(2); // one word forward + one word backward (Vol(k))
    }
    let mut hg = Hypergraph::new(nv, nets, vwgt, ncost);
    if let Some(prev_parts) = prev {
        for j in 0..ncols {
            if !col_pins[j].is_empty() {
                hg.fix(nrows + j, prev_parts[j]);
            }
        }
    }
    hg
}

/// Run all L phases and assemble the partition ("H-SGD").
pub fn hypergraph_partition(structure: &[Csr], cfg: &PhaseConfig) -> DnnPartition {
    assert!(!structure.is_empty());
    let mut layer_parts: Vec<Vec<u32>> = Vec::with_capacity(structure.len());
    let mut prev: Option<Vec<u32>> = None;

    let profile = std::env::var("SPDNN_PROFILE").is_ok();
    let mut t_build = 0f64;
    let mut t_part = 0f64;
    for (k, w) in structure.iter().enumerate() {
        let sw = crate::util::Stopwatch::start();
        let hg = build_phase_hypergraph(w, prev.as_deref());
        t_build += sw.elapsed_secs();
        let mut pcfg = PartitionConfig::new(cfg.nparts);
        pcfg.epsilon = cfg.epsilon;
        pcfg.seed = cfg.seed.wrapping_add(k as u64).wrapping_mul(0x9E3779B9);
        let sw = crate::util::Stopwatch::start();
        let parts = partition(&hg, &pcfg);
        t_part += sw.elapsed_secs();
        let rows: Vec<u32> = parts[..w.nrows].to_vec();
        prev = Some(rows.clone());
        layer_parts.push(rows);
    }
    if profile {
        let (tc, tr, te) = crate::hypergraph::partitioner::profile_snapshot();
        crate::log!(
            Info,
            "[profile] phase-hg build {t_build:.3}s, partition {t_part:.3}s              (coarsen {tc:.3}s, uncoarsen-refine {tr:.3}s, extract {te:.3}s)"
        );
    }

    // Assign input entries to the majority consumer part of their column.
    let w0 = &structure[0];
    let rows0 = &layer_parts[0];
    let mut input_parts = vec![0u32; w0.ncols];
    let mut counts = vec![0u32; cfg.nparts];
    let mut col_rows: Vec<Vec<u32>> = vec![Vec::new(); w0.ncols];
    for r in 0..w0.nrows {
        for &c in w0.row(r).0 {
            col_rows[c as usize].push(r as u32);
        }
    }
    for j in 0..w0.ncols {
        if col_rows[j].is_empty() {
            input_parts[j] = (j % cfg.nparts) as u32; // unread entry: spread
            continue;
        }
        counts.iter_mut().for_each(|c| *c = 0);
        for &r in &col_rows[j] {
            counts[rows0[r as usize] as usize] += 1;
        }
        input_parts[j] = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(p, _)| p as u32)
            .unwrap();
    }

    DnnPartition {
        nparts: cfg.nparts,
        input_parts,
        layer_parts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{generate_structure, RadixNetConfig};
    use crate::sparse::Coo;

    #[test]
    fn phase_hypergraph_shapes() {
        // 3x3 matrix, col 1 empty
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(2, 2, 1.0);
        let w = coo.to_csr();
        let hg = build_phase_hypergraph(&w, None);
        assert_eq!(hg.nv, 3); // no fixed vertices in phase 1
        assert_eq!(hg.num_nets(), 2); // col 1 has no pins → no net
        assert_eq!(hg.vwgt, vec![1, 1, 1]);
        assert!(hg.ncost.iter().all(|&c| c == 2));

        let prev = vec![1u32, 0, 1];
        let hg2 = build_phase_hypergraph(&w, Some(&prev));
        assert_eq!(hg2.nv, 6); // 3 rows + 3 (potential) fixed slots
        assert_eq!(hg2.fixed[3], 1); // col 0 producer = part 1
        assert_eq!(hg2.fixed[4], crate::hypergraph::FREE); // empty col: free
        assert_eq!(hg2.fixed[5], 1);
        // fixed vertices carry no weight
        assert_eq!(hg2.vwgt[3], 0);
    }

    #[test]
    fn net_pins_are_column_consumers_plus_fixed() {
        let mut coo = Coo::new(4, 2);
        coo.push(0, 0, 1.0);
        coo.push(2, 0, 1.0);
        coo.push(3, 1, 1.0);
        let w = coo.to_csr();
        let prev = vec![0u32, 1];
        let hg = build_phase_hypergraph(&w, Some(&prev));
        // net 0 = column 0: pins {0, 2, fixed 4}
        let mut p0 = hg.net_pins(0).to_vec();
        p0.sort_unstable();
        assert_eq!(p0, vec![0, 2, 4]);
        let mut p1 = hg.net_pins(1).to_vec();
        p1.sort_unstable();
        assert_eq!(p1, vec![3, 5]);
    }

    #[test]
    fn partition_valid_on_radixnet() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 6).unwrap());
        let cfg = PhaseConfig::new(4);
        let p = hypergraph_partition(&structure, &cfg);
        p.validate(&structure).unwrap();
        // balance: comp loads within a reasonable factor
        let loads = p.comp_loads(&structure);
        let avg = loads.iter().sum::<u64>() as f64 / 4.0;
        let maxl = *loads.iter().max().unwrap() as f64;
        assert!(maxl <= avg * 1.25, "loads {loads:?}");
    }

    #[test]
    fn beats_random_volume_on_radixnet() {
        use crate::partition::metrics::PartitionMetrics;
        use crate::partition::random::random_partition;
        let structure = generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap());
        let h = hypergraph_partition(&structure, &PhaseConfig::new(8));
        let r = random_partition(&structure, 8, 3);
        let mh = PartitionMetrics::compute(&structure, &h);
        let mr = PartitionMetrics::compute(&structure, &r);
        assert!(
            (mh.total_volume() as f64) < mr.total_volume() as f64 * 0.8,
            "H volume {} not well below R volume {}",
            mh.total_volume(),
            mr.total_volume()
        );
    }

    #[test]
    fn deterministic() {
        let structure = generate_structure(&RadixNetConfig::graph_challenge(64, 4).unwrap());
        let cfg = PhaseConfig::new(4);
        let a = hypergraph_partition(&structure, &cfg);
        let b = hypergraph_partition(&structure, &cfg);
        assert_eq!(a.layer_parts, b.layer_parts);
        assert_eq!(a.input_parts, b.input_parts);
    }
}
