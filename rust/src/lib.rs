//! spdnn — reproduction of "Partitioning Sparse Deep Neural Networks for
//! Scalable Training and Inference" (Demirci & Ferhatosmanoglu, ICS'21).
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: the distributed coordinator — sparse substrate,
//!   hypergraph partitioner, multi-phase DNN partitioning model, simulated
//!   message-passing fabric, SpFF/SpBP engines (Algorithms 2–3), metrics.
//! - **L2 (python/compile/model.py)**: rank-local layer compute in JAX,
//!   AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels/)**: the SpMV hot-spot as a Pallas
//!   block-sparse masked-matmul kernel (interpret mode on CPU).
//!
//! The L3 hot path runs on the shared-memory rank-parallel engine
//! (`runtime::parallel`: one OS thread per rank over the message-passing
//! fabric); request streams are served by the persistent rank pool
//! (`serving::RankPool`: long-lived rank threads, adaptive micro-batching,
//! latency stats), and the AOT artifacts can optionally execute through
//! the PJRT CPU client (`runtime::pjrt`, feature `pjrt`), with Python
//! never on the request path.

// The CSR kernels and schedule code are index-heavy by nature; explicit
// ranges over coupled arrays (indptr/indices/vals) read clearer than
// iterator chains there.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]
// `unsafe` is confined to the validated CSR kernels (`sparse::csr`, which
// carries the one scoped `allow`); everything else — fabric, engines,
// serving, analysis — must stay safe code.
#![deny(unsafe_code)]

pub mod analysis;
pub mod comm;
pub mod coordinator;
pub mod data;
pub mod hypergraph;
pub mod partition;
pub mod dnn;
pub mod experiments;
pub mod obs;
pub mod radixnet;
pub mod replica;
pub mod runtime;
pub mod serving;
pub mod sparse;
pub mod util;
