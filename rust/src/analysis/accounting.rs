//! Accounting cross-checks (`A...` diagnostics): the plan's static byte
//! claims, the codec contracts, and the rank-state codec tables must all
//! tell one story.
//!
//! Three parties account for bytes-on-wire: the plan
//! ([`crate::partition::CommPlan::fwd_wire_bytes`]), the replay /
//! α-β network model ([`crate::comm::NetModel`], charged per whole
//! transfer), and the live fabric counters (which count
//! `4 × encode_into(..).len()` per send). They agree only if the codec's
//! `wire_words` arithmetic matches the documented wire format AND
//! `encode_into` actually produces `wire_words(len)` words. These checks
//! pin every link of that chain statically.

use super::{Code, Violation};
use crate::comm::codec::DEFAULT_INT8_GROUP;
use crate::comm::{Codec, NetModel};
use crate::coordinator::worker::RankState;
use crate::coordinator::ExecMode;
use crate::partition::CommPlan;
use std::collections::BTreeSet;

/// Wire footprint in f32 words recomputed from the **documented** wire
/// format (header words + scale block + packed lanes, see the
/// `comm::codec` module doc) — deliberately independent of
/// [`Codec::wire_words`], so drift between the doc and the
/// implementation surfaces as `A001` instead of silently propagating
/// into every counter.
fn spec_wire_words(codec: Codec, len: usize) -> usize {
    match codec {
        Codec::F32 => len,
        Codec::F16 => 2 + len.div_ceil(2),
        Codec::Int8 { group } => {
            let g = if group == 0 { DEFAULT_INT8_GROUP } else { group };
            2 + len.div_ceil(g) + len.div_ceil(4)
        }
    }
}

fn chunking(mode: ExecMode) -> usize {
    match mode {
        ExecMode::Pipelined { chunk_acts } => chunk_acts,
        _ => 0,
    }
}

/// `A001`/`A002`: per layer, the plan's chunked `fwd_wire_bytes` must
/// equal the spec recomputation, and the whole-transfer charge basis the
/// replay/netmodel uses must equal the plan's unchunked form. For F32
/// the α-β model's byte form must also price the layer identically to
/// its word form (bytes = 4 × words exactly).
pub fn check_wire_accounting(
    plan: &CommPlan,
    mode: ExecMode,
    batch: usize,
    out: &mut Vec<Violation>,
) {
    let ca = chunking(mode);
    let nm = NetModel::infiniband();
    for (k, lp) in plan.layers.iter().enumerate() {
        let spec: u64 = lp
            .transfers
            .iter()
            .flat_map(|t| t.chunks(ca))
            .map(|(_, idx)| 4 * spec_wire_words(lp.codec_fwd, idx.len() * batch) as u64)
            .sum();
        let claimed = lp.fwd_wire_bytes(batch, ca);
        if spec != claimed {
            out.push(
                Violation::new(
                    Code::WireBytesMismatch,
                    format!(
                        "chunked {} wire bytes: plan claims {claimed}, wire format \
                         yields {spec}",
                        lp.codec_fwd.label()
                    ),
                )
                .at(k),
            );
        }
        let replay: u64 = lp
            .transfers
            .iter()
            .map(|t| lp.codec_fwd.wire_bytes(t.indices.len() * batch))
            .sum();
        if replay != lp.fwd_wire_bytes(batch, 0) {
            out.push(
                Violation::new(
                    Code::ReplayChargeMismatch,
                    format!(
                        "whole-transfer charge {replay} != unchunked plan bytes {}",
                        lp.fwd_wire_bytes(batch, 0)
                    ),
                )
                .at(k),
            );
        }
        if lp.codec_fwd == Codec::F32 {
            let msgs = lp.message_count_chunked(ca);
            let words = lp.volume() * batch as u64;
            let by_words = nm.layer_cost(msgs, words, msgs, words);
            let by_bytes = nm.layer_cost_bytes(msgs, claimed, msgs, claimed);
            if by_words != by_bytes {
                out.push(
                    Violation::new(
                        Code::ReplayChargeMismatch,
                        format!(
                            "netmodel f32 layer cost differs by form: {by_words} (words) \
                             vs {by_bytes} (bytes)"
                        ),
                    )
                    .at(k),
                );
            }
        }
    }
}

/// `A003`: for every distinct `(codec, payload length)` pair this plan
/// can put on the wire, `wire_bytes` must be `4 × wire_words`, and both
/// `encode_into` and `encode_into_checked` must produce exactly their
/// declared word counts — the fabric's counter contract (counters charge
/// `4 × encoded length`).
pub fn check_codec_contract(
    plan: &CommPlan,
    mode: ExecMode,
    batch: usize,
    out: &mut Vec<Violation>,
) {
    let ca = chunking(mode);
    // (codec id, int8 group, payload length), deduped across the plan
    let mut lens: BTreeSet<(u16, usize, usize)> = BTreeSet::new();
    for lp in &plan.layers {
        for codec in [lp.codec_fwd, lp.codec_bwd] {
            let group = match codec {
                Codec::Int8 { group } => group,
                _ => 0,
            };
            for t in &lp.transfers {
                for (_, idx) in t.chunks(ca) {
                    lens.insert((codec.id(), group, idx.len() * batch));
                }
            }
        }
    }
    let mut wire = Vec::new();
    for &(id, group, len) in &lens {
        let codec = match id {
            0 => Codec::F32,
            1 => Codec::F16,
            _ => Codec::Int8 { group },
        };
        if codec.wire_bytes(len) != 4 * codec.wire_words(len) as u64 {
            out.push(Violation::new(
                Code::CodecContractBroken,
                format!(
                    "{} len {len}: wire_bytes {} != 4 × wire_words {}",
                    codec.label(),
                    codec.wire_bytes(len),
                    codec.wire_words(len)
                ),
            ));
        }
        let src = vec![0.37f32; len];
        codec.encode_into(&src, &mut wire);
        if wire.len() != codec.wire_words(len) {
            out.push(Violation::new(
                Code::CodecContractBroken,
                format!(
                    "{} len {len}: encode_into produced {} words, wire_words says {}",
                    codec.label(),
                    wire.len(),
                    codec.wire_words(len)
                ),
            ));
        }
        codec.encode_into_checked(&src, &mut wire);
        if wire.len() != codec.checked_wire_words(len) {
            out.push(Violation::new(
                Code::CodecContractBroken,
                format!(
                    "{} len {len}: checked encode produced {} words, contract says {}",
                    codec.label(),
                    wire.len(),
                    codec.checked_wire_words(len)
                ),
            ));
        }
    }
}

/// `A004`: the codec table a built [`RankState`] baked in must match the
/// plan it will execute against — a mismatch means sender and receiver
/// could frame one payload with two different codecs.
pub fn check_state_codecs(state: &RankState, plan: &CommPlan) -> Vec<Violation> {
    let mut out = Vec::new();
    if state.codecs.len() != plan.layers.len() {
        out.push(
            Violation::new(
                Code::StateCodecMismatch,
                format!(
                    "state carries {} codec pairs, plan has {} layers",
                    state.codecs.len(),
                    plan.layers.len()
                ),
            )
            .on(state.rank),
        );
        return out;
    }
    for (k, (lp, &(cf, cb))) in plan.layers.iter().zip(state.codecs.iter()).enumerate() {
        if cf != lp.codec_fwd || cb != lp.codec_bwd {
            out.push(
                Violation::new(
                    Code::StateCodecMismatch,
                    format!(
                        "state encodes {}/{}, plan says {}/{}",
                        cf.label(),
                        cb.label(),
                        lp.codec_fwd.label(),
                        lp.codec_bwd.label()
                    ),
                )
                .at(k)
                .on(state.rank),
            );
        }
    }
    out
}
