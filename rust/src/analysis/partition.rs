//! Partition-soundness checks (`P...` diagnostics): ownership, transfer
//! well-formedness, coverage, and the pipelined row regroup.
//!
//! The engine contract these checks prove statically is the one
//! [`crate::sparse::SplitCsr::build`] and the full-width scatter path
//! enforce dynamically per rank: every activation a row block reads is
//! either owned by the rank or delivered by exactly one inbound
//! transfer, and everything a rank sends it actually computed.

use super::{Code, Violation};
use crate::partition::{CommPlan, DnnPartition};
use crate::sparse::{regroup_rows, Csr};

/// Shape consistency between structure, partition, and plan
/// (`P001`/`P002`/`P004`). Returns false when the shapes are too broken
/// for the deeper checks to index safely.
pub fn check_shapes(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    out: &mut Vec<Violation>,
) -> bool {
    let before = out.len();
    if structure.is_empty() {
        out.push(Violation::new(
            Code::ShapeMismatch,
            "structure has no layers",
        ));
        return false;
    }
    if part.layer_parts.len() != structure.len() {
        out.push(Violation::new(
            Code::ShapeMismatch,
            format!(
                "partition assigns {} layers, structure has {}",
                part.layer_parts.len(),
                structure.len()
            ),
        ));
    }
    if plan.layers.len() != structure.len() {
        out.push(Violation::new(
            Code::ShapeMismatch,
            format!(
                "plan covers {} layers, structure has {}",
                plan.layers.len(),
                structure.len()
            ),
        ));
    }
    if plan.nparts != part.nparts {
        out.push(Violation::new(
            Code::ShapeMismatch,
            format!(
                "plan built for {} ranks, partition declares {}",
                plan.nparts, part.nparts
            ),
        ));
    }
    if part.input_parts.len() != structure[0].ncols {
        out.push(Violation::new(
            Code::InputMismatch,
            format!(
                "input assignment covers {} entries, layer 0 reads {}",
                part.input_parts.len(),
                structure[0].ncols
            ),
        ));
    }
    for k in 1..structure.len() {
        if structure[k].ncols != structure[k - 1].nrows {
            out.push(
                Violation::new(
                    Code::ShapeMismatch,
                    format!(
                        "layer {k} reads {} columns but layer {} outputs {} rows",
                        structure[k].ncols,
                        k - 1,
                        structure[k - 1].nrows
                    ),
                )
                .at(k),
            );
        }
    }
    for (k, (parts, w)) in part.layer_parts.iter().zip(structure.iter()).enumerate() {
        if parts.len() != w.nrows {
            out.push(
                Violation::new(
                    Code::RowCountMismatch,
                    format!("layer {k} assigns {} rows, matrix has {}", parts.len(), w.nrows),
                )
                .at(k),
            );
        }
    }
    for (k, lp) in plan.layers.iter().enumerate() {
        if lp.send_of.len() != part.nparts || lp.recv_of.len() != part.nparts {
            out.push(
                Violation::new(
                    Code::ShapeMismatch,
                    format!(
                        "layer {k} plan views sized {}/{} for {} ranks",
                        lp.send_of.len(),
                        lp.recv_of.len(),
                        part.nparts
                    ),
                )
                .at(k),
            );
        }
    }
    out.len() == before
}

/// Every rank id the partition hands out is in range (`P003`). Reports
/// at most one violation per assignment vector to avoid flooding.
pub fn check_ranks(part: &DnnPartition, out: &mut Vec<Violation>) {
    if let Some((j, &p)) = part
        .input_parts
        .iter()
        .enumerate()
        .find(|&(_, &p)| p as usize >= part.nparts)
    {
        out.push(Violation::new(
            Code::RankOutOfRange,
            format!("input entry {j} assigned to rank {p} of {}", part.nparts),
        ));
    }
    for (k, parts) in part.layer_parts.iter().enumerate() {
        if let Some((r, &p)) = parts
            .iter()
            .enumerate()
            .find(|&(_, &p)| p as usize >= part.nparts)
        {
            out.push(
                Violation::new(
                    Code::RankOutOfRange,
                    format!("layer {k} row {r} assigned to rank {p} of {}", part.nparts),
                )
                .at(k),
            );
        }
    }
}

/// Transfer well-formedness per layer (`P020`/`P022`/`P023`/`P024` and
/// endpoint `P003`): indices strictly ascending, in-bounds, non-empty,
/// and **owned by the sending rank** — the "every row owned exactly
/// once" half that catches a duplicated row owner, because the plan's
/// sender no longer matches `owner_of_activation` after the flip.
pub fn check_transfers(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    out: &mut Vec<Violation>,
) {
    for (k, (lp, w)) in plan.layers.iter().zip(structure.iter()).enumerate() {
        for (tid, t) in lp.transfers.iter().enumerate() {
            if t.from as usize >= part.nparts || t.to as usize >= part.nparts {
                out.push(
                    Violation::new(
                        Code::RankOutOfRange,
                        format!(
                            "transfer {tid} endpoints {}→{} outside {} ranks",
                            t.from, t.to, part.nparts
                        ),
                    )
                    .at(k),
                );
                continue;
            }
            if t.indices.is_empty() {
                out.push(
                    Violation::new(
                        Code::EmptyTransfer,
                        format!("transfer {tid} ({}→{}) carries no indices", t.from, t.to),
                    )
                    .at(k)
                    .on(t.from),
                );
                continue;
            }
            if t.indices.windows(2).any(|p| p[0] >= p[1]) {
                out.push(
                    Violation::new(
                        Code::UnsortedTransfer,
                        format!(
                            "transfer {tid} ({}→{}) indices not strictly ascending",
                            t.from, t.to
                        ),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
            if let Some(&j) = t.indices.iter().find(|&&j| j as usize >= w.ncols) {
                out.push(
                    Violation::new(
                        Code::IndexOutOfBounds,
                        format!(
                            "transfer {tid} ({}→{}) index {j} outside {} columns",
                            t.from, t.to, w.ncols
                        ),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
            if let Some(&j) = t.indices.iter().find(|&&j| {
                (j as usize) < w.ncols && part.owner_of_activation(k, j as usize) != t.from
            }) {
                let owner = part.owner_of_activation(k, j as usize);
                out.push(
                    Violation::new(
                        Code::ForeignSend,
                        format!(
                            "transfer {tid} ({}→{}) carries activation {j} owned by rank {owner}",
                            t.from, t.to
                        ),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
        }
    }
}

/// Coverage per (layer, rank) (`P021`/`P025`): walking every nonzero of
/// the rank's row block, each referenced column must be owned-or-
/// delivered exactly once. One violation per (layer, rank, class) with a
/// count, so a systematically broken plan stays readable.
pub fn check_coverage(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    out: &mut Vec<Violation>,
) {
    for (k, (lp, w)) in plan.layers.iter().zip(structure.iter()).enumerate() {
        for m in 0..part.nparts {
            // cover[j]: times x^{k-1}(j) is available to rank m
            let mut cover = vec![0u8; w.ncols];
            for (j, c) in cover.iter_mut().enumerate() {
                if part.owner_of_activation(k, j) as usize == m {
                    *c = 1;
                }
            }
            let mut dups = 0usize;
            let mut first_dup = None;
            for &tid in &lp.recv_of[m] {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    continue; // S007 reported by the schedule checks
                };
                for &j in &t.indices {
                    let j = j as usize;
                    if j >= w.ncols {
                        continue; // P022 reported by check_transfers
                    }
                    if cover[j] >= 1 {
                        dups += 1;
                        if first_dup.is_none() {
                            first_dup = Some((j, tid));
                        }
                    }
                    cover[j] = cover[j].saturating_add(1);
                }
            }
            if let Some((j, tid)) = first_dup {
                out.push(
                    Violation::new(
                        Code::DoubleDelivery,
                        format!(
                            "column {j} reaches rank {m} twice (via transfer {tid}); \
                             {dups} duplicated deliveries in this layer"
                        ),
                    )
                    .at(k)
                    .on(m as u32),
                );
            }
            let mut missing = 0usize;
            let mut first_miss = None;
            for (r, &p) in part.layer_parts[k].iter().enumerate() {
                if p as usize != m {
                    continue;
                }
                for &c in w.row(r).0 {
                    if (c as usize) < w.ncols && cover[c as usize] == 0 {
                        missing += 1;
                        if first_miss.is_none() {
                            first_miss = Some((r, c));
                        }
                    }
                }
            }
            if let Some((r, c)) = first_miss {
                out.push(
                    Violation::new(
                        Code::UncoveredColumn,
                        format!(
                            "row {r} needs column {c}, neither owned nor received by \
                             rank {m}; {missing} uncovered reads in this layer"
                        ),
                    )
                    .at(k)
                    .on(m as u32),
                );
            }
        }
    }
}

/// Pipelined row-regroup soundness (`P010`/`P011`/`P012`): re-derive the
/// per-rank boundary-first permutation exactly the way
/// [`crate::coordinator::RankState::build`] does and verify perm/inv are
/// mutual inverses, the boundary prefix covers every chunk group, and
/// each outbound chunk's source rows sit inside its ready prefix.
pub fn check_regroup(
    part: &DnnPartition,
    plan: &CommPlan,
    chunk_acts: usize,
    out: &mut Vec<Violation>,
) {
    let depth = plan.layers.len();
    for m in 0..part.nparts {
        for k in 0..depth {
            let owned = part.rows_of(k, m as u32);
            // Re-derive `outbound_chunks_of(m)` of the NEXT layer in view
            // order, exactly as the engine does — but through
            // `transfers.get` so a corrupt view (S007, reported by the
            // schedule checks) cannot panic here, and with foreign
            // indices (P020, reported elsewhere) dropped.
            let mut groups: Vec<Vec<u32>> = Vec::new();
            if k + 1 < depth {
                let lp = &plan.layers[k + 1];
                for &tid in &lp.send_of[m] {
                    let Some(t) = lp.transfers.get(tid as usize) else {
                        continue;
                    };
                    for (_, idx) in t.chunks(chunk_acts) {
                        groups.push(
                            idx.iter()
                                .filter_map(|&j| owned.binary_search(&j).ok().map(|p| p as u32))
                                .collect(),
                        );
                    }
                }
            }
            let rg = regroup_rows(owned.len(), &groups);
            let n = owned.len();
            let mut perm_ok = rg.perm.len() == n && rg.inv.len() == n;
            if perm_ok {
                for (i, &p) in rg.perm.iter().enumerate() {
                    if p as usize >= n || rg.inv[p as usize] as usize != i {
                        perm_ok = false;
                        break;
                    }
                }
            }
            if !perm_ok {
                out.push(
                    Violation::new(
                        Code::RegroupNotInverse,
                        format!("rank {m} regroup over {n} rows: perm/inv are not inverse"),
                    )
                    .at(k)
                    .on(m as u32),
                );
                continue;
            }
            let prefix_ok = rg.boundary_end <= n
                && rg.ready.len() == groups.len()
                && rg.ready.iter().all(|&e| e <= rg.boundary_end);
            if !prefix_ok {
                out.push(
                    Violation::new(
                        Code::BoundaryPrefixBroken,
                        format!(
                            "rank {m}: boundary_end {} of {n} rows, ready {:?} \
                             ({} groups)",
                            rg.boundary_end,
                            rg.ready,
                            groups.len()
                        ),
                    )
                    .at(k)
                    .on(m as u32),
                );
                continue;
            }
            for (i, g) in groups.iter().enumerate() {
                if let Some(&p) = g.iter().find(|&&p| rg.inv[p as usize] as usize >= rg.ready[i]) {
                    out.push(
                        Violation::new(
                            Code::ChunkOutsideReady,
                            format!(
                                "rank {m} chunk group {i}: local row {p} sits at permuted \
                                 position {} beyond ready prefix {}",
                                rg.inv[p as usize],
                                rg.ready[i]
                            ),
                        )
                        .at(k)
                        .on(m as u32),
                    );
                }
            }
        }
    }
}
