//! Replica-ring schedule verification (`R...` diagnostics): proves the
//! cross-group gradient all-reduce of [`crate::replica`] deadlock-free
//! and its accounting honest **without spawning a thread**.
//!
//! The live engine ([`crate::replica::GradAllReduce`]) derives every
//! send/recv tag from the pure functions in
//! [`crate::replica::topology`]; this module re-executes the same
//! schedule hop-by-hop, single-threaded, against those same functions
//! and checks the properties the engine's correctness rests on:
//!
//! - **R001** — at every hop of both phases, what group `g` sends to
//!   `g+1` is exactly what `g+1` waits for (a perfect matching; because
//!   the fabric matches purely on tags, this is deadlock-freedom by
//!   construction), and no tag repeats across hops.
//! - **R002** — the `R` segments are contiguous, disjoint, and cover the
//!   flat gradient `[0, m)` exactly.
//! - **R003** — the reduce-scatter leaves each owner with (a bounded
//!   approximation of) the full group sum, and the allgather delivers
//!   every segment everywhere, never forwarding bytes a group does not
//!   hold.
//! - **R004** — wire words counted during the simulation equal
//!   [`predicted_wire_words`], the same prediction the live fabric
//!   counters are checked against.
//! - **R005** — the EF residual contract: a lossless codec leaves the
//!   residual identically zero; all replicas end bit-identical; and for
//!   lossy codecs the adopted result plus every group's residual
//!   reconstructs the exact sum (no quantization error is silently
//!   dropped).
//! - **R006** — in the allgather each segment is encoded exactly once
//!   (by its owner); forwards travel verbatim.

use super::{Code, CheckReport, Violation};
use crate::comm::Codec;
use crate::replica::allreduce::predicted_wire_words;
use crate::replica::topology::{
    gather_recv_seg, gather_send_seg, owned_seg, scatter_recv_seg, scatter_send_seg, seg_bounds,
};
use std::collections::BTreeSet;

/// `R002` over an arbitrary bounds function (the real check passes
/// [`seg_bounds`]; tests pass broken closures to prove detection).
fn check_partition_with<F: Fn(usize) -> (usize, usize)>(
    m: usize,
    groups: usize,
    bounds: F,
    out: &mut Vec<Violation>,
) {
    let mut covered = 0usize;
    for s in 0..groups {
        let (lo, hi) = bounds(s);
        if lo != covered || hi < lo || hi > m {
            out.push(Violation::new(
                Code::SegPartitionBroken,
                format!("R={groups} m={m}: segment {s} spans [{lo}, {hi}) after [0, {covered})"),
            ));
            return;
        }
        covered = hi;
    }
    if covered != m {
        out.push(Violation::new(
            Code::SegPartitionBroken,
            format!("R={groups} m={m}: segments cover only [0, {covered})"),
        ));
    }
}

/// `R001` over arbitrary send/recv segment functions `(me, hop) -> seg`:
/// every hop must be a perfect matching and no (from, hop, seg) send tag
/// may repeat within the phase.
fn check_matching_with<S, R>(
    groups: usize,
    phase: &str,
    send: S,
    recv: R,
    out: &mut Vec<Violation>,
) where
    S: Fn(usize, usize) -> usize,
    R: Fn(usize, usize) -> usize,
{
    let mut tags: BTreeSet<(usize, usize, usize)> = BTreeSet::new();
    for hop in 0..groups.saturating_sub(1) {
        for me in 0..groups {
            let next = (me + 1) % groups;
            let s = send(me, hop);
            let want = recv(next, hop);
            if s != want {
                out.push(Violation::new(
                    Code::RingTagMismatch,
                    format!(
                        "R={groups} {phase} hop {hop}: {me} sends segment {s}, \
                         {next} waits for {want}"
                    ),
                ));
            }
            if s >= groups {
                out.push(Violation::new(
                    Code::RingTagMismatch,
                    format!("R={groups} {phase} hop {hop}: segment id {s} out of range"),
                ));
            } else if !tags.insert((me, hop, s)) {
                out.push(Violation::new(
                    Code::RingTagMismatch,
                    format!("R={groups} {phase}: duplicate send tag ({me}, hop {hop}, seg {s})"),
                ));
            }
        }
    }
}

/// Verify the full ring all-reduce of one length-`m` gradient across
/// `groups` replicas under `codec` (optionally in the checked chaos
/// envelope), appending any violation found. Returns the wire words the
/// simulation moved (0 for `groups == 1` — the degenerate fold-only
/// case, like the live engine).
pub fn check_replica(
    groups: usize,
    m: usize,
    codec: Codec,
    checked: bool,
    out: &mut Vec<Violation>,
) -> u64 {
    assert!(groups >= 1);
    check_partition_with(m, groups, |s| seg_bounds(m, groups, s), out);
    check_matching_with(
        groups,
        "scatter",
        |me, hop| scatter_send_seg(me, groups, hop),
        |me, hop| scatter_recv_seg(me, groups, hop),
        out,
    );
    check_matching_with(
        groups,
        "gather",
        |me, hop| gather_send_seg(me, groups, hop),
        |me, hop| gather_recv_seg(me, groups, hop),
        out,
    );

    // Numeric replay of the live engine's exact dataflow. Integer-valued
    // inputs in [-11, 11]: partial sums stay ≤ 11·R, exactly
    // representable in both f32 and f16, so only int8 quantizes lossily.
    let lossless = codec == Codec::F32;
    let enc = |src: &[f32]| -> Vec<f32> {
        let mut w = Vec::new();
        if checked {
            codec.encode_into_checked(src, &mut w);
        } else {
            codec.encode_into(src, &mut w);
        }
        w
    };
    let dec = |wire: &[f32]| -> Vec<f32> {
        let mut d = Vec::new();
        if checked {
            codec.decode_checked_into(wire, &mut d);
        } else {
            codec.decode_into(wire, &mut d);
        }
        d
    };
    let mut grads: Vec<Vec<f32>> = (0..groups)
        .map(|g| (0..m).map(|i| ((g * 31 + i * 7) % 23) as f32 - 11.0).collect())
        .collect();
    let expect: Vec<f32> = (0..m)
        .map(|i| (0..groups).map(|g| grads[g][i]).sum::<f32>())
        .collect();
    let mut resid = vec![vec![0f32; m]; groups];
    let mut words = vec![0u64; groups];

    if groups > 1 {
        // Phase 1 — reduce-scatter: every hop, all payloads are encoded
        // from the pre-receive state (the live send-then-recv order).
        for hop in 0..groups - 1 {
            let payloads: Vec<Vec<f32>> = (0..groups)
                .map(|me| {
                    let s = scatter_send_seg(me, groups, hop);
                    let (lo, hi) = seg_bounds(m, groups, s);
                    let wire = enc(&grads[me][lo..hi]);
                    if !lossless {
                        let d = dec(&wire);
                        for (i, dv) in d.iter().enumerate() {
                            resid[me][lo + i] += grads[me][lo + i] - dv;
                        }
                    }
                    words[me] += wire.len() as u64;
                    wire
                })
                .collect();
            for me in 0..groups {
                let prev = (me + groups - 1) % groups;
                let s = scatter_recv_seg(me, groups, hop);
                if s != scatter_send_seg(prev, groups, hop) {
                    continue; // already an R001 above
                }
                let (lo, hi) = seg_bounds(m, groups, s);
                let d = dec(&payloads[prev]);
                if d.len() != hi - lo {
                    out.push(Violation::new(
                        Code::RingTagMismatch,
                        format!(
                            "R={groups} scatter hop {hop}: segment {s} payload decodes to \
                             {} elements, bounds say {}",
                            d.len(),
                            hi - lo
                        ),
                    ));
                    continue;
                }
                for (i, dv) in d.iter().enumerate() {
                    grads[me][lo + i] += dv;
                }
            }
        }

        // Phase 2 — allgather: each owner encodes its reduced segment
        // once (adopting the decoded values itself), then bytes travel
        // the ring verbatim.
        let mut held: Vec<Vec<Option<Vec<f32>>>> =
            (0..groups).map(|_| (0..groups).map(|_| None).collect()).collect();
        let mut encodes = vec![0u32; groups];
        for me in 0..groups {
            let s = owned_seg(me, groups);
            let (lo, hi) = seg_bounds(m, groups, s);
            let wire = enc(&grads[me][lo..hi]);
            encodes[s] += 1;
            if !lossless {
                let d = dec(&wire);
                for (i, dv) in d.iter().enumerate() {
                    resid[me][lo + i] += grads[me][lo + i] - dv;
                }
                grads[me][lo..hi].copy_from_slice(&d);
            }
            held[me][s] = Some(wire);
        }
        for hop in 0..groups - 1 {
            let outgoing: Vec<Option<Vec<f32>>> = (0..groups)
                .map(|me| {
                    let s = gather_send_seg(me, groups, hop);
                    match &held[me][s] {
                        Some(w) => {
                            words[me] += w.len() as u64;
                            Some(w.clone())
                        }
                        None => {
                            out.push(Violation::new(
                                Code::RingDeliveryIncomplete,
                                format!(
                                    "R={groups} gather hop {hop}: group {me} forwards \
                                     segment {s} it does not hold"
                                ),
                            ));
                            None
                        }
                    }
                })
                .collect();
            for me in 0..groups {
                let prev = (me + groups - 1) % groups;
                let s = gather_recv_seg(me, groups, hop);
                if s != gather_send_seg(prev, groups, hop) {
                    continue; // already an R001
                }
                if let Some(w) = &outgoing[prev] {
                    let (lo, hi) = seg_bounds(m, groups, s);
                    let d = dec(w);
                    if d.len() == hi - lo {
                        grads[me][lo..hi].copy_from_slice(&d);
                        held[me][s] = Some(w.clone());
                    } else {
                        out.push(Violation::new(
                            Code::RingTagMismatch,
                            format!(
                                "R={groups} gather hop {hop}: segment {s} payload decodes \
                                 to {} elements, bounds say {}",
                                d.len(),
                                hi - lo
                            ),
                        ));
                    }
                }
            }
        }
        for (s, &n) in encodes.iter().enumerate() {
            if n != 1 {
                out.push(Violation::new(
                    Code::GatherEncodeMiscount,
                    format!("R={groups}: segment {s} encoded {n} times in the allgather"),
                ));
            }
        }
        for (me, h) in held.iter().enumerate() {
            if let Some(s) = h.iter().position(|x| x.is_none()) {
                out.push(Violation::new(
                    Code::RingDeliveryIncomplete,
                    format!("R={groups}: group {me} never received segment {s}"),
                ));
            }
        }
    }

    // Final-value contracts. Integer inputs make f32/f16 exact; int8's
    // error is bounded by one half quantization step per encode on the
    // chain (absmax ≤ 11·R, so step/2 ≤ 11·R/254 per hop).
    let tol = match codec {
        Codec::F32 | Codec::F16 => 1e-6,
        Codec::Int8 { .. } => 0.5 * groups as f32 + 0.1,
    };
    for me in 0..groups {
        for i in 0..m {
            if grads[me][i].to_bits() != grads[0][i].to_bits() {
                out.push(Violation::new(
                    Code::ResidualContractBroken,
                    format!("R={groups} m={m}: groups 0 and {me} diverged at element {i}"),
                ));
                break;
            }
        }
    }
    for i in 0..m {
        if (grads[0][i] - expect[i]).abs() > tol {
            out.push(Violation::new(
                Code::RingDeliveryIncomplete,
                format!(
                    "R={groups} m={m}: element {i} reduced to {} (expected {} ± {tol})",
                    grads[0][i], expect[i]
                ),
            ));
            break;
        }
        // EF conservation: the adopted value plus every group's residual
        // at this element reconstructs the exact sum.
        let recon: f32 = grads[0][i] + resid.iter().map(|r| r[i]).sum::<f32>();
        if (recon - expect[i]).abs() > 0.02 {
            out.push(Violation::new(
                Code::ResidualContractBroken,
                format!(
                    "R={groups} m={m}: element {i} adopted+residual {} fails to \
                     reconstruct {}",
                    recon, expect[i]
                ),
            ));
            break;
        }
    }
    if lossless {
        for (me, r) in resid.iter().enumerate() {
            if r.iter().any(|&x| x != 0.0) {
                out.push(Violation::new(
                    Code::ResidualContractBroken,
                    format!("R={groups}: lossless codec left group {me} a nonzero residual"),
                ));
            }
        }
    }
    for (me, &w) in words.iter().enumerate() {
        let want = predicted_wire_words(me, groups, m, codec, checked);
        if w != want {
            out.push(Violation::new(
                Code::RingWireMismatch,
                format!("R={groups} m={m}: group {me} moved {w} wire words, predicted {want}"),
            ));
        }
    }
    words.iter().sum()
}

/// Run [`check_replica`] over the built-in replica matrix: R ∈
/// {1, 2, 3, 4, 8} rings × all codecs (plus a small int8 scale group) ×
/// plain and checked envelopes, each over gradient lengths spanning
/// empty, sub-ring, and multi-group-span sizes. One report per
/// (R, codec, envelope); `spdnn check` and CI require every one
/// [`CheckReport::ok`].
pub fn check_replica_matrix() -> Vec<CheckReport> {
    let ms = [0usize, 1, 5, 64, 257];
    let codecs = [Codec::F32, Codec::F16, Codec::int8(), Codec::Int8 { group: 16 }];
    let mut reports = Vec::new();
    for groups in [1usize, 2, 3, 4, 8] {
        for &codec in &codecs {
            for checked in [false, true] {
                let mut violations = Vec::new();
                let mut wire_words = 0u64;
                for &m in &ms {
                    wire_words += check_replica(groups, m, codec, checked, &mut violations);
                }
                let label = if codec == (Codec::Int8 { group: 16 }) {
                    "int8/g16".to_string()
                } else {
                    codec.label().to_string()
                };
                let env = if checked { " checked" } else { "" };
                let msgs = (ms.len() * groups * 2 * groups.saturating_sub(1)) as u64;
                reports.push(CheckReport {
                    config: format!("replica ring R={groups} {label}{env}"),
                    layers: ms.len(),
                    nparts: groups,
                    batch: 0,
                    transfers: msgs,
                    messages: msgs,
                    wire_bytes: 4 * wire_words,
                    violations,
                });
            }
        }
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_replica_matrix_is_clean() {
        let reports = check_replica_matrix();
        assert!(!reports.is_empty());
        for r in &reports {
            assert!(r.ok(), "replica matrix violation:\n{}", r.render());
        }
        // R = 1 configurations move nothing; R > 1 f32 ones move plenty
        assert!(reports.iter().any(|r| r.nparts == 1 && r.wire_bytes == 0));
        assert!(reports.iter().any(|r| r.nparts > 1 && r.wire_bytes > 0));
    }

    #[test]
    fn wire_accounting_matches_the_prediction_sum() {
        let mut v = Vec::new();
        let words = check_replica(4, 101, Codec::int8(), false, &mut v);
        assert!(v.is_empty(), "{v:?}");
        let want: u64 = (0..4)
            .map(|g| predicted_wire_words(g, 4, 101, Codec::int8(), false))
            .sum();
        assert_eq!(words, want);
    }

    #[test]
    fn broken_partition_is_detected() {
        let mut v = Vec::new();
        // overlapping segments: [0, 2), [1, 3), ...
        check_partition_with(4, 2, |s| (s, s + 2), &mut v);
        assert!(
            v.iter().any(|x| x.code == Code::SegPartitionBroken),
            "overlapping bounds must raise R002"
        );
        let mut v = Vec::new();
        // short coverage: [0, 1), [1, 2) over m = 4
        check_partition_with(4, 2, |s| (s, s + 1), &mut v);
        assert!(v.iter().any(|x| x.code == Code::SegPartitionBroken));
    }

    #[test]
    fn mismatched_schedule_is_detected() {
        let mut v = Vec::new();
        // a receiver waiting for the wrong segment deadlocks the ring
        check_matching_with(3, "bogus", |me, hop| (me + hop) % 3, |me, _| me, &mut v);
        assert!(
            v.iter().any(|x| x.code == Code::RingTagMismatch),
            "mismatched send/recv must raise R001"
        );
    }

    #[test]
    fn checked_envelope_accounting_holds() {
        // the chaos envelope adds header + checksum framing; R004 must
        // still balance exactly
        let mut v = Vec::new();
        check_replica(3, 64, Codec::F32, true, &mut v);
        check_replica(3, 64, Codec::F16, true, &mut v);
        assert!(v.is_empty(), "{v:?}");
    }
}
