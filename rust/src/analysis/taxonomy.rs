//! Trace-span taxonomy conformance (`T...` diagnostics): the span names
//! and categories engines emit must match the documented set in
//! `docs/OBSERVABILITY.md`, which is embedded at compile time so the doc
//! and the checker can never drift apart.

use super::{Code, Violation};
use crate::obs::Span;
use std::collections::BTreeSet;

/// Every span name an engine, the serving pool, or the trace driver may
/// emit — the canonical taxonomy (kept sorted; mirrors the table in
/// `docs/OBSERVABILITY.md`).
pub const SPAN_NAMES: &[&str] = &[
    "allreduce.fold",
    "allreduce.gather",
    "allreduce.scatter",
    "coalesce",
    "dispatch",
    "epilogue",
    "epilogue.boundary",
    "epilogue.interior",
    "pass",
    "post",
    "queue.wait",
    "respawn",
    "send",
    "spmv",
    "spmv.boundary",
    "spmv.interior",
    "spmv.local",
    "spmv.seg",
    "spmvt",
    "spmvt.seg",
    "updt",
    "wait",
];

/// Every span category: replica all-reduce, forward, backward, serving
/// pool, capture driver.
pub const SPAN_CATS: &[&str] = &["alr", "bwd", "drv", "fwd", "pool"];

/// The documented taxonomy, embedded so checker and doc version together.
const OBSERVABILITY_DOC: &str = include_str!("../../../docs/OBSERVABILITY.md");

/// `T003`: every taxonomy entry must appear (backticked) in
/// `docs/OBSERVABILITY.md` — an engine span added to the code without a
/// doc row fails here.
pub fn check_doc(out: &mut Vec<Violation>) {
    for name in SPAN_NAMES {
        if !OBSERVABILITY_DOC.contains(&format!("`{name}`")) {
            out.push(Violation::new(
                Code::UndocumentedTaxonomy,
                format!("span name `{name}` has no row in docs/OBSERVABILITY.md"),
            ));
        }
    }
    for cat in SPAN_CATS {
        if !OBSERVABILITY_DOC.contains(&format!("`{cat}`")) {
            out.push(Violation::new(
                Code::UndocumentedTaxonomy,
                format!("span category `{cat}` missing from docs/OBSERVABILITY.md"),
            ));
        }
    }
}

/// `T001`/`T002`: every emitted span must use a documented name and
/// category. Each offending name/category is reported once.
pub fn check_spans(spans: &[Span], out: &mut Vec<Violation>) {
    let mut bad_names: BTreeSet<&'static str> = BTreeSet::new();
    let mut bad_cats: BTreeSet<&'static str> = BTreeSet::new();
    for s in spans {
        if !SPAN_NAMES.contains(&s.name) && bad_names.insert(s.name) {
            out.push(Violation::new(
                Code::UnknownSpanName,
                format!("emitted span name \"{}\" is outside the taxonomy", s.name),
            ));
        }
        if !SPAN_CATS.contains(&s.cat) && bad_cats.insert(s.cat) {
            out.push(Violation::new(
                Code::UnknownSpanCat,
                format!("emitted span category \"{}\" is outside the taxonomy", s.cat),
            ));
        }
    }
}

/// Harvest live spans from traced micro-runs of every engine mode (one
/// training epoch + one batched inference on a tiny 2-rank RadixNet,
/// plus a 2-group replica training step for the `allreduce.*` spans) and
/// run [`check_spans`] over everything the engines emitted. This is the
/// CI gate "an engine emits a span name missing from the documented
/// taxonomy": a new span site fails here until the doc table grows its
/// row. Spawns rank threads, so it is CLI/test-only — never called from
/// the static [`super::check_plan`] path.
pub fn check_live_spans(out: &mut Vec<Violation>) {
    use crate::coordinator::{infer_with_plan_mode_traced, run_with_plan_mode_traced, ExecMode};
    use crate::obs::TraceMode;
    use crate::partition::{random::random_partition, CommPlan};
    use crate::radixnet::{generate, RadixNetConfig};

    let cfg = RadixNetConfig::graph_challenge(64, 3).expect("built-in GC size");
    let net = generate(&cfg);
    let part = random_partition(&net.layers, 2, 9);
    let plan = CommPlan::build(&net.layers, &part);
    let n0 = net.input_dim();
    let inputs: Vec<Vec<f32>> = (0..2)
        .map(|s| {
            (0..n0)
                .map(|i| if (i + s) % 3 == 0 { 1.0 } else { 0.25 })
                .collect()
        })
        .collect();
    let nl = net.layers.last().expect("net has layers").nrows;
    let targets: Vec<Vec<f32>> = (0..2).map(|_| vec![0.5f32; nl]).collect();
    let b = 2usize;
    let x0: Vec<f32> = (0..n0 * b).map(|i| (i % 5) as f32 * 0.2).collect();

    for mode in [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::Pipelined { chunk_acts: 8 },
    ] {
        let trace = TraceMode::with_capacity(8192);
        let (_run, tracers) =
            run_with_plan_mode_traced(&net, &part, &plan, &inputs, &targets, 0.05, 1, mode, trace);
        for t in &tracers {
            check_spans(&t.spans(), out);
        }
        let trace = TraceMode::with_capacity(8192);
        let (_y, _stats, tracers) =
            infer_with_plan_mode_traced(&net, &part, &plan, &x0, b, mode, trace);
        for t in &tracers {
            check_spans(&t.spans(), out);
        }
    }

    // replica training: the lossy ring all-reduce emits the alr-category
    // fold/scatter/gather spans on top of the engine's own
    let rcfg = crate::replica::ReplicaConfig {
        groups: 2,
        batch: 1,
        eta: 0.05,
        epochs: 1,
        mode: ExecMode::Overlap,
        codec: crate::comm::Codec::int8(),
        scope: crate::runtime::parallel::FaultScope::Off,
    };
    let trace = TraceMode::with_capacity(8192);
    let (_run, tracers) =
        crate::replica::train_replicas_traced(&net, &part, &plan, &inputs, &targets, &rcfg, trace);
    for grp in &tracers {
        for t in grp {
            check_spans(&t.spans(), out);
        }
    }
}
