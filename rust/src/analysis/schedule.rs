//! Schedule matching (`S...` diagnostics): symbolic enumeration of the
//! send/recv tag multiset of each engine mode, proved to be a perfect
//! bipartite matching.
//!
//! The simulated fabric ([`crate::comm::fabric`]) buffers sends and
//! matches receives purely on the tag
//! `(layer, phase, peer, transfer, chunk)` — timing never changes which
//! message satisfies which wait. So if every tag is sent exactly once
//! and awaited exactly once, no rank can block forever: the schedule is
//! deadlock-free **by construction**, independent of the interleaving.
//! This also covers the pipelined post-before-interior ordering — layer
//! `k`'s step posts layer-`k+1`-tagged chunks early, but tag-wise those
//! belong to layer `k+1`'s schedule, which is exactly how they are
//! enumerated here.

use super::{Code, Violation};
use crate::comm::Phase;
use crate::coordinator::ExecMode;
use crate::partition::CommPlan;
use std::collections::BTreeMap;

/// One symbolic message of the schedule: everything the fabric matches
/// on, plus the receiving side, so orphans and starvation are decidable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tag {
    pub layer: u32,
    pub phase: Phase,
    pub from: u32,
    pub to: u32,
    pub tid: u32,
    pub chunk: u32,
}

/// Total order key (Phase itself is not `Ord`).
type Key = (u32, u8, u32, u32, u32, u32);

fn key(t: &Tag) -> Key {
    let ph = match t.phase {
        Phase::Forward => 0u8,
        Phase::Backward => 1,
    };
    (t.layer, ph, t.from, t.to, t.tid, t.chunk)
}

fn tag_str(k: &Key) -> String {
    let ph = if k.1 == 0 { "fwd" } else { "bwd" };
    format!("L{} {ph} {}→{} transfer {} chunk {}", k.0, k.2, k.3, k.4, k.5)
}

/// The chunk granularity a mode posts transfers at (0 = whole).
fn chunking(mode: ExecMode) -> usize {
    match mode {
        ExecMode::Pipelined { chunk_acts } => chunk_acts,
        _ => 0,
    }
}

/// Every send each rank posts under `mode`, derived from the per-rank
/// `send_of` views (forward) and `recv_of` views (backward mirror: the
/// forward receiver of a transfer sends its partial gradient back), so a
/// corrupted view changes the enumerated schedule exactly as it would
/// change the engine's behavior.
pub fn sends_of(plan: &CommPlan, mode: ExecMode, train: bool) -> Vec<Tag> {
    let ca = chunking(mode);
    let mut tags = Vec::new();
    for (k, lp) in plan.layers.iter().enumerate() {
        for (r, list) in lp.send_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    continue; // S007, reported by check_views
                };
                for (c, _) in t.chunks(ca) {
                    tags.push(Tag {
                        layer: k as u32,
                        phase: Phase::Forward,
                        from: r as u32,
                        to: t.to,
                        tid,
                        chunk: c,
                    });
                }
            }
        }
        if !train {
            continue;
        }
        for (r, list) in lp.recv_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    continue;
                };
                for (c, _) in t.chunks(ca) {
                    tags.push(Tag {
                        layer: k as u32,
                        phase: Phase::Backward,
                        from: r as u32,
                        to: t.from,
                        tid,
                        chunk: c,
                    });
                }
            }
        }
    }
    tags
}

/// Every receive each rank waits for under `mode`: the mirror of
/// [`sends_of`], derived from the `recv_of` views forward and the
/// `send_of` views backward (a rank that sent activations waits for the
/// matching partial gradients).
pub fn recvs_of(plan: &CommPlan, mode: ExecMode, train: bool) -> Vec<Tag> {
    let ca = chunking(mode);
    let mut tags = Vec::new();
    for (k, lp) in plan.layers.iter().enumerate() {
        for (r, list) in lp.recv_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    continue;
                };
                for (c, _) in t.chunks(ca) {
                    tags.push(Tag {
                        layer: k as u32,
                        phase: Phase::Forward,
                        from: t.from,
                        to: r as u32,
                        tid,
                        chunk: c,
                    });
                }
            }
        }
        if !train {
            continue;
        }
        for (r, list) in lp.send_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    continue;
                };
                for (c, _) in t.chunks(ca) {
                    tags.push(Tag {
                        layer: k as u32,
                        phase: Phase::Backward,
                        from: t.to,
                        to: r as u32,
                        tid,
                        chunk: c,
                    });
                }
            }
        }
    }
    tags
}

/// Prove `sends` and `recvs` form a perfect bipartite matching:
/// `S001` orphan send, `S002` starved receive (a wait nothing satisfies
/// — deadlock), `S003`/`S004` duplicate tags (the cross-generation
/// collision class: two in-flight messages the fabric cannot tell
/// apart).
pub fn match_schedule(sends: &[Tag], recvs: &[Tag], out: &mut Vec<Violation>) {
    let mut counts: BTreeMap<Key, (u32, u32)> = BTreeMap::new();
    for t in sends {
        counts.entry(key(t)).or_insert((0, 0)).0 += 1;
    }
    for t in recvs {
        counts.entry(key(t)).or_insert((0, 0)).1 += 1;
    }
    for (k, &(s, r)) in &counts {
        let layer = k.0 as usize;
        if s > 1 {
            out.push(
                Violation::new(
                    Code::DuplicateSendTag,
                    format!("{} posted {s} times", tag_str(k)),
                )
                .at(layer)
                .on(k.2),
            );
        }
        if r > 1 {
            out.push(
                Violation::new(
                    Code::DuplicateRecvTag,
                    format!("{} awaited {r} times", tag_str(k)),
                )
                .at(layer)
                .on(k.3),
            );
        }
        if s > 0 && r == 0 {
            out.push(
                Violation::new(
                    Code::OrphanSend,
                    format!("{} has no matching receive", tag_str(k)),
                )
                .at(layer)
                .on(k.2),
            );
        }
        if r > 0 && s == 0 {
            out.push(
                Violation::new(
                    Code::StarvedReceive,
                    format!("{} is never sent — rank {} would block forever", tag_str(k), k.3),
                )
                .at(layer)
                .on(k.3),
            );
        }
    }
}

/// View/transfer consistency per layer (`S007`, plus `S005`
/// self-messages): every transfer id appears in exactly one rank's send
/// view and exactly one rank's recv view, and those ranks are the
/// transfer's own endpoints.
pub fn check_views(plan: &CommPlan, out: &mut Vec<Violation>) {
    for (k, lp) in plan.layers.iter().enumerate() {
        let nt = lp.transfers.len();
        for (tid, t) in lp.transfers.iter().enumerate() {
            if t.from == t.to {
                out.push(
                    Violation::new(
                        Code::SelfMessage,
                        format!("transfer {tid} sends rank {} to itself", t.from),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
        }
        let mut sseen = vec![0u32; nt];
        let mut rseen = vec![0u32; nt];
        for (r, list) in lp.send_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    out.push(
                        Violation::new(
                            Code::ViewMismatch,
                            format!("send view of rank {r} references unknown transfer {tid}"),
                        )
                        .at(k)
                        .on(r as u32),
                    );
                    continue;
                };
                sseen[tid as usize] += 1;
                if t.from as usize != r {
                    out.push(
                        Violation::new(
                            Code::ViewMismatch,
                            format!(
                                "transfer {tid} ({}→{}) listed in the send view of rank {r}",
                                t.from, t.to
                            ),
                        )
                        .at(k)
                        .on(r as u32),
                    );
                }
            }
        }
        for (r, list) in lp.recv_of.iter().enumerate() {
            for &tid in list {
                let Some(t) = lp.transfers.get(tid as usize) else {
                    out.push(
                        Violation::new(
                            Code::ViewMismatch,
                            format!("recv view of rank {r} references unknown transfer {tid}"),
                        )
                        .at(k)
                        .on(r as u32),
                    );
                    continue;
                };
                rseen[tid as usize] += 1;
                if t.to as usize != r {
                    out.push(
                        Violation::new(
                            Code::ViewMismatch,
                            format!(
                                "transfer {tid} ({}→{}) listed in the recv view of rank {r}",
                                t.from, t.to
                            ),
                        )
                        .at(k)
                        .on(r as u32),
                    );
                }
            }
        }
        for tid in 0..nt {
            if sseen[tid] != 1 {
                out.push(
                    Violation::new(
                        Code::ViewMismatch,
                        format!(
                            "transfer {tid} appears {} times across send views (want 1)",
                            sseen[tid]
                        ),
                    )
                    .at(k),
                );
            }
            if rseen[tid] != 1 {
                out.push(
                    Violation::new(
                        Code::ViewMismatch,
                        format!(
                            "transfer {tid} appears {} times across recv views (want 1)",
                            rseen[tid]
                        ),
                    )
                    .at(k),
                );
            }
        }
    }
}

/// Chunk-schedule integrity (`S006`): under the mode's granularity,
/// every transfer's chunk ids are dense from 0, each chunk is non-empty
/// and within the size bound, and the chunks reassemble to exactly the
/// transfer's index list — the contract both endpoints derive their
/// sub-transfer schedules from.
pub fn check_chunk_schedules(plan: &CommPlan, mode: ExecMode, out: &mut Vec<Violation>) {
    let ca = chunking(mode);
    for (k, lp) in plan.layers.iter().enumerate() {
        for (tid, t) in lp.transfers.iter().enumerate() {
            let mut next = 0u32;
            let mut glued: Vec<u32> = Vec::with_capacity(t.indices.len());
            let mut broken = false;
            for (c, idx) in t.chunks(ca) {
                if c != next {
                    out.push(
                        Violation::new(
                            Code::ChunkScheduleBroken,
                            format!("transfer {tid}: chunk ids jump {next} → {c}"),
                        )
                        .at(k)
                        .on(t.from),
                    );
                    broken = true;
                    break;
                }
                next = c + 1;
                if idx.is_empty() || (ca > 0 && idx.len() > ca) {
                    out.push(
                        Violation::new(
                            Code::ChunkScheduleBroken,
                            format!(
                                "transfer {tid} chunk {c} carries {} indices (bound {ca})",
                                idx.len()
                            ),
                        )
                        .at(k)
                        .on(t.from),
                    );
                    broken = true;
                }
                glued.extend_from_slice(idx);
            }
            if broken {
                continue;
            }
            let want = if t.indices.is_empty() {
                0
            } else if ca == 0 {
                1
            } else {
                t.indices.len().div_ceil(ca)
            };
            if next as usize != want {
                out.push(
                    Violation::new(
                        Code::ChunkScheduleBroken,
                        format!("transfer {tid}: {next} chunks, schedule requires {want}"),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
            if glued != t.indices {
                out.push(
                    Violation::new(
                        Code::ChunkScheduleBroken,
                        format!("transfer {tid}: chunks do not reassemble the index list"),
                    )
                    .at(k)
                    .on(t.from),
                );
            }
        }
    }
}
