//! Static plan verification — the `spdnn check` pass.
//!
//! The row-wise partitioning of the paper (Section 4) turns every SGD
//! step into a P×P message schedule, and the engines execute that
//! schedule chunked ([`crate::coordinator::ExecMode::Pipelined`]),
//! codec-compressed ([`crate::comm::Codec`]) and permuted boundary-first
//! ([`crate::sparse::regroup_rows`]). This module proves a
//! (structure, partition, plan) triple safe **without spawning a single
//! rank thread**, so a bad plan is rejected before any engine can
//! deadlock on it:
//!
//! 1. **Partition soundness** ([`partition`]): every activation owned
//!    exactly once per layer, transfer indices in-bounds and owned by
//!    their sender, every needed column owned-or-delivered exactly once,
//!    and the pipelined row regroup a true permutation with a consistent
//!    boundary prefix.
//! 2. **Schedule matching** ([`schedule`]): the full send/recv tag
//!    multiset of each engine mode is enumerated symbolically (per
//!    transfer, per chunk, forward and backward) and proved a perfect
//!    bipartite matching — no orphan sends, no starved receives, no tag
//!    collisions. Because the simulated fabric buffers sends and matches
//!    receives purely on tags, a perfect matching is deadlock-freedom by
//!    construction.
//! 3. **Accounting cross-checks** ([`accounting`], [`taxonomy`]): the
//!    plan's static `wire_bytes` equal an independent recomputation from
//!    the documented wire format and the replay/netmodel charge basis,
//!    codecs honor their `encode_into`/`wire_words` contract, and every
//!    trace-span name an engine emits is in the documented taxonomy of
//!    `docs/OBSERVABILITY.md`.
//! 4. **Replica ring schedule** ([`replica`]): the cross-group gradient
//!    all-reduce of [`crate::replica`] is re-executed hop-by-hop,
//!    single-threaded, from the same topology functions the live engine
//!    runs — perfect send/recv tag matching at every hop, segment
//!    partition coverage, full delivery with hold-before-forward,
//!    encode-once allgather, EF-residual conservation, and wire-word
//!    accounting against [`crate::replica::predicted_wire_words`].
//!
//! Violations carry stable diagnostic codes (`P...` partition, `S...`
//! schedule, `A...` accounting, `T...` taxonomy, `R...` replica ring —
//! see [`Code`] and `docs/ANALYSIS.md`). The CLI entry point is `spdnn
//! check`; debug builds additionally run [`check_plan`] inside
//! [`crate::coordinator::RankState::build`] so every test that builds a
//! rank state verifies its plan for free.

pub mod accounting;
pub mod partition;
pub mod replica;
pub mod schedule;
pub mod taxonomy;

pub use accounting::check_state_codecs;
pub use replica::{check_replica, check_replica_matrix};

use crate::coordinator::ExecMode;
use crate::partition::{CommPlan, DnnPartition, ServingPlan};
use crate::sparse::Csr;

/// Stable diagnostic code of one violation class. The string form
/// (`P020`, `S001`, ...) is the contract tests and tooling match on;
/// the variant name is for Rust callers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// P001 — structure / partition / plan shapes disagree.
    ShapeMismatch,
    /// P002 — a layer's row assignment has the wrong length.
    RowCountMismatch,
    /// P003 — a rank id is outside `0..nparts`.
    RankOutOfRange,
    /// P004 — the input assignment has the wrong length.
    InputMismatch,
    /// P010 — a pipelined row regroup's perm/inv are not mutual inverses.
    RegroupNotInverse,
    /// P011 — the boundary prefix bookkeeping is inconsistent.
    BoundaryPrefixBroken,
    /// P012 — an outbound chunk's rows fall outside its ready prefix.
    ChunkOutsideReady,
    /// P020 — a transfer carries an activation its sender does not own.
    ForeignSend,
    /// P021 — one activation reaches one rank twice (owned + delivered,
    /// or delivered by two transfers).
    DoubleDelivery,
    /// P022 — a transfer index is out of the layer's column range.
    IndexOutOfBounds,
    /// P023 — a transfer's index list is not strictly ascending.
    UnsortedTransfer,
    /// P024 — a transfer carries no indices.
    EmptyTransfer,
    /// P025 — a rank needs a column it neither owns nor receives.
    UncoveredColumn,
    /// S001 — a posted send no receiver ever waits for.
    OrphanSend,
    /// S002 — a receive no sender ever posts (deadlock).
    StarvedReceive,
    /// S003 — two sends share one tag (cross-generation collision).
    DuplicateSendTag,
    /// S004 — two receives share one tag.
    DuplicateRecvTag,
    /// S005 — a transfer from a rank to itself.
    SelfMessage,
    /// S006 — a transfer's chunk schedule is broken (ids not dense,
    /// oversized chunks, or reassembly mismatch).
    ChunkScheduleBroken,
    /// S007 — send/recv views disagree with the transfer list.
    ViewMismatch,
    /// A001 — static chunked wire bytes differ from the wire format.
    WireBytesMismatch,
    /// A002 — the replay/netmodel charge basis differs from the plan.
    ReplayChargeMismatch,
    /// A003 — a codec violates its own encode/size contract.
    CodecContractBroken,
    /// A004 — a rank state's codec table disagrees with the plan.
    StateCodecMismatch,
    /// T001 — an engine emitted a span name outside the taxonomy.
    UnknownSpanName,
    /// T002 — an engine emitted a span category outside the taxonomy.
    UnknownSpanCat,
    /// T003 — a taxonomy entry is missing from `docs/OBSERVABILITY.md`.
    UndocumentedTaxonomy,
    /// R001 — a replica-ring hop's send/recv tags fail to match.
    RingTagMismatch,
    /// R002 — the gradient segments do not partition `[0, m)`.
    SegPartitionBroken,
    /// R003 — the ring all-reduce fails to deliver or absorb a segment.
    RingDeliveryIncomplete,
    /// R004 — live and predicted ring wire accounting disagree.
    RingWireMismatch,
    /// R005 — the EF residual contract is broken (nonzero residual under
    /// a lossless codec, replica divergence, or unconserved error).
    ResidualContractBroken,
    /// R006 — an allgather segment is encoded more or fewer than once.
    GatherEncodeMiscount,
}

impl Code {
    /// The stable wire/report spelling (`P020`, `S001`, ...).
    pub fn as_str(self) -> &'static str {
        match self {
            Code::ShapeMismatch => "P001",
            Code::RowCountMismatch => "P002",
            Code::RankOutOfRange => "P003",
            Code::InputMismatch => "P004",
            Code::RegroupNotInverse => "P010",
            Code::BoundaryPrefixBroken => "P011",
            Code::ChunkOutsideReady => "P012",
            Code::ForeignSend => "P020",
            Code::DoubleDelivery => "P021",
            Code::IndexOutOfBounds => "P022",
            Code::UnsortedTransfer => "P023",
            Code::EmptyTransfer => "P024",
            Code::UncoveredColumn => "P025",
            Code::OrphanSend => "S001",
            Code::StarvedReceive => "S002",
            Code::DuplicateSendTag => "S003",
            Code::DuplicateRecvTag => "S004",
            Code::SelfMessage => "S005",
            Code::ChunkScheduleBroken => "S006",
            Code::ViewMismatch => "S007",
            Code::WireBytesMismatch => "A001",
            Code::ReplayChargeMismatch => "A002",
            Code::CodecContractBroken => "A003",
            Code::StateCodecMismatch => "A004",
            Code::UnknownSpanName => "T001",
            Code::UnknownSpanCat => "T002",
            Code::UndocumentedTaxonomy => "T003",
            Code::RingTagMismatch => "R001",
            Code::SegPartitionBroken => "R002",
            Code::RingDeliveryIncomplete => "R003",
            Code::RingWireMismatch => "R004",
            Code::ResidualContractBroken => "R005",
            Code::GatherEncodeMiscount => "R006",
        }
    }

    /// One-line human description of the violation class.
    pub fn describe(self) -> &'static str {
        match self {
            Code::ShapeMismatch => "structure/partition/plan shape mismatch",
            Code::RowCountMismatch => "layer row-count mismatch",
            Code::RankOutOfRange => "rank id out of range",
            Code::InputMismatch => "input assignment length mismatch",
            Code::RegroupNotInverse => "regroup perm/inv not mutual inverses",
            Code::BoundaryPrefixBroken => "boundary prefix inconsistent",
            Code::ChunkOutsideReady => "chunk rows outside ready prefix",
            Code::ForeignSend => "transfer sends an unowned activation",
            Code::DoubleDelivery => "activation reaches a rank twice",
            Code::IndexOutOfBounds => "transfer index out of bounds",
            Code::UnsortedTransfer => "transfer indices not strictly ascending",
            Code::EmptyTransfer => "empty transfer",
            Code::UncoveredColumn => "needed column neither owned nor received",
            Code::OrphanSend => "send with no matching receive",
            Code::StarvedReceive => "receive with no matching send (deadlock)",
            Code::DuplicateSendTag => "duplicate send tag",
            Code::DuplicateRecvTag => "duplicate receive tag",
            Code::SelfMessage => "rank messages itself",
            Code::ChunkScheduleBroken => "chunk schedule integrity violation",
            Code::ViewMismatch => "send/recv view inconsistent with transfers",
            Code::WireBytesMismatch => "static wire bytes disagree with wire format",
            Code::ReplayChargeMismatch => "replay charge basis disagrees with plan",
            Code::CodecContractBroken => "codec encode/size contract broken",
            Code::StateCodecMismatch => "rank-state codecs disagree with plan",
            Code::UnknownSpanName => "span name outside documented taxonomy",
            Code::UnknownSpanCat => "span category outside documented taxonomy",
            Code::UndocumentedTaxonomy => "taxonomy entry missing from docs",
            Code::RingTagMismatch => "ring hop send/recv tags do not match",
            Code::SegPartitionBroken => "segments do not partition the gradient",
            Code::RingDeliveryIncomplete => "ring all-reduce delivery incomplete",
            Code::RingWireMismatch => "ring wire accounting disagrees with prediction",
            Code::ResidualContractBroken => "EF residual contract broken",
            Code::GatherEncodeMiscount => "allgather segment not encoded exactly once",
        }
    }
}

/// One concrete violation: a diagnostic [`Code`] plus where (layer/rank)
/// and a human-readable detail line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub code: Code,
    pub layer: Option<usize>,
    pub rank: Option<u32>,
    pub detail: String,
}

impl Violation {
    /// A violation with no layer/rank attribution yet.
    pub fn new(code: Code, detail: impl Into<String>) -> Self {
        Violation {
            code,
            layer: None,
            rank: None,
            detail: detail.into(),
        }
    }

    /// Attribute the violation to a layer.
    pub fn at(mut self, layer: usize) -> Self {
        self.layer = Some(layer);
        self
    }

    /// Attribute the violation to a rank.
    pub fn on(mut self, rank: u32) -> Self {
        self.rank = Some(rank);
        self
    }
}

/// Result of one [`check_plan`] run: schedule statistics plus every
/// violation found. An empty violation list is the safety proof.
#[derive(Debug, Clone)]
pub struct CheckReport {
    /// Free-form label of the checked configuration (mode, codecs, net).
    pub config: String,
    pub layers: usize,
    pub nparts: usize,
    pub batch: usize,
    /// Whole transfers in the forward plan.
    pub transfers: u64,
    /// Messages under the mode's chunk schedule, forward + backward.
    pub messages: u64,
    /// Forward bytes-on-wire under the mode's chunk schedule.
    pub wire_bytes: u64,
    pub violations: Vec<Violation>,
}

impl CheckReport {
    /// True when the plan passed every check.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable report: one status line plus one line per violation.
    pub fn render(&self) -> String {
        let status = if self.ok() { "ok  " } else { "FAIL" };
        let mut s = format!(
            "[{status}] {} — {} layers, {} ranks, batch {}, {} transfers, \
             {} msgs, {} wire bytes\n",
            self.config,
            self.layers,
            self.nparts,
            self.batch,
            self.transfers,
            self.messages,
            self.wire_bytes
        );
        for v in &self.violations {
            s.push_str("       ");
            s.push_str(v.code.as_str());
            if let Some(k) = v.layer {
                s.push_str(&format!(" L{k}"));
            }
            if let Some(r) = v.rank {
                s.push_str(&format!(" r{r}"));
            }
            s.push_str(": ");
            s.push_str(&v.detail);
            s.push('\n');
        }
        s
    }

    /// The report as one JSON object (no trailing newline).
    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"config\":\"{}\",\"ok\":{},\"layers\":{},\"nparts\":{},\
             \"batch\":{},\"transfers\":{},\"messages\":{},\"wire_bytes\":{},\
             \"violations\":[",
            json_escape(&self.config),
            self.ok(),
            self.layers,
            self.nparts,
            self.batch,
            self.transfers,
            self.messages,
            self.wire_bytes
        );
        for (i, v) in self.violations.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"code\":\"{}\",\"layer\":{},\"rank\":{},\"detail\":\"{}\"}}",
                v.code.as_str(),
                v.layer.map_or("null".to_string(), |k| k.to_string()),
                v.rank.map_or("null".to_string(), |r| r.to_string()),
                json_escape(&v.detail)
            ));
        }
        s.push_str("]}");
        s
    }
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Display label of a mode including the pipelined chunk size (the plain
/// [`ExecMode::label`] drops it).
pub fn mode_label(mode: ExecMode) -> String {
    match mode {
        ExecMode::Pipelined { chunk_acts } => format!("pipelined(chunk={chunk_acts})"),
        m => m.label().to_string(),
    }
}

/// Statically verify one (structure, partition, plan) triple for one
/// engine mode and batch width. Runs every partition, schedule, and
/// accounting check; shape violations (`P001`–`P004`) short-circuit the
/// rest because the deeper checks index by the declared shapes.
pub fn check_plan(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    mode: ExecMode,
    batch: usize,
) -> CheckReport {
    let mut violations = Vec::new();
    if partition::check_shapes(structure, part, plan, &mut violations) {
        partition::check_ranks(part, &mut violations);
        partition::check_transfers(structure, part, plan, &mut violations);
        partition::check_coverage(structure, part, plan, &mut violations);
        if let ExecMode::Pipelined { chunk_acts } = mode {
            partition::check_regroup(part, plan, chunk_acts, &mut violations);
        }
        schedule::check_views(plan, &mut violations);
        schedule::check_chunk_schedules(plan, mode, &mut violations);
        let sends = schedule::sends_of(plan, mode, true);
        let recvs = schedule::recvs_of(plan, mode, true);
        schedule::match_schedule(&sends, &recvs, &mut violations);
        accounting::check_wire_accounting(plan, mode, batch, &mut violations);
        accounting::check_codec_contract(plan, mode, batch, &mut violations);
    }
    let chunk_acts = match mode {
        ExecMode::Pipelined { chunk_acts } => chunk_acts,
        _ => 0,
    };
    CheckReport {
        config: format!("{} P={} b={batch}", mode_label(mode), part.nparts),
        layers: structure.len(),
        nparts: part.nparts,
        batch,
        transfers: plan.fwd_messages(),
        messages: plan
            .layers
            .iter()
            .map(|l| l.message_count_chunked(chunk_acts))
            .sum::<u64>()
            * 2,
        wire_bytes: plan.fwd_wire_bytes(batch, chunk_acts),
        violations,
    }
}

/// [`check_plan`] over a [`ServingPlan`] bundle (partition + plan as one
/// unit, the form the serving pool consumes).
pub fn check_serving_plan(
    structure: &[Csr],
    sp: &ServingPlan,
    mode: ExecMode,
    batch: usize,
) -> CheckReport {
    check_plan(structure, &sp.part, &sp.plan, mode, batch)
}

/// Run [`check_plan`] over the built-in configuration matrix: two
/// RadixNet/Graph Challenge nets × {random, contiguous} partitions at
/// 1–8 ranks plus a zero-row-rank and a hypergraph partition × all three
/// engines (pipelined additionally at tiny and unchunked sizes) × all
/// three codecs (one pair mixed). This is the matrix `spdnn check` and
/// CI run; every report must come back [`CheckReport::ok`].
pub fn check_builtin_matrix(seed: u64) -> Vec<CheckReport> {
    use crate::comm::Codec;
    use crate::partition::phases::{hypergraph_partition, PhaseConfig};
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    let modes = [
        ExecMode::Blocking,
        ExecMode::Overlap,
        ExecMode::pipelined(),
        ExecMode::Pipelined { chunk_acts: 3 },
        ExecMode::Pipelined { chunk_acts: 0 },
    ];
    let codecs = [
        (Codec::F32, Codec::F32),
        (Codec::F16, Codec::F16),
        (Codec::int8(), Codec::F16),
    ];
    let mut reports = Vec::new();
    for (net_name, neurons, depth, with_hypergraph) in
        [("gc64x4", 64usize, 4usize, true), ("gc256x5", 256, 5, false)]
    {
        let cfg = RadixNetConfig::graph_challenge(neurons, depth).expect("built-in GC size");
        let structure = generate_structure(&cfg);
        let mut parts: Vec<(String, DnnPartition)> = Vec::new();
        for p in [1usize, 2, 3, 8] {
            let rand = random_partition(&structure, p, seed + p as u64);
            parts.push((format!("random P={p}"), rand));
            let contig = crate::partition::contiguous_partition(&structure, p);
            parts.push((format!("contig P={p}"), contig));
        }
        // Zero-row rank: every row of rank 3 handed to rank 0. Rank 3
        // stays in the rank set but owns nothing in any layer — the
        // degenerate case the schedule matcher must still close over.
        let mut zero = random_partition(&structure, 4, seed ^ 0x5EED);
        for assign in zero
            .layer_parts
            .iter_mut()
            .chain(std::iter::once(&mut zero.input_parts))
        {
            for p in assign.iter_mut() {
                if *p == 3 {
                    *p = 0;
                }
            }
        }
        parts.push(("zero-row P=4".to_string(), zero));
        if with_hypergraph {
            let hyper = hypergraph_partition(&structure, &PhaseConfig::new(4));
            parts.push(("hypergraph P=4".to_string(), hyper));
        }
        for (pname, part) in &parts {
            let base = CommPlan::build(&structure, part);
            for &(cf, cb) in &codecs {
                let mut plan = base.clone();
                plan.set_codec(cf, cb);
                for &mode in &modes {
                    let mut report = check_plan(&structure, part, &plan, mode, 4);
                    report.config = format!(
                        "{net_name} {pname} {} {}/{}",
                        mode_label(mode),
                        cf.label(),
                        cb.label()
                    );
                    reports.push(report);
                }
            }
        }
    }
    reports
}
