//! Synthetic MNIST-like dataset.
//!
//! The paper feeds MNIST digits, scaled to 32²…256² pixels, thresholded to
//! 0-1 vectors, into the RadiX-Net input layers (Section 6.1). This host
//! has no network access, so we generate a *synthetic* MNIST: seeded
//! stroke-template digits rasterized at 28×28, bilinearly scaled,
//! thresholded, flattened — the identical shape/sparsity pipeline
//! (substitution documented in DESIGN.md §2). The SGD cost and the
//! communication pattern depend only on input shape/sparsity, not pixel
//! semantics, and the e2e example still shows a genuinely falling loss.

pub mod digits;

use crate::util::Rng;

/// One dataset sample: a 0/1 flattened image and its class label.
#[derive(Debug, Clone)]
pub struct Sample {
    pub pixels: Vec<f32>,
    pub label: usize,
}

/// Dataset of binary images of dimension `dim = side*side`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub side: usize,
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn dim(&self) -> usize {
        self.side * self.side
    }

    /// One-hot target vector of length `out_dim` (class in the first 10).
    pub fn target(&self, i: usize, out_dim: usize) -> Vec<f32> {
        let mut y = vec![0f32; out_dim];
        let l = self.samples[i].label;
        if l < out_dim {
            y[l] = 1.0;
        }
        y
    }

    /// Pack samples `[lo, hi)` row-major `[dim x b]` for batched inference.
    pub fn pack_batch(&self, lo: usize, hi: usize) -> (Vec<f32>, usize) {
        let b = hi - lo;
        let d = self.dim();
        let mut x = vec![0f32; d * b];
        for (j, s) in self.samples[lo..hi].iter().enumerate() {
            for i in 0..d {
                x[i * b + j] = s.pixels[i];
            }
        }
        (x, b)
    }
}

/// Generate a synthetic MNIST-like dataset at `side`×`side` resolution.
///
/// Supported sides mirror the paper's scaling: 32, 64, 128, 256 (and any
/// other positive value for tests). `count` samples cycle over the 10
/// digit classes with per-sample jitter.
pub fn synthetic_mnist(side: usize, count: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut samples = Vec::with_capacity(count);
    for i in 0..count {
        let label = i % 10;
        let img28 = digits::render_digit(label, &mut rng);
        let scaled = bilinear_scale(&img28, 28, side);
        let pixels = threshold(&scaled, 0.35);
        samples.push(Sample { pixels, label });
    }
    Dataset { side, samples }
}

/// Bilinear image scaling from `src_side`² to `dst_side`².
pub fn bilinear_scale(src: &[f32], src_side: usize, dst_side: usize) -> Vec<f32> {
    assert_eq!(src.len(), src_side * src_side);
    if src_side == dst_side {
        return src.to_vec();
    }
    let mut out = vec![0f32; dst_side * dst_side];
    let scale = src_side as f32 / dst_side as f32;
    for y in 0..dst_side {
        for x in 0..dst_side {
            let sx = (x as f32 + 0.5) * scale - 0.5;
            let sy = (y as f32 + 0.5) * scale - 0.5;
            let x0 = sx.floor().max(0.0) as usize;
            let y0 = sy.floor().max(0.0) as usize;
            let x1 = (x0 + 1).min(src_side - 1);
            let y1 = (y0 + 1).min(src_side - 1);
            let fx = (sx - x0 as f32).clamp(0.0, 1.0);
            let fy = (sy - y0 as f32).clamp(0.0, 1.0);
            let v00 = src[y0 * src_side + x0];
            let v01 = src[y0 * src_side + x1];
            let v10 = src[y1 * src_side + x0];
            let v11 = src[y1 * src_side + x1];
            out[y * dst_side + x] = v00 * (1.0 - fx) * (1.0 - fy)
                + v01 * fx * (1.0 - fy)
                + v10 * (1.0 - fx) * fy
                + v11 * fx * fy;
        }
    }
    out
}

/// Threshold to 0/1 (the paper's binarization step).
pub fn threshold(img: &[f32], t: f32) -> Vec<f32> {
    img.iter().map(|&v| if v > t { 1.0 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes() {
        let d = synthetic_mnist(32, 20, 1);
        assert_eq!(d.samples.len(), 20);
        assert_eq!(d.dim(), 1024);
        for s in &d.samples {
            assert_eq!(s.pixels.len(), 1024);
            assert!(s.pixels.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn labels_cycle() {
        let d = synthetic_mnist(32, 25, 2);
        assert_eq!(d.samples[0].label, 0);
        assert_eq!(d.samples[13].label, 3);
    }

    #[test]
    fn images_nonempty_but_sparse() {
        let d = synthetic_mnist(64, 30, 3);
        for (i, s) in d.samples.iter().enumerate() {
            let on: f32 = s.pixels.iter().sum();
            let frac = on / s.pixels.len() as f32;
            assert!(on > 0.0, "sample {i} is blank");
            assert!(frac < 0.5, "sample {i} too dense: {frac}");
        }
    }

    #[test]
    fn bilinear_identity_when_same_side() {
        let img = vec![0.1, 0.2, 0.3, 0.4];
        assert_eq!(bilinear_scale(&img, 2, 2), img);
    }

    #[test]
    fn bilinear_preserves_constant_images() {
        let img = vec![0.7; 28 * 28];
        let up = bilinear_scale(&img, 28, 64);
        assert!(up.iter().all(|&v| (v - 0.7).abs() < 1e-5));
        let down = bilinear_scale(&img, 28, 16);
        assert!(down.iter().all(|&v| (v - 0.7).abs() < 1e-5));
    }

    #[test]
    fn target_one_hot() {
        let d = synthetic_mnist(32, 5, 4);
        let y = d.target(3, 1024);
        assert_eq!(y.iter().filter(|&&v| v == 1.0).count(), 1);
        assert_eq!(y[3], 1.0);
    }

    #[test]
    fn pack_batch_layout() {
        let d = synthetic_mnist(32, 4, 5);
        let (x, b) = d.pack_batch(1, 3);
        assert_eq!(b, 2);
        assert_eq!(x.len(), 1024 * 2);
        // column j of the packed batch equals sample j's pixels
        for i in 0..1024 {
            assert_eq!(x[i * 2], d.samples[1].pixels[i]);
            assert_eq!(x[i * 2 + 1], d.samples[2].pixels[i]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = synthetic_mnist(32, 10, 7);
        let b = synthetic_mnist(32, 10, 7);
        for (sa, sb) in a.samples.iter().zip(b.samples.iter()) {
            assert_eq!(sa.pixels, sb.pixels);
        }
    }

    #[test]
    fn different_classes_differ() {
        let d = synthetic_mnist(32, 10, 8);
        // class 0 vs class 1 rasters should not be identical
        assert_ne!(d.samples[0].pixels, d.samples[1].pixels);
    }
}
