//! Stroke-template digit rasterizer for the synthetic MNIST substitute.
//!
//! Each digit class 0-9 has a fixed set of stroke segments in unit
//! coordinates; rendering jitters the endpoints slightly (seeded) and draws
//! anti-aliased thick lines onto a 28×28 canvas — enough visual/structural
//! variety for a real (if easy) classification task.

use crate::util::Rng;

/// Stroke templates per digit: list of (x0, y0, x1, y1) in [0,1]².
fn template(digit: usize) -> &'static [(f32, f32, f32, f32)] {
    const T0: &[(f32, f32, f32, f32)] = &[
        (0.3, 0.2, 0.7, 0.2),
        (0.7, 0.2, 0.7, 0.8),
        (0.7, 0.8, 0.3, 0.8),
        (0.3, 0.8, 0.3, 0.2),
    ];
    const T1: &[(f32, f32, f32, f32)] = &[(0.5, 0.15, 0.5, 0.85), (0.35, 0.3, 0.5, 0.15)];
    const T2: &[(f32, f32, f32, f32)] = &[
        (0.3, 0.25, 0.7, 0.25),
        (0.7, 0.25, 0.7, 0.5),
        (0.7, 0.5, 0.3, 0.8),
        (0.3, 0.8, 0.7, 0.8),
    ];
    const T3: &[(f32, f32, f32, f32)] = &[
        (0.3, 0.2, 0.7, 0.2),
        (0.7, 0.2, 0.5, 0.5),
        (0.5, 0.5, 0.7, 0.8),
        (0.7, 0.8, 0.3, 0.8),
    ];
    const T4: &[(f32, f32, f32, f32)] = &[
        (0.35, 0.2, 0.3, 0.55),
        (0.3, 0.55, 0.75, 0.55),
        (0.65, 0.2, 0.65, 0.85),
    ];
    const T5: &[(f32, f32, f32, f32)] = &[
        (0.7, 0.2, 0.3, 0.2),
        (0.3, 0.2, 0.3, 0.5),
        (0.3, 0.5, 0.7, 0.55),
        (0.7, 0.55, 0.7, 0.8),
        (0.7, 0.8, 0.3, 0.8),
    ];
    const T6: &[(f32, f32, f32, f32)] = &[
        (0.65, 0.2, 0.35, 0.4),
        (0.35, 0.4, 0.3, 0.75),
        (0.3, 0.75, 0.65, 0.8),
        (0.65, 0.8, 0.7, 0.55),
        (0.7, 0.55, 0.3, 0.55),
    ];
    const T7: &[(f32, f32, f32, f32)] = &[(0.3, 0.2, 0.75, 0.2), (0.75, 0.2, 0.45, 0.85)];
    const T8: &[(f32, f32, f32, f32)] = &[
        (0.35, 0.2, 0.65, 0.2),
        (0.65, 0.2, 0.65, 0.5),
        (0.65, 0.5, 0.35, 0.5),
        (0.35, 0.5, 0.35, 0.2),
        (0.35, 0.5, 0.35, 0.8),
        (0.35, 0.8, 0.65, 0.8),
        (0.65, 0.8, 0.65, 0.5),
    ];
    const T9: &[(f32, f32, f32, f32)] = &[
        (0.65, 0.45, 0.35, 0.45),
        (0.35, 0.45, 0.35, 0.2),
        (0.35, 0.2, 0.65, 0.2),
        (0.65, 0.2, 0.65, 0.8),
    ];
    match digit {
        0 => T0,
        1 => T1,
        2 => T2,
        3 => T3,
        4 => T4,
        5 => T5,
        6 => T6,
        7 => T7,
        8 => T8,
        _ => T9,
    }
}

/// Render digit class `digit` as a 28×28 grayscale image in [0,1], with
/// seeded endpoint jitter.
pub fn render_digit(digit: usize, rng: &mut Rng) -> Vec<f32> {
    const S: usize = 28;
    let mut img = vec![0f32; S * S];
    let jitter = 0.04f32;
    let dx = rng.gen_f32_range(-jitter, jitter);
    let dy = rng.gen_f32_range(-jitter, jitter);
    let scale = rng.gen_f32_range(0.9, 1.1);
    for &(x0, y0, x1, y1) in template(digit % 10) {
        let j = |rng: &mut Rng| rng.gen_f32_range(-jitter, jitter);
        let p0 = (
            ((x0 - 0.5) * scale + 0.5 + dx + j(rng)) * S as f32,
            ((y0 - 0.5) * scale + 0.5 + dy + j(rng)) * S as f32,
        );
        let p1 = (
            ((x1 - 0.5) * scale + 0.5 + dx + j(rng)) * S as f32,
            ((y1 - 0.5) * scale + 0.5 + dy + j(rng)) * S as f32,
        );
        draw_line(&mut img, S, p0, p1, 1.3);
    }
    img
}

/// Draw a thick anti-aliased segment by distance-to-segment shading.
fn draw_line(img: &mut [f32], side: usize, p0: (f32, f32), p1: (f32, f32), width: f32) {
    let (x0, y0) = p0;
    let (x1, y1) = p1;
    let minx = (x0.min(x1) - width).floor().max(0.0) as usize;
    let maxx = (x0.max(x1) + width).ceil().min(side as f32 - 1.0) as usize;
    let miny = (y0.min(y1) - width).floor().max(0.0) as usize;
    let maxy = (y0.max(y1) + width).ceil().min(side as f32 - 1.0) as usize;
    let vx = x1 - x0;
    let vy = y1 - y0;
    let len2 = (vx * vx + vy * vy).max(1e-9);
    for y in miny..=maxy {
        for x in minx..=maxx {
            let px = x as f32 - x0;
            let py = y as f32 - y0;
            let t = ((px * vx + py * vy) / len2).clamp(0.0, 1.0);
            let ddx = px - t * vx;
            let ddy = py - t * vy;
            let dist = (ddx * ddx + ddy * ddy).sqrt();
            let v = (1.0 - (dist / width)).clamp(0.0, 1.0);
            let cell = &mut img[y * side + x];
            *cell = cell.max(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_digits_render_nonempty() {
        let mut rng = Rng::new(1);
        for d in 0..10 {
            let img = render_digit(d, &mut rng);
            let on = img.iter().filter(|&&v| v > 0.3).count();
            assert!(on > 10, "digit {d} nearly blank ({on} px)");
            assert!(on < 28 * 28 / 2, "digit {d} too dense ({on} px)");
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn jitter_varies_samples() {
        let mut rng = Rng::new(2);
        let a = render_digit(3, &mut rng);
        let b = render_digit(3, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn line_drawing_hits_endpoints() {
        let mut img = vec![0f32; 28 * 28];
        draw_line(&mut img, 28, (5.0, 5.0), (20.0, 20.0), 1.5);
        assert!(img[5 * 28 + 5] > 0.5);
        assert!(img[20 * 28 + 20] > 0.5);
        assert_eq!(img[27 * 28], 0.0); // far corner untouched
    }
}
