//! Rank-local state and the per-rank SpFF/SpBP step logic (Algorithms 2–3).
//!
//! Each rank owns the row blocks of its neurons in every layer plus the
//! matching bias entries. Two execution engines share this state:
//!
//! - **Blocking** ([`ExecMode::Blocking`], the paper's literal schedule):
//!   activation storage is a full-width buffer per layer — entries the
//!   rank owns are written by its local compute, entries it needs remotely
//!   are written by receives, and entries it neither owns nor needs are
//!   never read (the row block has no nonzero there). Every receive
//!   completes before the single fused SpMV/SpMM of the layer runs.
//! - **Overlap** ([`ExecMode::Overlap`], the split-CSR engine): each row
//!   block is reordered at build time into a local-column segment over the
//!   rank's *compact* owned-activation vector plus one compact segment per
//!   source rank ([`crate::sparse::SplitCsr`]). The layer step posts its
//!   sends, runs the local segment immediately, and applies each remote
//!   segment the moment its payload lands ([`Endpoint::recv_any`]) — the
//!   receive wait hides behind local compute instead of preceding it, and
//!   no full-width buffer or receive-side scatter exists at all.
//! - **Pipelined** ([`ExecMode::Pipelined`], the send-side pipeline on top
//!   of the split-CSR layout): each layer's rows are additionally
//!   regrouped at build time so **boundary rows** — rows whose activations
//!   feed a remote destination in the next layer — are packed first,
//!   grouped per outbound chunk ([`crate::sparse::regroup_rows`]). The
//!   layer step computes the boundary block, applies the inbound payloads
//!   it needs, and posts each outbound payload as chunked sub-transfers
//!   the moment **its own** ready prefix is final — before later boundary
//!   chunks or any interior (local-only) row computes — so peers start
//!   receiving while this rank is still working, instead of after the
//!   whole layer finishes.

use crate::comm::{Codec, Endpoint, Phase, Want};
use crate::dnn::{Activation, Loss, SparseNet};
use crate::obs::{TraceMode, Tracer, NO_CHUNK};
use crate::partition::{CommPlan, DnnPartition};
use crate::sparse::{regroup_rows, Csr, RowRegroup, SplitCsr};
use crate::util::PhaseTimer;

/// Default sub-transfer chunk size (activation entries per chunk) of the
/// pipelined engine — see [`ExecMode::pipelined`].
pub const DEFAULT_CHUNK_ACTS: usize = 128;

/// Which engine a [`RankState`] is built for. The mode fixes the internal
/// weight representation, so it is chosen at build time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Receive every remote activation before the layer's fused kernel
    /// (the seed engine — kept as the measured baseline).
    Blocking,
    /// Split-CSR engine: local-segment compute overlaps in-flight
    /// receives; sends still go out whole, after the previous layer
    /// finishes (the PR-3 schedule, kept as the measured baseline for the
    /// pipelined sender).
    #[default]
    Overlap,
    /// Split-CSR engine with **send-side row-range pipelining**: boundary
    /// rows compute first and each outbound payload posts the moment its
    /// row range is final, as sub-transfers of at most `chunk_acts`
    /// activation entries, while interior rows compute afterwards —
    /// overlapping with the peers' receives.
    Pipelined {
        /// Max activation entries per posted chunk (0 = unchunked: one
        /// chunk per transfer). Smaller chunks start peers earlier but pay
        /// more per-message overhead; see the README tuning note.
        chunk_acts: usize,
    },
}

impl ExecMode {
    /// The pipelined engine with the default chunk size.
    pub fn pipelined() -> Self {
        ExecMode::Pipelined {
            chunk_acts: DEFAULT_CHUNK_ACTS,
        }
    }

    /// Parse a CLI spelling (`blocking` | `overlap` | `pipelined`, the
    /// latter also accepted as `pipeline`). The pipelined engine comes
    /// back with the default chunk size ([`DEFAULT_CHUNK_ACTS`]).
    pub fn from_name(name: &str) -> Option<ExecMode> {
        match name.trim().to_ascii_lowercase().as_str() {
            "blocking" | "block" => Some(ExecMode::Blocking),
            "overlap" => Some(ExecMode::Overlap),
            "pipelined" | "pipeline" => Some(ExecMode::pipelined()),
            _ => None,
        }
    }

    /// Canonical CLI spelling of this mode, the inverse of
    /// [`ExecMode::from_name`] (chunk size not included).
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Blocking => "blocking",
            ExecMode::Overlap => "overlap",
            ExecMode::Pipelined { .. } => "pipelined",
        }
    }
}

/// One outbound transfer of a layer, precompiled for the overlapped
/// engine: gather positions into the compact activation vector.
pub(crate) struct SendSpec {
    pub(crate) to: u32,
    pub(crate) tid: u32,
    /// Positions into the compact owned-activation vector, one per payload
    /// word.
    pub(crate) pos: Vec<u32>,
}

/// One outbound sub-transfer chunk, precompiled for the pipelined engine.
pub(crate) struct ChunkSend {
    pub(crate) to: u32,
    pub(crate) tid: u32,
    pub(crate) chunk: u32,
    /// Gather positions into the source compact vector: the **permuted**
    /// output rows of the producing layer (or the compact input vector for
    /// the layer-0 input sends). All positions lie in the boundary prefix.
    pub(crate) pos: Vec<u32>,
}

/// The send-side pipeline schedule of one layer (pipelined mode only).
pub(crate) struct PipeSchedule {
    /// Permuted row order: row `r'` of the split matrices is the rank's
    /// original local row `perm[r']`. Boundary rows come first.
    pub(crate) perm: Vec<u32>,
    /// Inverse of `perm`: original local row `i` sits at `inv[i]`.
    pub(crate) inv: Vec<u32>,
    /// Rows `[0, boundary_end)` feed at least one next-layer outbound
    /// chunk; rows `[boundary_end, nrows)` are interior (local-only).
    pub(crate) boundary_end: usize,
    /// Next-layer outbound chunks (tagged layer k+1), ordered by the
    /// prefix length that completes them — each posted the moment *its*
    /// prefix is final, before any interior row computes.
    pub(crate) out_sends: Vec<ChunkSend>,
    /// Aligned with `out_sends`: the permuted-row prefix length that must
    /// be final (all segment contributions in, epilogue applicable) before
    /// that chunk's payload is complete. Ascending by construction.
    pub(crate) ready: Vec<usize>,
    /// Per remote segment of this layer: the first permuted row with a
    /// nonzero (`nrows` if the segment is empty). A pending segment blocks
    /// exactly the rows at or past its first row, so the final prefix is
    /// `min(boundary_end, min over pending segments of seg_first_row)`.
    /// Interior-only segments (first row ≥ `boundary_end`) never gate the
    /// outbound posts.
    pub(crate) seg_first_row: Vec<usize>,
}

/// One weight layer compiled for the overlapped/pipelined engines.
pub(crate) struct SplitLayer {
    /// Local segment + one compact remote segment per inbound payload
    /// (whole transfers in overlap mode, chunk-granular in pipelined
    /// mode).
    pub(crate) mat: SplitCsr,
    /// `(source rank, transfer id, chunk id)` want-list aligned with
    /// `mat.remote`.
    pub(crate) recv_wants: Vec<Want>,
    /// Outbound transfers in plan send order (overlap mode; empty in
    /// pipelined mode, whose sends live in [`PipeSchedule::out_sends`]).
    pub(crate) sends: Vec<SendSpec>,
    /// Send-side pipeline schedule (pipelined mode only).
    pub(crate) pipe: Option<PipeSchedule>,
}

/// Mode-specific weight representation. Exactly one exists per state, so
/// training can never desynchronize two copies of the values.
pub(crate) enum Repr {
    /// Full-width row blocks (blocking engine).
    Full { blocks: Vec<Csr> },
    /// Split-CSR layers (overlapped engine) — the value-owning store for
    /// training updates and merges in this mode.
    Split { layers: Vec<SplitLayer> },
}

/// Everything one rank stores.
pub struct RankState {
    pub rank: u32,
    pub nparts: usize,
    /// The mode this state was built for (fixes `repr`'s variant and, for
    /// pipelined, the chunk size baked into the schedules).
    mode: ExecMode,
    /// Owned global row ids per weight layer, ascending.
    pub rows: Vec<Vec<u32>>,
    /// Mode-specific weight storage.
    pub(crate) repr: Repr,
    /// Layer-0 outbound chunks (pipelined mode): the input vector is
    /// available the moment the step starts, so these post immediately.
    pub(crate) input_sends: Vec<ChunkSend>,
    /// Per-layer `(forward, backward)` wire codecs, copied out of the plan
    /// at build time so the precompiled engines never re-consult it.
    pub(crate) codecs: Vec<(Codec, Codec)>,
    /// Deferred-update gradient collection (replica training): when armed
    /// via [`RankState::begin_collect`], every engine's update window
    /// appends the layer's gradient here — weight grads in repr storage
    /// order, then bias grads in the engine's delta layout — instead of
    /// applying it. §5.1 computes every `s = Wᵀδ` *before* its layer's
    /// update and layer k−1's transpose precedes its own update, so
    /// deferring all updates within a step leaves the step's gradients
    /// bit-identical; the replica driver all-reduces the collected vectors
    /// across groups and applies them via
    /// [`RankState::apply_layer_grad`].
    pub(crate) collect: Option<Vec<Vec<f32>>>,
    /// Local bias entries per layer (aligned with `rows`).
    pub biases: Vec<Vec<f32>>,
    pub activation: Activation,
    pub loss: Loss,
    /// Owned entries of the input vector x^0, ascending.
    pub input_rows: Vec<u32>,
    /// Global layer dims: `dims[0]` = input width, `dims[k+1]` = rows of
    /// weight layer k.
    pub dims: Vec<usize>,
    /// Per-phase timers (spmv / updt / comm / wait), for live breakdowns:
    /// "comm" is send-side work, "wait" is time actually blocked on
    /// receives — the component the overlapped engine hides.
    pub timer: PhaseTimer,
    /// Flight recorder: per-layer/per-chunk spans when tracing is on
    /// (see [`crate::obs`]); a zero-capacity no-op when built with
    /// [`TraceMode::Off`].
    pub tracer: Tracer,
}

/// Reusable per-rank inference buffers, sized lazily to the largest
/// request seen so far, so a pool rank thread serving a stream of requests
/// stops touching the allocator after its first (largest) batch.
///
/// Blocking mode ping-pongs two full-width activation matrices plus the
/// local SpMM output `z`; overlap mode ping-pongs two *compact* buffers
/// (never wider than the rank's largest owned block) and needs no `z`.
/// Kernels fully overwrite their output rows and unwritten slots are never
/// read (module invariant), so nothing is ever re-zeroed.
#[derive(Default)]
pub struct RankScratch {
    pub(crate) ping: Vec<f32>,
    pub(crate) pong: Vec<f32>,
    pub(crate) z: Vec<f32>,
    /// Full-width output staging for the one-shot full-width API when the
    /// state runs the compact overlapped engine.
    pub(crate) full_out: Vec<f32>,
    /// Shrinking `(from, transfer, chunk)` want-set for the drain loop.
    pub(crate) wants: Vec<Want>,
    /// Segment index per entry of `wants`.
    pub(crate) want_seg: Vec<usize>,
    /// Received payloads held per segment until the interior rows have
    /// been computed (pipelined inference drain loop).
    pub(crate) held: Vec<Option<Vec<f32>>>,
}

impl RankScratch {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn ensure(&mut self, pingpong: usize, local: usize) {
        if self.ping.len() < pingpong {
            self.ping.resize(pingpong, 0.0);
            self.pong.resize(pingpong, 0.0);
        }
        if self.z.len() < local {
            self.z.resize(local, 0.0);
        }
    }

    pub(crate) fn ensure_full_out(&mut self, len: usize) {
        if self.full_out.len() < len {
            self.full_out.resize(len, 0.0);
        }
    }
}

impl RankState {
    /// Carve this rank's slice out of the full model, compiled for `mode`.
    /// The communication plan is part of the build because the overlapped
    /// engine's split matrices are derived from the inbound transfer lists.
    /// Tracing follows the process-wide `SPDNN_TRACE` contract
    /// ([`TraceMode::from_env`], off by default); use
    /// [`RankState::build_traced`] for explicit control.
    pub fn build(
        net: &SparseNet,
        part: &DnnPartition,
        plan: &CommPlan,
        rank: u32,
        mode: ExecMode,
    ) -> Self {
        Self::build_traced(net, part, plan, rank, mode, TraceMode::from_env())
    }

    /// [`RankState::build`] with an explicit [`TraceMode`]. Pass the SAME
    /// mode value to every rank — the `On` variant carries the shared
    /// clock epoch that puts all ranks on one timeline.
    pub fn build_traced(
        net: &SparseNet,
        part: &DnnPartition,
        plan: &CommPlan,
        rank: u32,
        mode: ExecMode,
        trace: TraceMode,
    ) -> Self {
        // Debug builds refuse to execute a plan the static verifier
        // rejects — the same gate `spdnn check` applies offline. Rank 0
        // only: the plan is shared, so one verification per build wave
        // suffices, and `check_plan` spawns nothing.
        if cfg!(debug_assertions) && rank == 0 {
            let report = crate::analysis::check_plan(&net.layers, part, plan, mode, 1);
            assert!(
                report.ok(),
                "plan verifier rejected the schedule:\n{}",
                report.render()
            );
        }
        let mut rows = Vec::with_capacity(net.depth());
        let mut blocks = Vec::with_capacity(net.depth());
        let mut biases = Vec::with_capacity(net.depth());
        for (k, w) in net.layers.iter().enumerate() {
            let owned = part.rows_of(k, rank);
            blocks.push(w.row_block(&owned));
            biases.push(
                owned
                    .iter()
                    .map(|&r| net.biases[k][r as usize])
                    .collect(),
            );
            rows.push(owned);
        }
        let input_rows: Vec<u32> = part
            .input_parts
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == rank)
            .map(|(j, _)| j as u32)
            .collect();
        let mut dims = Vec::with_capacity(net.depth() + 1);
        dims.push(net.input_dim());
        for w in &net.layers {
            dims.push(w.nrows);
        }
        let me = rank as usize;
        let mut input_sends = Vec::new();
        let repr = match mode {
            ExecMode::Blocking => Repr::Full { blocks },
            ExecMode::Overlap => {
                let layers = blocks
                    .iter()
                    .enumerate()
                    .map(|(k, block)| {
                        let owned_acts: &[u32] = if k == 0 { &input_rows } else { &rows[k - 1] };
                        let lp = &plan.layers[k];
                        let inbound: Vec<(u32, u32, u32, &[u32])> = lp
                            .inbound_of(me)
                            .into_iter()
                            .map(|(src, tid, idx)| (src, tid, 0, idx))
                            .collect();
                        let mat = SplitCsr::build(block, owned_acts, &inbound)
                            .unwrap_or_else(|e| {
                                panic!("rank {rank} layer {k}: plan does not cover block: {e}")
                            });
                        let recv_wants =
                            inbound.iter().map(|&(src, tid, c, _)| (src, tid, c)).collect();
                        let sends = lp
                            .outbound_of(me)
                            .into_iter()
                            .map(|(to, tid, indices)| SendSpec {
                                to,
                                tid,
                                pos: indices
                                    .iter()
                                    .map(|&j| {
                                        owned_acts
                                            .binary_search(&j)
                                            .expect("outbound index is owned")
                                            as u32
                                    })
                                    .collect(),
                            })
                            .collect();
                        SplitLayer {
                            mat,
                            recv_wants,
                            sends,
                            pipe: None,
                        }
                    })
                    .collect();
                Repr::Split { layers }
            }
            ExecMode::Pipelined { chunk_acts } => {
                let depth = blocks.len();
                // Pass 1: regroup each layer's rows so the rows feeding
                // each NEXT-layer outbound chunk (its activations are this
                // layer's output) form the boundary prefix.
                let mut regroups: Vec<RowRegroup> = Vec::with_capacity(depth);
                let mut out_chunks: Vec<Vec<(u32, u32, u32, &[u32])>> =
                    Vec::with_capacity(depth);
                for k in 0..depth {
                    let chunks = if k + 1 < depth {
                        plan.layers[k + 1].outbound_chunks_of(me, chunk_acts)
                    } else {
                        Vec::new()
                    };
                    let owned = &rows[k];
                    let groups: Vec<Vec<u32>> = chunks
                        .iter()
                        .map(|&(_, _, _, idx)| {
                            idx.iter()
                                .map(|&j| {
                                    owned
                                        .binary_search(&j)
                                        .expect("outbound index is owned") as u32
                                })
                                .collect()
                        })
                        .collect();
                    regroups.push(regroup_rows(owned.len(), &groups));
                    out_chunks.push(chunks);
                }
                // Pass 2: build each layer's split matrices over the
                // PERMUTED row block, with chunk-granular remote segments
                // and the previous layer's permuted output as the compact
                // input layout.
                let layers = (0..depth)
                    .map(|k| {
                        let rg = &regroups[k];
                        let pblock = blocks[k].row_block(&rg.perm);
                        let owned_acts: Vec<u32> = if k == 0 {
                            input_rows.clone()
                        } else {
                            regroups[k - 1]
                                .perm
                                .iter()
                                .map(|&p| rows[k - 1][p as usize])
                                .collect()
                        };
                        let inbound = plan.layers[k].inbound_chunks_of(me, chunk_acts);
                        let mat = SplitCsr::build(&pblock, &owned_acts, &inbound)
                            .unwrap_or_else(|e| {
                                panic!("rank {rank} layer {k}: plan does not cover block: {e}")
                            });
                        let recv_wants =
                            inbound.iter().map(|&(src, tid, c, _)| (src, tid, c)).collect();
                        let nloc = pblock.nrows;
                        let seg_first_row = mat
                            .remote
                            .iter()
                            .map(|s| {
                                (0..nloc)
                                    .find(|&r| s.csr.indptr[r + 1] > s.csr.indptr[r])
                                    .unwrap_or(nloc)
                            })
                            .collect();
                        // outbound chunks ordered by completion prefix, so
                        // the earliest-finished row range posts first
                        let mut order: Vec<usize> = (0..out_chunks[k].len()).collect();
                        order.sort_by_key(|&i| rg.ready[i]);
                        let ready: Vec<usize> = order.iter().map(|&i| rg.ready[i]).collect();
                        let out_sends = order
                            .into_iter()
                            .map(|i| {
                                let (to, tid, chunk, idx) = out_chunks[k][i];
                                ChunkSend {
                                    to,
                                    tid,
                                    chunk,
                                    pos: idx
                                        .iter()
                                        .map(|&j| {
                                            let p = rows[k]
                                                .binary_search(&j)
                                                .expect("outbound index is owned");
                                            rg.inv[p]
                                        })
                                        .collect(),
                                }
                            })
                            .collect();
                        SplitLayer {
                            mat,
                            recv_wants,
                            sends: Vec::new(),
                            pipe: Some(PipeSchedule {
                                perm: rg.perm.clone(),
                                inv: rg.inv.clone(),
                                boundary_end: rg.boundary_end,
                                out_sends,
                                ready,
                                seg_first_row,
                            }),
                        }
                    })
                    .collect();
                // MSRV 1.74: map_or, not Option::is_none_or (1.82)
                debug_assert!(
                    regroups.last().map_or(true, |rg| {
                        rg.perm.iter().enumerate().all(|(i, &p)| i == p as usize)
                    }),
                    "last layer must keep its row order (no next-layer sends)"
                );
                // layer-0 sends gather straight from the compact input
                input_sends = plan.layers[0]
                    .outbound_chunks_of(me, chunk_acts)
                    .into_iter()
                    .map(|(to, tid, chunk, idx)| ChunkSend {
                        to,
                        tid,
                        chunk,
                        pos: idx
                            .iter()
                            .map(|&j| {
                                input_rows
                                    .binary_search(&j)
                                    .expect("outbound index is owned")
                                    as u32
                            })
                            .collect(),
                    })
                    .collect();
                Repr::Split { layers }
            }
        };
        let codecs = plan
            .layers
            .iter()
            .map(|l| (l.codec_fwd, l.codec_bwd))
            .collect();
        Self {
            rank,
            nparts: part.nparts,
            mode,
            rows,
            repr,
            input_sends,
            codecs,
            collect: None,
            biases,
            activation: net.activation,
            loss: net.loss,
            input_rows,
            dims,
            timer: PhaseTimer::new(),
            tracer: Tracer::new(trace, rank),
        }
    }

    /// Which engine this state was built for.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Depth in weight layers.
    pub fn depth(&self) -> usize {
        self.rows.len()
    }

    /// Flat gradient length of layer `k` in collect mode: one entry per
    /// stored weight nonzero (repr storage order) plus one per owned bias.
    /// Identical across replica groups built from the same partition/plan/
    /// mode — the invariant the cross-group all-reduce relies on.
    pub fn grad_len(&self, k: usize) -> usize {
        let nnz = match &self.repr {
            Repr::Full { blocks } => blocks[k].nnz(),
            Repr::Split { layers } => layers[k].mat.nnz(),
        };
        nnz + self.rows[k].len()
    }

    /// Arm deferred-update gradient collection: subsequent train steps
    /// fill per-layer gradient buffers instead of updating weights. The
    /// buffers persist across steps (cleared and refilled each step), so
    /// steady-state training allocates nothing.
    pub fn begin_collect(&mut self) {
        let depth = self.depth();
        let mut bufs = Vec::with_capacity(depth);
        for k in 0..depth {
            bufs.push(Vec::with_capacity(self.grad_len(k)));
        }
        self.collect = Some(bufs);
    }

    /// Take this step's collected per-layer gradients (collect mode only).
    /// Hand the buffers back with [`RankState::restore_grad_bufs`] after
    /// the exchange so the next step reuses their allocations.
    pub fn take_step_grads(&mut self) -> Vec<Vec<f32>> {
        self.collect.take().expect("collect mode not armed")
    }

    /// Return gradient buffers taken by [`RankState::take_step_grads`],
    /// re-arming collect mode for the next step.
    pub fn restore_grad_bufs(&mut self, bufs: Vec<Vec<f32>>) {
        self.collect = Some(bufs);
    }

    /// Apply a flat layer gradient in collect-mode layout: weight entries
    /// in repr storage order, then bias entries in the engine's delta
    /// layout (direct owned-row order, or the pipelined permuted order
    /// when the layer carries a pipeline schedule).
    pub fn apply_layer_grad(&mut self, k: usize, g: &[f32], eta: f32) {
        let nb = self.rows[k].len();
        match &mut self.repr {
            Repr::Full { blocks } => {
                let nnz = blocks[k].nnz();
                debug_assert_eq!(g.len(), nnz + nb);
                blocks[k].apply_grad(&g[..nnz], eta);
                for (i, &d) in g[nnz..].iter().enumerate() {
                    self.biases[k][i] -= eta * d;
                }
            }
            Repr::Split { layers } => {
                let sl = &mut layers[k];
                let nnz = sl.mat.nnz();
                debug_assert_eq!(g.len(), nnz + nb);
                sl.mat.apply_grad(&g[..nnz], eta);
                match &sl.pipe {
                    Some(pipe) => {
                        for (r, &d) in g[nnz..].iter().enumerate() {
                            self.biases[k][pipe.perm[r] as usize] -= eta * d;
                        }
                    }
                    None => {
                        for (i, &d) in g[nnz..].iter().enumerate() {
                            self.biases[k][i] -= eta * d;
                        }
                    }
                }
            }
        }
    }

    /// Forward pass (Alg. 2) for one input on the **blocking** engine.
    /// `x0` is the **full** input vector but only entries this rank owns
    /// are read. Returns the full-width activation buffers x^0..x^L
    /// (locally known entries only). Panics on an overlap-mode state — the
    /// overlapped engine keeps activations compact and goes through
    /// [`RankState::train_step`] / [`RankState::infer_batch_scratch`].
    pub fn forward(&mut self, ep: &mut Endpoint, plan: &CommPlan, x0: &[f32]) -> Vec<Vec<f32>> {
        let depth = self.depth();
        let mut xbuf: Vec<Vec<f32>> = Vec::with_capacity(depth + 1);
        let mut x = vec![0f32; self.dims[0]];
        for &j in &self.input_rows {
            x[j as usize] = x0[j as usize];
        }
        xbuf.push(x);

        let blocks = match &self.repr {
            Repr::Full { blocks } => blocks,
            Repr::Split { .. } => panic!("RankState::forward requires ExecMode::Blocking"),
        };
        for k in 0..depth {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cf = self.codecs[k].0;
            // non-blocking sends of owned x^{k} entries (Alg. 2 lines 3–5)
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = ep.take_buf();
                    payload.extend(t.indices.iter().map(|&j| xbuf[k][j as usize]));
                    moved += 4 * payload.len() as u64;
                    ep.send_encoded(t.to, k as u32, Phase::Forward, tid, 0, cf, payload);
                }
            });
            self.tracer.end(sp, "send", "fwd", k as u32, NO_CHUNK, moved);
            // receives (Alg. 2 lines 7–8); blocking mode receives before
            // the single fused SpMV — the stall the overlapped engine
            // hides.
            let mut xk = std::mem::take(&mut xbuf[k]);
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("wait", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.from, k as u32, Phase::Forward, tid);
                    let payload = ep.decode_payload(cf, payload);
                    moved += 4 * payload.len() as u64;
                    for (i, &j) in t.indices.iter().enumerate() {
                        xk[j as usize] = payload[i];
                    }
                    ep.recycle(payload);
                }
            });
            self.tracer.end(sp, "wait", "fwd", k as u32, NO_CHUNK, moved);
            xbuf[k] = xk;
            // local SpMV + bias + activation (Alg. 2 lines 6, 10)
            let mut out = vec![0f32; self.dims[k + 1]];
            let mut z = vec![0f32; blocks[k].nrows];
            let sp = self.tracer.start();
            self.timer.time("spmv", || {
                blocks[k].spmv(&xbuf[k], &mut z);
            });
            self.tracer.end(sp, "spmv", "fwd", k as u32, NO_CHUNK, 0);
            for (i, zi) in z.iter_mut().enumerate() {
                *zi += self.biases[k][i];
            }
            self.activation.apply(&mut z);
            for (i, &r) in self.rows[k].iter().enumerate() {
                out[r as usize] = z[i];
            }
            xbuf.push(out);
        }
        xbuf
    }

    /// Full train step: forward + backward + update (Alg. 2 + Alg. 3).
    /// `y` is the full target vector (only owned output entries are read).
    /// Returns this rank's partial loss. Dispatches on the build mode.
    pub fn train_step(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        y: &[f32],
        eta: f32,
    ) -> f32 {
        match self.mode {
            ExecMode::Blocking => self.train_step_blocking(ep, plan, x0, y, eta),
            // a single vector is a batch of one in row-major layout
            ExecMode::Overlap => self.train_step_overlap(ep, plan, x0, y, 1, eta),
            ExecMode::Pipelined { .. } => self.train_step_pipelined(ep, plan, x0, y, 1, eta),
        }
    }

    /// Blocking-engine train step (the seed schedule, kept as baseline).
    fn train_step_blocking(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        y: &[f32],
        eta: f32,
    ) -> f32 {
        let depth = self.depth();
        let xbuf = self.forward(ep, plan, x0);

        // δ^L over owned output rows (Alg. 3 line 2)
        let last_rows = self.rows[depth - 1].clone();
        let mut delta: Vec<f32> = Vec::with_capacity(last_rows.len());
        let mut local_loss = 0f32;
        for &r in &last_rows {
            let xr = xbuf[depth][r as usize];
            let yr = y[r as usize];
            local_loss += 0.5 * (xr - yr) * (xr - yr);
            let g = xr - yr; // MSE gradient
            delta.push(g * self.activation.derivative_from_output(xr));
        }

        let blocks = match &mut self.repr {
            Repr::Full { blocks } => blocks,
            Repr::Split { .. } => unreachable!("dispatched on Full"),
        };
        for k in (0..depth).rev() {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cb = self.codecs[k].1;
            // s = (W^k_m)ᵀ δ^k_m (Alg. 3 line 4)
            let mut s = vec![0f32; blocks[k].ncols];
            let sp = self.tracer.start();
            self.timer.time("spmv", || {
                blocks[k].spmv_t_add(&delta, &mut s);
            });
            self.tracer.end(sp, "spmvt", "bwd", k as u32, NO_CHUNK, 0);
            // non-blocking sends of partial gradients (lines 5–7):
            // mirror of forward receives.
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("comm", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = ep.take_buf();
                    payload.extend(t.indices.iter().map(|&j| s[j as usize]));
                    moved += 4 * payload.len() as u64;
                    ep.send_encoded(t.from, k as u32, Phase::Backward, tid, 0, cb, payload);
                }
            });
            self.tracer.end(sp, "send", "bwd", k as u32, NO_CHUNK, moved);
            // overlap window: weight + bias update (lines 8–9) uses x^{k-1}
            // including entries received during the forward phase. Collect
            // mode records the gradient instead — the replica driver
            // exchanges and applies it after the step.
            let sp = self.tracer.start();
            if let Some(gr) = self.collect.as_mut() {
                self.timer.time("updt", || {
                    gr[k].clear();
                    blocks[k].outer_grad(&delta, &xbuf[k], &mut gr[k]);
                    gr[k].extend_from_slice(&delta);
                });
            } else {
                self.timer.time("updt", || {
                    blocks[k].sgd_update(&delta, &xbuf[k], eta);
                });
                for (i, d) in delta.iter().enumerate() {
                    self.biases[k][i] -= eta * d;
                }
            }
            self.tracer.end(sp, "updt", "bwd", k as u32, NO_CHUNK, 0);
            // receive partial gradients (lines 10–12): mirror of fwd sends.
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("wait", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.to, k as u32, Phase::Backward, tid);
                    let payload = ep.decode_payload(cb, payload);
                    moved += 4 * payload.len() as u64;
                    for (i, &j) in t.indices.iter().enumerate() {
                        s[j as usize] += payload[i];
                    }
                    ep.recycle(payload);
                }
            });
            self.tracer.end(sp, "wait", "bwd", k as u32, NO_CHUNK, moved);
            // δ^{k-1} = s ⊙ f'(z^{k-1}) on owned rows of layer k-1 (line 13)
            if k > 0 {
                let owned = &self.rows[k - 1];
                let mut next = Vec::with_capacity(owned.len());
                for &j in owned.iter() {
                    let yj = xbuf[k][j as usize];
                    next.push(s[j as usize] * self.activation.derivative_from_output(yj));
                }
                delta = next;
            }
        }
        local_loss
    }

    /// Inference-only forward for a batch of `b` inputs (SpMM, §5.1).
    /// `x0` is the full input matrix row-major `[n0 × b]`; only owned rows
    /// are read. Returns the full-width `[nL × b]` buffer — **only owned
    /// rows are meaningful** (the rest may hold stale scratch contents).
    pub fn infer_batch(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
    ) -> Vec<f32> {
        let mut scratch = RankScratch::new();
        self.infer_batch_scratch(ep, plan, x0, b, &mut scratch)
            .to_vec()
    }

    /// Allocation-reusing form of [`RankState::infer_batch`]: all activation
    /// matrices live in the caller's [`RankScratch`], which the serving pool
    /// keeps per rank thread across requests. Stale values from earlier
    /// layers/requests may remain in the reused buffers; that is safe under
    /// the module invariant — a slot is read only if this rank owns it or
    /// received it this request.
    pub fn infer_batch_scratch<'s>(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        match self.repr {
            Repr::Full { .. } => self.infer_batch_scratch_blocking(ep, plan, x0, b, scratch),
            Repr::Split { .. } => {
                // compact result scattered into a full-width staging buffer
                // to honor the full-width contract of this API; the serving
                // hot path uses `infer_owned_outputs` and skips this.
                let depth = self.depth();
                let nl = self.dims[depth];
                let compact_len = {
                    let out = self.infer_compact(ep, plan, x0, b, scratch);
                    out.len()
                };
                assert_eq!(compact_len, self.rows[depth - 1].len() * b);
                scratch.ensure_full_out(nl * b);
                for (i, &r) in self.rows[depth - 1].iter().enumerate() {
                    let r = r as usize;
                    scratch.full_out[r * b..(r + 1) * b]
                        .copy_from_slice(&scratch.ping[i * b..(i + 1) * b]);
                }
                &scratch.full_out[..nl * b]
            }
        }
    }

    /// Blocking-engine batched forward (seed path): full-width ping-pong
    /// buffers, every receive scattered before the single fused SpMM.
    fn infer_batch_scratch_blocking<'s>(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        let depth = self.depth();
        let maxw = self.dims.iter().copied().max().unwrap_or(0);
        let blocks = match &self.repr {
            Repr::Full { blocks } => blocks,
            Repr::Split { .. } => unreachable!("dispatched on Full"),
        };
        let maxlocal = blocks.iter().map(|w| w.nrows).max().unwrap_or(0);
        scratch.ensure(maxw * b, maxlocal * b);
        for &j in &self.input_rows {
            let j = j as usize;
            scratch.ping[j * b..(j + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        for k in 0..depth {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cf = self.codecs[k].0;
            let cur = &mut scratch.ping;
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = ep.take_buf();
                    payload.reserve(t.indices.len() * b);
                    for &j in &t.indices {
                        let j = j as usize;
                        payload.extend_from_slice(&cur[j * b..(j + 1) * b]);
                    }
                    moved += 4 * payload.len() as u64;
                    ep.send_encoded(t.to, k as u32, Phase::Forward, tid, 0, cf, payload);
                }
            });
            self.tracer.end(sp, "send", "fwd", k as u32, NO_CHUNK, moved);
            let sp = self.tracer.start();
            let mut moved = 0u64;
            self.timer.time("wait", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.from, k as u32, Phase::Forward, tid);
                    let payload = ep.decode_payload(cf, payload);
                    moved += 4 * payload.len() as u64;
                    for (i, &j) in t.indices.iter().enumerate() {
                        let j = j as usize;
                        cur[j * b..(j + 1) * b].copy_from_slice(&payload[i * b..(i + 1) * b]);
                    }
                    ep.recycle(payload);
                }
            });
            self.tracer.end(sp, "wait", "fwd", k as u32, NO_CHUNK, moved);
            // fused row-block SpMM: bias + activation applied per cache
            // tile inside the accumulation pass
            let blk = &blocks[k];
            let bias = &self.biases[k];
            let act = self.activation;
            let xin = &scratch.ping[..blk.ncols * b];
            let z = &mut scratch.z[..blk.nrows * b];
            let sp = self.tracer.start();
            self.timer.time("spmv", || {
                blk.spmm_fused_rowmajor(xin, z, b, act.fused_bias_epilogue(bias));
            });
            self.tracer.end(sp, "spmv", "fwd", k as u32, NO_CHUNK, 0);
            for (i, &r) in self.rows[k].iter().enumerate() {
                let r = r as usize;
                scratch.pong[r * b..(r + 1) * b].copy_from_slice(&scratch.z[i * b..(i + 1) * b]);
            }
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
        }
        &scratch.ping[..self.dims[depth] * b]
    }

    /// The per-rank batched-inference body shared by the one-shot engine
    /// ([`crate::coordinator::sgd::infer_with_plan`]) and the persistent
    /// serving pool ([`crate::serving::RankPool`]): run the forward SpMM
    /// pass, then extract this rank's owned output rows as
    /// `(global row, [b] values)` pairs ready for driver-side assembly.
    /// On the overlapped engine the outputs come straight out of the
    /// compact buffer — no full-width staging at all.
    pub fn infer_owned_outputs(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &mut RankScratch,
    ) -> Vec<(u32, Vec<f32>)> {
        match self.repr {
            Repr::Full { .. } => {
                let full = self.infer_batch_scratch_blocking(ep, plan, x0, b, scratch);
                let owned = self.rows.last().expect("network has at least one layer");
                owned
                    .iter()
                    .map(|&r| {
                        let r = r as usize;
                        (r as u32, full[r * b..(r + 1) * b].to_vec())
                    })
                    .collect()
            }
            Repr::Split { .. } => {
                // Both compact engines leave the LAST layer in its original
                // row order (it has no next-layer sends to regroup for), so
                // the owned-row extraction is shared.
                let compact = self.infer_compact(ep, plan, x0, b, scratch);
                let owned = self.rows.last().expect("network has at least one layer");
                owned
                    .iter()
                    .enumerate()
                    .map(|(i, &r)| (r, compact[i * b..(i + 1) * b].to_vec()))
                    .collect()
            }
        }
    }

    /// Compact batched forward for a split-repr state, dispatched on the
    /// build mode (overlap vs pipelined).
    pub(crate) fn infer_compact<'s>(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        match self.mode {
            ExecMode::Overlap => self.infer_overlap_compact(ep, plan, x0, b, scratch),
            ExecMode::Pipelined { .. } => self.infer_pipelined_compact(ep, plan, x0, b, scratch),
            ExecMode::Blocking => unreachable!("compact path dispatched on Split repr"),
        }
    }

    /// Reassemble this rank's rows into a global model (driver-side merge).
    pub fn merge_into(&self, net: &mut SparseNet) {
        match &self.repr {
            Repr::Full { blocks } => {
                for (k, owned) in self.rows.iter().enumerate() {
                    for (i, &r) in owned.iter().enumerate() {
                        let (_, src) = blocks[k].row(i);
                        let lo = net.layers[k].indptr[r as usize] as usize;
                        let hi = net.layers[k].indptr[r as usize + 1] as usize;
                        net.layers[k].vals[lo..hi].copy_from_slice(src);
                        net.biases[k][r as usize] = self.biases[k][i];
                    }
                }
            }
            Repr::Split { layers } => {
                for (k, owned) in self.rows.iter().enumerate() {
                    for (i, &r) in owned.iter().enumerate() {
                        // pipelined layers store rows boundary-first; the
                        // original local row i sits at inv[i]
                        let split_row = match &layers[k].pipe {
                            Some(pipe) => pipe.inv[i] as usize,
                            None => i,
                        };
                        let pairs = layers[k].mat.gather_row(split_row);
                        let lo = net.layers[k].indptr[r as usize] as usize;
                        let hi = net.layers[k].indptr[r as usize + 1] as usize;
                        debug_assert_eq!(hi - lo, pairs.len(), "row {r} nnz mismatch");
                        for (off, (c, v)) in pairs.into_iter().enumerate() {
                            debug_assert_eq!(
                                net.layers[k].indices[lo + off],
                                c,
                                "row {r} column order mismatch"
                            );
                            net.layers[k].vals[lo + off] = v;
                        }
                        net.biases[k][r as usize] = self.biases[k][i];
                    }
                }
            }
        }
    }
}
