//! Rank-local state and the per-rank SpFF/SpBP step logic (Algorithms 2–3).
//!
//! Each rank owns the row blocks of its neurons in every layer plus the
//! matching bias entries. Activation storage is a full-width buffer per
//! layer: entries the rank owns are written by its local compute, entries
//! it needs remotely are written by receives, and entries it neither owns
//! nor needs are never read (the row block has no nonzero there) — this is
//! semantically identical to the paper's placeholder subvectors x̄/x̂ while
//! keeping the hot loop a single CSR SpMV.

use crate::comm::{Endpoint, Phase};
use crate::dnn::{Activation, Loss, SparseNet};
use crate::partition::{CommPlan, DnnPartition};
use crate::sparse::Csr;
use crate::util::PhaseTimer;

/// Everything one rank stores.
pub struct RankState {
    pub rank: u32,
    pub nparts: usize,
    /// Owned global row ids per weight layer, ascending.
    pub rows: Vec<Vec<u32>>,
    /// Local row blocks (local rows × global columns).
    pub blocks: Vec<Csr>,
    /// Local bias entries per layer (aligned with `rows`).
    pub biases: Vec<Vec<f32>>,
    pub activation: Activation,
    pub loss: Loss,
    /// Owned entries of the input vector x^0.
    pub input_rows: Vec<u32>,
    /// Global layer dims: `dims[0]` = input width, `dims[k+1]` = rows of
    /// weight layer k.
    pub dims: Vec<usize>,
    /// Per-phase timers (SpMV / Updt / Comm), for live breakdowns.
    pub timer: PhaseTimer,
}

/// Reusable per-rank inference buffers: two full-width ping-pong activation
/// matrices plus the local row-block SpMM output. Sized lazily to the widest
/// layer × batch seen so far, so a pool rank thread serving a stream of
/// requests stops touching the allocator after its first (largest) batch.
/// The fused SpMM fully overwrites its output rows and the placeholder
/// invariant (module doc) guarantees unwritten full-width slots are never
/// read, so the buffers are never re-zeroed.
#[derive(Default)]
pub struct RankScratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
    z: Vec<f32>,
}

impl RankScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, full: usize, local: usize) {
        if self.ping.len() < full {
            self.ping.resize(full, 0.0);
            self.pong.resize(full, 0.0);
        }
        if self.z.len() < local {
            self.z.resize(local, 0.0);
        }
    }
}

impl RankState {
    /// Carve this rank's slice out of the full model.
    pub fn build(net: &SparseNet, part: &DnnPartition, rank: u32) -> Self {
        let mut rows = Vec::with_capacity(net.depth());
        let mut blocks = Vec::with_capacity(net.depth());
        let mut biases = Vec::with_capacity(net.depth());
        for (k, w) in net.layers.iter().enumerate() {
            let owned = part.rows_of(k, rank);
            blocks.push(w.row_block(&owned));
            biases.push(
                owned
                    .iter()
                    .map(|&r| net.biases[k][r as usize])
                    .collect(),
            );
            rows.push(owned);
        }
        let input_rows = part
            .input_parts
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == rank)
            .map(|(j, _)| j as u32)
            .collect();
        let mut dims = Vec::with_capacity(net.depth() + 1);
        dims.push(net.input_dim());
        for w in &net.layers {
            dims.push(w.nrows);
        }
        Self {
            rank,
            nparts: part.nparts,
            rows,
            blocks,
            biases,
            activation: net.activation,
            loss: net.loss,
            input_rows,
            dims,
            timer: PhaseTimer::new(),
        }
    }

    /// Width of the activation vector feeding weight layer k (x^{k}).
    fn in_width(&self, k: usize) -> usize {
        self.blocks[k].ncols
    }

    /// Forward pass (Alg. 2) for one input. `x0` is the **full** input
    /// vector but only entries this rank owns are read. Returns the
    /// full-width activation buffers x^0..x^L (locally known entries only).
    pub fn forward(&mut self, ep: &mut Endpoint, plan: &CommPlan, x0: &[f32]) -> Vec<Vec<f32>> {
        let depth = self.blocks.len();
        let mut xbuf: Vec<Vec<f32>> = Vec::with_capacity(depth + 1);
        let mut x = vec![0f32; self.in_width(0)];
        for &j in &self.input_rows {
            x[j as usize] = x0[j as usize];
        }
        xbuf.push(x);

        for k in 0..depth {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            // non-blocking sends of owned x^{k} entries (Alg. 2 lines 3–5)
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload: Vec<f32> = t
                        .indices
                        .iter()
                        .map(|&j| xbuf[k][j as usize])
                        .collect();
                    ep.send(t.to, k as u32, Phase::Forward, tid, payload);
                }
            });
            // receives (Alg. 2 lines 7–8); live mode receives before the
            // single fused SpMV — overlap is a perf artifact modeled by the
            // replay simulator, not needed for correctness.
            let mut xk = std::mem::take(&mut xbuf[k]);
            self.timer.time("comm", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.from, k as u32, Phase::Forward, tid);
                    for (i, &j) in t.indices.iter().enumerate() {
                        xk[j as usize] = payload[i];
                    }
                }
            });
            xbuf[k] = xk;
            // local SpMV + bias + activation (Alg. 2 lines 6, 10)
            let mut out = vec![0f32; self.dims[k + 1]];
            let mut z = vec![0f32; self.blocks[k].nrows];
            self.timer.time("spmv", || {
                self.blocks[k].spmv(&xbuf[k], &mut z);
            });
            for (i, zi) in z.iter_mut().enumerate() {
                *zi += self.biases[k][i];
            }
            self.activation.apply(&mut z);
            for (i, &r) in self.rows[k].iter().enumerate() {
                out[r as usize] = z[i];
            }
            xbuf.push(out);
        }
        xbuf
    }

    /// Full train step: forward + backward + update (Alg. 2 + Alg. 3).
    /// `y` is the full target vector (only owned output entries are read).
    /// Returns this rank's partial loss.
    pub fn train_step(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        y: &[f32],
        eta: f32,
    ) -> f32 {
        let depth = self.blocks.len();
        let xbuf = self.forward(ep, plan, x0);

        // δ^L over owned output rows (Alg. 3 line 2)
        let last_rows = self.rows[depth - 1].clone();
        let mut delta: Vec<f32> = Vec::with_capacity(last_rows.len());
        let mut local_loss = 0f32;
        for &r in &last_rows {
            let xr = xbuf[depth][r as usize];
            let yr = y[r as usize];
            local_loss += 0.5 * (xr - yr) * (xr - yr);
            let g = xr - yr; // MSE gradient
            delta.push(g * self.activation.derivative_from_output(xr));
        }

        for k in (0..depth).rev() {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            // s = (W^k_m)ᵀ δ^k_m (Alg. 3 line 4)
            let mut s = vec![0f32; self.in_width(k)];
            self.timer.time("spmv", || {
                self.blocks[k].spmv_t_add(&delta, &mut s);
            });
            // non-blocking sends of partial gradients (lines 5–7):
            // mirror of forward receives.
            self.timer.time("comm", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload: Vec<f32> =
                        t.indices.iter().map(|&j| s[j as usize]).collect();
                    ep.send(t.from, k as u32, Phase::Backward, tid, payload);
                }
            });
            // overlap window: weight + bias update (lines 8–9) uses x^{k-1}
            // including entries received during the forward phase.
            self.timer.time("updt", || {
                self.blocks[k].sgd_update(&delta, &xbuf[k], eta);
            });
            for (i, d) in delta.iter().enumerate() {
                self.biases[k][i] -= eta * d;
            }
            // receive partial gradients (lines 10–12): mirror of fwd sends.
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.to, k as u32, Phase::Backward, tid);
                    for (i, &j) in t.indices.iter().enumerate() {
                        s[j as usize] += payload[i];
                    }
                }
            });
            // δ^{k-1} = s ⊙ f'(z^{k-1}) on owned rows of layer k-1 (line 13)
            if k > 0 {
                let owned = &self.rows[k - 1];
                let mut next = Vec::with_capacity(owned.len());
                for &j in owned.iter() {
                    let yj = xbuf[k][j as usize];
                    next.push(s[j as usize] * self.activation.derivative_from_output(yj));
                }
                delta = next;
            }
        }
        local_loss
    }

    /// Inference-only forward for a batch of `b` inputs (SpMM, §5.1).
    /// `x0` is the full input matrix row-major `[n0 × b]`; only owned rows
    /// are read. Returns the full-width `[nL × b]` buffer — **only owned
    /// rows are meaningful** (the rest may hold stale scratch contents).
    pub fn infer_batch(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
    ) -> Vec<f32> {
        let mut scratch = RankScratch::new();
        self.infer_batch_scratch(ep, plan, x0, b, &mut scratch)
            .to_vec()
    }

    /// Allocation-reusing form of [`RankState::infer_batch`]: all activation
    /// matrices live in the caller's [`RankScratch`], which the serving pool
    /// keeps per rank thread across requests. Stale values from earlier
    /// layers/requests may remain in the reused buffers; that is safe under
    /// the module invariant — a slot is read only if this rank owns it
    /// (written by the scatter below) or needs it (written by a receive).
    pub fn infer_batch_scratch<'s>(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        let depth = self.blocks.len();
        let maxw = self.dims.iter().copied().max().unwrap_or(0);
        let maxlocal = self.blocks.iter().map(|w| w.nrows).max().unwrap_or(0);
        scratch.ensure(maxw * b, maxlocal * b);
        for &j in &self.input_rows {
            let j = j as usize;
            scratch.ping[j * b..(j + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        for k in 0..depth {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cur = &mut scratch.ping;
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = Vec::with_capacity(t.indices.len() * b);
                    for &j in &t.indices {
                        let j = j as usize;
                        payload.extend_from_slice(&cur[j * b..(j + 1) * b]);
                    }
                    ep.send(t.to, k as u32, Phase::Forward, tid, payload);
                }
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.from, k as u32, Phase::Forward, tid);
                    for (i, &j) in t.indices.iter().enumerate() {
                        let j = j as usize;
                        cur[j * b..(j + 1) * b].copy_from_slice(&payload[i * b..(i + 1) * b]);
                    }
                }
            });
            // fused row-block SpMM: bias + activation applied per cache
            // tile inside the accumulation pass (the serving hot loop)
            let blk = &self.blocks[k];
            let bias = &self.biases[k];
            let act = self.activation;
            let xin = &scratch.ping[..blk.ncols * b];
            let z = &mut scratch.z[..blk.nrows * b];
            self.timer.time("spmv", || {
                blk.spmm_fused_rowmajor(xin, z, b, act.fused_bias_epilogue(bias));
            });
            for (i, &r) in self.rows[k].iter().enumerate() {
                let r = r as usize;
                scratch.pong[r * b..(r + 1) * b].copy_from_slice(&scratch.z[i * b..(i + 1) * b]);
            }
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
        }
        &scratch.ping[..self.dims[depth] * b]
    }

    /// The per-rank batched-inference body shared by the one-shot engine
    /// ([`crate::coordinator::sgd::infer_with_plan`]) and the persistent
    /// serving pool ([`crate::serving::RankPool`]): run the forward SpMM
    /// pass, then extract this rank's owned output rows as
    /// `(global row, [b] values)` pairs ready for driver-side assembly.
    pub fn infer_owned_outputs(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
        scratch: &mut RankScratch,
    ) -> Vec<(u32, Vec<f32>)> {
        let full = self.infer_batch_scratch(ep, plan, x0, b, scratch);
        let owned = self.rows.last().expect("network has at least one layer");
        owned
            .iter()
            .map(|&r| {
                let r = r as usize;
                (r as u32, full[r * b..(r + 1) * b].to_vec())
            })
            .collect()
    }

    /// Reassemble this rank's rows into a global model (driver-side merge).
    pub fn merge_into(&self, net: &mut SparseNet) {
        for (k, owned) in self.rows.iter().enumerate() {
            for (i, &r) in owned.iter().enumerate() {
                let (_, src) = self.blocks[k].row(i);
                let lo = net.layers[k].indptr[r as usize] as usize;
                let hi = net.layers[k].indptr[r as usize + 1] as usize;
                net.layers[k].vals[lo..hi].copy_from_slice(src);
                net.biases[k][r as usize] = self.biases[k][i];
            }
        }
    }
}
