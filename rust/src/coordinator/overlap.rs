//! The split-CSR **overlapped** execution engine ([`ExecMode::Overlap`]).
//!
//! Every layer step follows the same schedule:
//!
//! 1. post the non-blocking sends of owned activations (gathered straight
//!    from the compact activation vector — no full-width buffer exists);
//! 2. run the **local segment** SpMM immediately — this is the compute
//!    that hides the in-flight receives;
//! 3. drain arrivals: already-landed payloads are consumed without
//!    blocking ([`Endpoint::try_recv`]), the rest as they land in
//!    **arrival order** ([`Endpoint::recv_any`]), each applied as a
//!    compact remote-segment SpMM directly on the payload;
//! 4. apply the bias + activation epilogue once all contributions are in.
//!
//! Only step 3's actual blocked time is charged to the `wait` phase, so
//! live breakdowns show exactly how much of the blocking engine's receive
//! stall the overlap hides. The backward mirror keeps the same idea: each
//! remote segment's partial gradient is computed and sent *before* the
//! local transpose and weight update, and the mirrored receives are
//! consumed in arrival order behind the update window.

use super::minibatch::row_means;
use super::worker::{RankScratch, RankState, Repr};
use crate::comm::{Endpoint, Phase, Want};
use crate::obs::NO_CHUNK;
use crate::partition::CommPlan;

impl RankState {
    /// Overlapped batched forward over compact activations. Returns the
    /// final layer's owned rows `[local_L × b]` row-major, borrowed from
    /// `scratch.ping` (where the last layer's output lands after the final
    /// ping-pong swap).
    ///
    /// The layer step here and the retaining one in
    /// [`RankState::train_step_overlap`] are intentional twins (scratch
    /// ping-pong + recycled payloads vs per-layer buffers + retained
    /// payloads for the update); a change to the send/drain schedule in
    /// one must be mirrored in the other.
    pub(crate) fn infer_overlap_compact<'s>(
        &mut self,
        ep: &mut Endpoint,
        _plan: &CommPlan, // schedule is fully precompiled into the split layers
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        let depth = self.depth();
        let maxcompact = self
            .input_rows
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        scratch.ensure(maxcompact * b, 0);
        for (i, &j) in self.input_rows.iter().enumerate() {
            let j = j as usize;
            scratch.ping[i * b..(i + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        let layers = match &self.repr {
            Repr::Split { layers } => layers,
            Repr::Full { .. } => unreachable!("overlap path dispatched on Split"),
        };
        for (k, sl) in layers.iter().enumerate().take(depth) {
            let inw = sl.mat.local_gcols.len();
            let nloc = sl.mat.nrows;
            let cf = self.codecs[k].0;
            // 1. sends, gathered from the compact activation vector
            {
                let cur = &scratch.ping[..inw * b];
                let sp = self.tracer.start();
                let mut moved = 0u64;
                self.timer.time("comm", || {
                    for s in &sl.sends {
                        let mut payload = ep.take_buf();
                        payload.reserve(s.pos.len() * b);
                        for &p in &s.pos {
                            let p = p as usize;
                            payload.extend_from_slice(&cur[p * b..(p + 1) * b]);
                        }
                        moved += 4 * payload.len() as u64;
                        ep.send_encoded(s.to, k as u32, Phase::Forward, s.tid, 0, cf, payload);
                    }
                });
                self.tracer.end(sp, "send", "fwd", k as u32, NO_CHUNK, moved);
            }
            // 2. local segment, while remote activations are in flight.
            // With no remote segments the epilogue fuses into this pass.
            let fuse_now = sl.mat.remote.is_empty();
            {
                let x = &scratch.ping[..inw * b];
                let z = &mut scratch.pong[..nloc * b];
                let bias = &self.biases[k];
                let act = self.activation;
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    if fuse_now {
                        sl.mat
                            .local
                            .spmm_fused_rowmajor(x, z, b, act.fused_bias_epilogue(bias));
                    } else {
                        sl.mat.local.spmm_fused_rowmajor(x, z, b, |_, _| {});
                    }
                });
                self.tracer.end(sp, "spmv.local", "fwd", k as u32, NO_CHUNK, 0);
            }
            if !fuse_now {
                // 3a. apply everything that already landed, without blocking
                scratch.wants.clear();
                scratch.want_seg.clear();
                for (si, &(src, tid, chunk)) in sl.recv_wants.iter().enumerate() {
                    if let Some(payload) =
                        ep.try_recv_chunk(src, k as u32, Phase::Forward, tid, chunk)
                    {
                        let payload = ep.decode_payload(cf, payload);
                        let z = &mut scratch.pong[..nloc * b];
                        let seg = &sl.mat.remote[si].csr;
                        let sp = self.tracer.start();
                        self.timer.time("spmv", || seg.spmm_add_rowmajor(&payload, z, b));
                        self.tracer
                            .end(sp, "spmv.seg", "fwd", k as u32, chunk, 4 * payload.len() as u64);
                        ep.recycle(payload);
                    } else {
                        scratch.wants.push((src, tid, chunk));
                        scratch.want_seg.push(si);
                    }
                }
                // 3b. the rest in arrival order; only this blocks
                while !scratch.wants.is_empty() {
                    let sp = self.tracer.start();
                    let (i, payload) = {
                        let wants = &scratch.wants;
                        self.timer
                            .time("wait", || ep.recv_any(k as u32, Phase::Forward, wants))
                    };
                    self.tracer
                        .end(sp, "wait", "fwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                    let payload = ep.decode_payload(cf, payload);
                    let si = scratch.want_seg[i];
                    let chunk = scratch.wants[i].2;
                    scratch.wants.swap_remove(i);
                    scratch.want_seg.swap_remove(i);
                    let z = &mut scratch.pong[..nloc * b];
                    let seg = &sl.mat.remote[si].csr;
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || seg.spmm_add_rowmajor(&payload, z, b));
                    self.tracer
                        .end(sp, "spmv.seg", "fwd", k as u32, chunk, 4 * payload.len() as u64);
                    ep.recycle(payload);
                }
                // 4. bias + activation once every contribution is in
                let z = &mut scratch.pong[..nloc * b];
                let bias = &self.biases[k];
                let act = self.activation;
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    let mut epi = act.fused_bias_epilogue(bias);
                    for i in 0..nloc {
                        epi(i, &mut z[i * b..(i + 1) * b]);
                    }
                });
                self.tracer.end(sp, "epilogue", "fwd", k as u32, NO_CHUNK, 0);
            }
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
        }
        &scratch.ping[..self.rows[depth - 1].len() * b]
    }

    /// Overlapped minibatch train step (§5.1 semantics: batched SpFF,
    /// batch-averaged δ^L, single-vector SpBP over batch-mean
    /// activations). [`RankState::train_step`] is the `b = 1` case, where
    /// the means reduce to the activations themselves. Returns this rank's
    /// partial (batch-averaged) loss.
    pub(crate) fn train_step_overlap(
        &mut self,
        ep: &mut Endpoint,
        _plan: &CommPlan, // schedule is fully precompiled into the split layers
        x0: &[f32],
        y: &[f32],
        b: usize,
        eta: f32,
    ) -> f32 {
        let depth = self.depth();

        // ---- overlapped forward, retaining per-layer activations and the
        // received payloads (both feed the weight update); the layer step
        // mirrors `infer_overlap_compact` — keep the two in sync ----
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(depth + 1);
        let mut payloads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(depth);
        let mut a0 = vec![0f32; self.input_rows.len() * b];
        for (i, &j) in self.input_rows.iter().enumerate() {
            let j = j as usize;
            a0[i * b..(i + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        acts.push(a0);
        {
            let layers = match &self.repr {
                Repr::Split { layers } => layers,
                Repr::Full { .. } => unreachable!("overlap path dispatched on Split"),
            };
            for (k, sl) in layers.iter().enumerate().take(depth) {
                let nloc = sl.mat.nrows;
                let cf = self.codecs[k].0;
                let mut z = vec![0f32; nloc * b];
                let fuse_now = sl.mat.remote.is_empty();
                {
                    let cur = &acts[k];
                    let sp = self.tracer.start();
                    let mut moved = 0u64;
                    self.timer.time("comm", || {
                        for s in &sl.sends {
                            let mut payload = ep.take_buf();
                            payload.reserve(s.pos.len() * b);
                            for &p in &s.pos {
                                let p = p as usize;
                                payload.extend_from_slice(&cur[p * b..(p + 1) * b]);
                            }
                            moved += 4 * payload.len() as u64;
                            ep.send_encoded(s.to, k as u32, Phase::Forward, s.tid, 0, cf, payload);
                        }
                    });
                    self.tracer.end(sp, "send", "fwd", k as u32, NO_CHUNK, moved);
                    let bias = &self.biases[k];
                    let act = self.activation;
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        if fuse_now {
                            sl.mat
                                .local
                                .spmm_fused_rowmajor(cur, &mut z, b, act.fused_bias_epilogue(bias));
                        } else {
                            sl.mat.local.spmm_fused_rowmajor(cur, &mut z, b, |_, _| {});
                        }
                    });
                    self.tracer.end(sp, "spmv.local", "fwd", k as u32, NO_CHUNK, 0);
                }
                let nsegs = sl.mat.remote.len();
                let mut lay_payloads: Vec<Vec<f32>> = vec![Vec::new(); nsegs];
                if !fuse_now {
                    let mut wants: Vec<Want> = Vec::with_capacity(nsegs);
                    let mut want_seg: Vec<usize> = Vec::with_capacity(nsegs);
                    for (si, &(src, tid, chunk)) in sl.recv_wants.iter().enumerate() {
                        if let Some(payload) =
                            ep.try_recv_chunk(src, k as u32, Phase::Forward, tid, chunk)
                        {
                            let payload = ep.decode_payload(cf, payload);
                            let seg = &sl.mat.remote[si].csr;
                            let sp = self.tracer.start();
                            self.timer.time("spmv", || seg.spmm_add_rowmajor(&payload, &mut z, b));
                            self.tracer.end(
                                sp,
                                "spmv.seg",
                                "fwd",
                                k as u32,
                                chunk,
                                4 * payload.len() as u64,
                            );
                            lay_payloads[si] = payload;
                        } else {
                            wants.push((src, tid, chunk));
                            want_seg.push(si);
                        }
                    }
                    while !wants.is_empty() {
                        let sp = self.tracer.start();
                        let (i, payload) = self
                            .timer
                            .time("wait", || ep.recv_any(k as u32, Phase::Forward, &wants));
                        self.tracer
                            .end(sp, "wait", "fwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                        let payload = ep.decode_payload(cf, payload);
                        let si = want_seg[i];
                        let chunk = wants[i].2;
                        wants.swap_remove(i);
                        want_seg.swap_remove(i);
                        let seg = &sl.mat.remote[si].csr;
                        let sp = self.tracer.start();
                        self.timer.time("spmv", || seg.spmm_add_rowmajor(&payload, &mut z, b));
                        self.tracer.end(
                            sp,
                            "spmv.seg",
                            "fwd",
                            k as u32,
                            chunk,
                            4 * payload.len() as u64,
                        );
                        lay_payloads[si] = payload;
                    }
                    let bias = &self.biases[k];
                    let act = self.activation;
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        let mut epi = act.fused_bias_epilogue(bias);
                        for i in 0..nloc {
                            epi(i, &mut z[i * b..(i + 1) * b]);
                        }
                    });
                    self.tracer.end(sp, "epilogue", "fwd", k as u32, NO_CHUNK, 0);
                }
                acts.push(z);
                payloads.push(lay_payloads);
            }
        }

        // ---- δ^L averaged over the batch (Alg. 3 line 2 / Eq. 6) ----
        let act = self.activation;
        let inv_b = 1.0 / b as f32;
        let last = &self.rows[depth - 1];
        let xl = &acts[depth];
        let mut delta: Vec<f32> = Vec::with_capacity(last.len());
        let mut local_loss = 0f32;
        for (i, &r) in last.iter().enumerate() {
            let r = r as usize;
            let mut d = 0f32;
            for j in 0..b {
                let xr = xl[i * b + j];
                let yr = y[r * b + j];
                local_loss += 0.5 * (xr - yr) * (xr - yr) * inv_b;
                d += (xr - yr) * act.derivative_from_output(xr);
            }
            delta.push(d * inv_b);
        }

        // ---- overlapped backward (Alg. 3, mirror schedule) ----
        let layers = match &mut self.repr {
            Repr::Split { layers } => layers,
            Repr::Full { .. } => unreachable!("overlap path dispatched on Split"),
        };
        for k in (0..depth).rev() {
            let sl = &mut layers[k];
            let inw = sl.mat.local_gcols.len();
            let cb = self.codecs[k].1;
            // 1. per-segment partial gradients, sent the moment each is
            // ready (mirror of the forward receives)
            for seg in &sl.mat.remote {
                let mut sseg = ep.take_buf();
                sseg.resize(seg.csr.ncols, 0.0);
                let sp = self.tracer.start();
                self.timer.time("spmv", || seg.csr.spmv_t_add(&delta, &mut sseg));
                self.tracer.end(sp, "spmvt.seg", "bwd", k as u32, seg.chunk, 0);
                let moved = 4 * sseg.len() as u64;
                let sp = self.tracer.start();
                self.timer.time("comm", || {
                    ep.send_encoded(
                        seg.src,
                        k as u32,
                        Phase::Backward,
                        seg.tid,
                        seg.chunk,
                        cb,
                        sseg,
                    )
                });
                self.tracer.end(sp, "send", "bwd", k as u32, seg.chunk, moved);
            }
            // 2. local transpose over owned slots
            let mut s_local = vec![0f32; inw];
            let sp = self.tracer.start();
            self.timer.time("spmv", || sl.mat.local.spmv_t_add(&delta, &mut s_local));
            self.tracer.end(sp, "spmvt", "bwd", k as u32, NO_CHUNK, 0);
            // 3. weight + bias update in the overlap window, against the
            // batch-mean activations (local compact + per-segment payload)
            let mx_local = row_means(&acts[k], b);
            let mx_segs: Vec<Vec<f32>> = payloads[k].iter().map(|p| row_means(p, b)).collect();
            let sp = self.tracer.start();
            if let Some(gr) = self.collect.as_mut() {
                // collect mode: record the gradient instead of updating —
                // the replica driver exchanges and applies it after the step
                self.timer.time("updt", || {
                    gr[k].clear();
                    sl.mat.outer_grad(&delta, &mx_local, &mx_segs, &mut gr[k]);
                    gr[k].extend_from_slice(&delta);
                });
            } else {
                self.timer.time("updt", || sl.mat.sgd_update(&delta, &mx_local, &mx_segs, eta));
                for (i, d) in delta.iter().enumerate() {
                    self.biases[k][i] -= eta * d;
                }
            }
            self.tracer.end(sp, "updt", "bwd", k as u32, NO_CHUNK, 0);
            // 4. mirrored receives in arrival order (behind the update)
            if !sl.sends.is_empty() {
                let mut wants: Vec<Want> =
                    sl.sends.iter().map(|s| (s.to, s.tid, 0)).collect();
                let mut which: Vec<usize> = (0..sl.sends.len()).collect();
                while !wants.is_empty() {
                    let sp = self.tracer.start();
                    let (i, payload) =
                        self.timer.time("wait", || ep.recv_any(k as u32, Phase::Backward, &wants));
                    self.tracer
                        .end(sp, "wait", "bwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                    let payload = ep.decode_payload(cb, payload);
                    let sj = which[i];
                    wants.swap_remove(i);
                    which.swap_remove(i);
                    for (idx, &p) in sl.sends[sj].pos.iter().enumerate() {
                        s_local[p as usize] += payload[idx];
                    }
                    ep.recycle(payload);
                }
            }
            // 5. δ^{k-1} = s ⊙ f'(x̄^k) over owned slots (compact)
            if k > 0 {
                let mut next = Vec::with_capacity(inw);
                for i in 0..inw {
                    next.push(s_local[i] * act.derivative_from_output(mx_local[i]));
                }
                delta = next;
            }
        }
        // return the retained payload allocations to the endpoint pool
        for lay in payloads {
            for p in lay {
                if p.capacity() > 0 {
                    ep.recycle(p);
                }
            }
        }
        local_loss
    }
}
