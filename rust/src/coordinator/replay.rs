//! Deterministic replay simulator — regenerates the paper's *time* results
//! (Fig. 4 strong scaling, Fig. 5 breakdown, Table 2 throughput) at any
//! processor count without needing that many cores.
//!
//! The replay walks the exact per-layer schedule of Algorithms 2–3 over the
//! exact per-rank message sets of a [`CommPlan`] and charges:
//! - compute from calibrated per-nnz rates ([`ComputeModel`], measured on
//!   this host), scaled by batch size;
//! - communication from the α-β [`NetModel`] on the true message/word
//!   counts;
//! - the inter-layer synchronization barrier by taking, per layer, the
//!   maximum compute over ranks plus the maximum comm over ranks (the
//!   barrier the paper identifies as the main latency overhead, §6.2).

use crate::comm::netmodel::{layer_loads, ComputeModel, NetModel, RankLayerLoad};
use crate::partition::{CommPlan, DnnPartition};
use crate::sparse::Csr;

/// What to simulate.
#[derive(Debug, Clone, Copy)]
pub struct ReplayConfig {
    pub net: NetModel,
    pub comp: ComputeModel,
    /// Inputs processed per iteration (1 = pure SGD; >1 = minibatch SpMM).
    pub batch: usize,
    /// Simulate training (fwd+bwd+update) or inference only.
    pub train: bool,
}

impl ReplayConfig {
    pub fn training(comp: ComputeModel) -> Self {
        Self {
            net: NetModel::infiniband(),
            comp,
            batch: 1,
            train: true,
        }
    }

    pub fn inference(comp: ComputeModel, batch: usize) -> Self {
        Self {
            net: NetModel::infiniband(),
            comp,
            batch,
            train: false,
        }
    }
}

/// Simulated timing result for one iteration (one input / one batch).
#[derive(Debug, Clone, Default)]
pub struct ReplayResult {
    /// Seconds spent in local SpMV-like compute (fwd + bwd products).
    pub spmv: f64,
    /// Seconds spent in gradient updates.
    pub updt: f64,
    /// Seconds spent communicating (incl. the per-layer barrier effect).
    pub comm: f64,
}

impl ReplayResult {
    pub fn total(&self) -> f64 {
        self.spmv + self.updt + self.comm
    }
}

/// Per-rank comm load in one layer: messages and **wire bytes** (the
/// codec-encoded footprint of each payload), send and recv.
#[derive(Debug, Clone, Copy, Default)]
struct CommLoad {
    smsgs: u64,
    sbytes: u64,
    rmsgs: u64,
    rbytes: u64,
}

/// Simulate one SGD iteration (or one inference batch if `train=false`).
pub fn replay(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    cfg: &ReplayConfig,
) -> ReplayResult {
    let nparts = part.nparts;
    let loads = layer_loads(structure, &part.layer_parts, nparts);
    let b = cfg.batch as f64;
    let mut res = ReplayResult::default();

    let mut fwd_scratch = vec![CommLoad::default(); nparts];
    let mut bwd_scratch = vec![CommLoad::default(); nparts];
    for (k, lp) in plan.layers.iter().enumerate() {
        // per-rank comm loads of this layer, in wire bytes under the
        // layer's codecs — forward and its SpBP mirror (send/recv swap)
        // separately, because the backward gradients may run a different
        // codec than the forward activations
        for c in fwd_scratch.iter_mut() {
            *c = CommLoad::default();
        }
        for c in bwd_scratch.iter_mut() {
            *c = CommLoad::default();
        }
        for t in &lp.transfers {
            let n = t.indices.len() * cfg.batch;
            let fb = lp.codec_fwd.wire_bytes(n);
            let bb = lp.codec_bwd.wire_bytes(n);
            let f = &mut fwd_scratch[t.from as usize];
            f.smsgs += 1;
            f.sbytes += fb;
            let r = &mut fwd_scratch[t.to as usize];
            r.rmsgs += 1;
            r.rbytes += fb;
            let f = &mut bwd_scratch[t.to as usize];
            f.smsgs += 1;
            f.sbytes += bb;
            let r = &mut bwd_scratch[t.from as usize];
            r.rmsgs += 1;
            r.rbytes += bb;
        }
        let max_comm = fwd_scratch
            .iter()
            .map(|c| cfg.net.layer_cost_bytes(c.smsgs, c.sbytes, c.rmsgs, c.rbytes))
            .fold(0.0, f64::max);

        // forward compute: SpMV/SpMM + activation
        let max_fwd = loads[k]
            .iter()
            .map(|l: &RankLayerLoad| cfg.comp.fwd_time(l.nnz, l.rows) * b)
            .fold(0.0, f64::max);
        res.spmv += max_fwd;
        res.comm += max_comm;

        if cfg.train {
            // backward: transpose product + same comm (mirror) + update
            let max_bwd = loads[k]
                .iter()
                .map(|l| cfg.comp.bwd_time(l.nnz, l.rows) * b)
                .fold(0.0, f64::max);
            let max_updt = loads[k]
                .iter()
                .map(|l| cfg.comp.update_time(l.nnz) * b)
                .fold(0.0, f64::max);
            res.spmv += max_bwd;
            res.updt += max_updt;
            // SpBP mirrors SpFF's message sets, under the backward codec
            let max_comm_bwd = bwd_scratch
                .iter()
                .map(|c| cfg.net.layer_cost_bytes(c.smsgs, c.sbytes, c.rmsgs, c.rbytes))
                .fold(0.0, f64::max);
            res.comm += max_comm_bwd;
        }
    }
    res
}

/// Predicted seconds of the cross-group gradient all-reduce appended to
/// each replica training step ([`crate::replica`]): per layer, every
/// rank rings its own flat gradient (weights + biases, the
/// `RankState::grad_len` layout) with its same-rank peers concurrently,
/// so the layer charge is the max over ranks of
/// [`NetModel::ring_allreduce_cost`]; layers serialize. `groups == 1`
/// costs nothing, matching the live engine's zero-message degenerate
/// case.
pub fn replica_allreduce_time(
    structure: &[Csr],
    part: &DnnPartition,
    cfg: &ReplayConfig,
    groups: usize,
    codec: crate::comm::Codec,
) -> f64 {
    if groups <= 1 {
        return 0.0;
    }
    let loads = layer_loads(structure, &part.layer_parts, part.nparts);
    loads
        .iter()
        .map(|layer| {
            layer
                .iter()
                .map(|l| {
                    cfg.net
                        .ring_allreduce_cost(groups, (l.nnz + l.rows) as usize, codec)
                })
                .fold(0.0, f64::max)
        })
        .sum()
}

/// Strong-scaling sweep (Fig. 4): simulated seconds/input at each P for a
/// given partitioning function.
pub fn scaling_sweep(
    structure: &[Csr],
    parts: &[(usize, DnnPartition)],
    cfg: &ReplayConfig,
) -> Vec<(usize, ReplayResult)> {
    parts
        .iter()
        .map(|(p, part)| {
            let plan = CommPlan::build(structure, part);
            (*p, replay(structure, part, &plan, cfg))
        })
        .collect()
}

/// Inference throughput in edges/second (Table 2 metric): `inputs` vectors
/// through a network of `total_nnz` connections in simulated time.
pub fn throughput_edges_per_sec(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    comp: ComputeModel,
    batch: usize,
    inputs: usize,
) -> f64 {
    let cfg = ReplayConfig::inference(comp, batch);
    let per_batch = replay(structure, part, plan, &cfg).total();
    let nbatches = (inputs + batch - 1) / batch;
    let total_nnz: u64 = structure.iter().map(|w| w.nnz() as u64).sum();
    (total_nnz as f64 * inputs as f64) / (per_batch * nbatches as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::phases::{hypergraph_partition, PhaseConfig};
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    fn structure() -> Vec<Csr> {
        generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap())
    }

    fn cfg() -> ReplayConfig {
        ReplayConfig::training(ComputeModel::haswell_defaults())
    }

    #[test]
    fn compute_shrinks_with_more_ranks() {
        let s = structure();
        let p4 = random_partition(&s, 4, 1);
        let p16 = random_partition(&s, 16, 1);
        let r4 = replay(&s, &p4, &CommPlan::build(&s, &p4), &cfg());
        let r16 = replay(&s, &p16, &CommPlan::build(&s, &p16), &cfg());
        assert!(r16.spmv < r4.spmv, "{} vs {}", r16.spmv, r4.spmv);
        assert!(r16.comm > 0.0 && r4.comm > 0.0);
    }

    #[test]
    fn hypergraph_partition_is_faster_in_model() {
        let s = structure();
        let h = hypergraph_partition(&s, &PhaseConfig::new(8));
        let r = random_partition(&s, 8, 2);
        let th = replay(&s, &h, &CommPlan::build(&s, &h), &cfg()).total();
        let tr = replay(&s, &r, &CommPlan::build(&s, &r), &cfg()).total();
        assert!(th < tr, "H {th} not faster than R {tr}");
    }

    #[test]
    fn single_rank_has_zero_comm() {
        let s = structure();
        let p = random_partition(&s, 1, 1);
        let r = replay(&s, &p, &CommPlan::build(&s, &p), &cfg());
        assert_eq!(r.comm, 0.0);
        assert!(r.spmv > 0.0);
        assert!(r.updt > 0.0);
    }

    #[test]
    fn inference_has_no_update_time() {
        let s = structure();
        let p = random_partition(&s, 4, 1);
        let plan = CommPlan::build(&s, &p);
        let mut c = cfg();
        c.train = false;
        let r = replay(&s, &p, &plan, &c);
        assert_eq!(r.updt, 0.0);
    }

    #[test]
    fn batch_amortizes_latency() {
        // throughput (edges/s) grows with batch size: α is paid once per
        // message regardless of batch width.
        let s = structure();
        let p = random_partition(&s, 8, 1);
        let plan = CommPlan::build(&s, &p);
        let comp = ComputeModel::haswell_defaults();
        let t1 = throughput_edges_per_sec(&s, &p, &plan, comp, 1, 64);
        let t64 = throughput_edges_per_sec(&s, &p, &plan, comp, 64, 64);
        assert!(t64 > t1, "batch 64 {t64} <= batch 1 {t1}");
    }

    #[test]
    fn codec_shrinks_predicted_comm_but_not_compute() {
        use crate::comm::Codec;
        let s = structure();
        let p = random_partition(&s, 8, 1);
        let plan32 = CommPlan::build(&s, &p);
        let mut plan16 = plan32.clone();
        plan16.set_codec(Codec::F16, Codec::F16);
        let mut plan8 = plan32.clone();
        plan8.set_codec(Codec::int8(), Codec::int8());
        let c = cfg();
        let r32 = replay(&s, &p, &plan32, &c);
        let r16 = replay(&s, &p, &plan16, &c);
        let r8 = replay(&s, &p, &plan8, &c);
        assert!(r16.comm < r32.comm, "f16 {} !< f32 {}", r16.comm, r32.comm);
        assert!(r8.comm < r16.comm, "int8 {} !< f16 {}", r8.comm, r16.comm);
        assert_eq!(r16.spmv, r32.spmv, "codec must not change compute time");
        assert_eq!(r16.updt, r32.updt);
        // mixed phases: a lossy forward with a lossless backward sits
        // between all-f32 and all-f16
        let mut mixed = plan32.clone();
        mixed.set_codec(Codec::F16, Codec::F32);
        let rm = replay(&s, &p, &mixed, &c);
        assert!(r16.comm < rm.comm && rm.comm < r32.comm);
    }

    #[test]
    fn replica_allreduce_charge_behaves() {
        use crate::comm::Codec;
        let s = structure();
        let p = random_partition(&s, 4, 1);
        let c = cfg();
        assert_eq!(replica_allreduce_time(&s, &p, &c, 1, Codec::F32), 0.0);
        let t2 = replica_allreduce_time(&s, &p, &c, 2, Codec::F32);
        assert!(t2 > 0.0);
        let t2q = replica_allreduce_time(&s, &p, &c, 2, Codec::int8());
        assert!(t2q < t2, "int8 ring {t2q} not cheaper than f32 {t2}");
    }

    #[test]
    fn total_is_sum_of_parts() {
        let s = structure();
        let p = random_partition(&s, 4, 1);
        let r = replay(&s, &p, &CommPlan::build(&s, &p), &cfg());
        assert!((r.total() - (r.spmv + r.updt + r.comm)).abs() < 1e-12);
    }
}
