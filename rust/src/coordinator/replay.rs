//! Deterministic replay simulator — regenerates the paper's *time* results
//! (Fig. 4 strong scaling, Fig. 5 breakdown, Table 2 throughput) at any
//! processor count without needing that many cores.
//!
//! The replay walks the exact per-layer schedule of Algorithms 2–3 over the
//! exact per-rank message sets of a [`CommPlan`] and charges:
//! - compute from calibrated per-nnz rates ([`ComputeModel`], measured on
//!   this host), scaled by batch size;
//! - communication from the α-β [`NetModel`] on the true message/word
//!   counts;
//! - the inter-layer synchronization barrier by taking, per layer, the
//!   maximum compute over ranks plus the maximum comm over ranks (the
//!   barrier the paper identifies as the main latency overhead, §6.2).

use crate::comm::netmodel::{layer_loads, ComputeModel, NetModel, RankLayerLoad};
use crate::partition::{CommPlan, DnnPartition};
use crate::sparse::Csr;

/// What to simulate.
#[derive(Debug, Clone, Copy)]
pub struct ReplayConfig {
    pub net: NetModel,
    pub comp: ComputeModel,
    /// Inputs processed per iteration (1 = pure SGD; >1 = minibatch SpMM).
    pub batch: usize,
    /// Simulate training (fwd+bwd+update) or inference only.
    pub train: bool,
}

impl ReplayConfig {
    pub fn training(comp: ComputeModel) -> Self {
        Self {
            net: NetModel::infiniband(),
            comp,
            batch: 1,
            train: true,
        }
    }

    pub fn inference(comp: ComputeModel, batch: usize) -> Self {
        Self {
            net: NetModel::infiniband(),
            comp,
            batch,
            train: false,
        }
    }
}

/// Simulated timing result for one iteration (one input / one batch).
#[derive(Debug, Clone, Default)]
pub struct ReplayResult {
    /// Seconds spent in local SpMV-like compute (fwd + bwd products).
    pub spmv: f64,
    /// Seconds spent in gradient updates.
    pub updt: f64,
    /// Seconds spent communicating (incl. the per-layer barrier effect).
    pub comm: f64,
}

impl ReplayResult {
    pub fn total(&self) -> f64 {
        self.spmv + self.updt + self.comm
    }
}

/// Per-rank comm load in one layer (messages/words, send and recv).
#[derive(Debug, Clone, Copy, Default)]
struct CommLoad {
    smsgs: u64,
    swords: u64,
    rmsgs: u64,
    rwords: u64,
}

/// Simulate one SGD iteration (or one inference batch if `train=false`).
pub fn replay(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    cfg: &ReplayConfig,
) -> ReplayResult {
    let nparts = part.nparts;
    let loads = layer_loads(structure, &part.layer_parts, nparts);
    let b = cfg.batch as f64;
    let mut res = ReplayResult::default();

    let mut comm_scratch = vec![CommLoad::default(); nparts];
    for (k, lp) in plan.layers.iter().enumerate() {
        // per-rank comm loads of this layer
        for c in comm_scratch.iter_mut() {
            *c = CommLoad::default();
        }
        for t in &lp.transfers {
            let words = t.indices.len() as u64 * cfg.batch as u64;
            let f = &mut comm_scratch[t.from as usize];
            f.smsgs += 1;
            f.swords += words;
            let r = &mut comm_scratch[t.to as usize];
            r.rmsgs += 1;
            r.rwords += words;
        }
        let max_comm = comm_scratch
            .iter()
            .map(|c| cfg.net.layer_cost(c.smsgs, c.swords, c.rmsgs, c.rwords))
            .fold(0.0, f64::max);

        // forward compute: SpMV/SpMM + activation
        let max_fwd = loads[k]
            .iter()
            .map(|l: &RankLayerLoad| cfg.comp.fwd_time(l.nnz, l.rows) * b)
            .fold(0.0, f64::max);
        res.spmv += max_fwd;
        res.comm += max_comm;

        if cfg.train {
            // backward: transpose product + same comm (mirror) + update
            let max_bwd = loads[k]
                .iter()
                .map(|l| cfg.comp.bwd_time(l.nnz, l.rows) * b)
                .fold(0.0, f64::max);
            let max_updt = loads[k]
                .iter()
                .map(|l| cfg.comp.update_time(l.nnz) * b)
                .fold(0.0, f64::max);
            res.spmv += max_bwd;
            res.updt += max_updt;
            res.comm += max_comm; // SpBP mirrors SpFF exactly
        }
    }
    res
}

/// Strong-scaling sweep (Fig. 4): simulated seconds/input at each P for a
/// given partitioning function.
pub fn scaling_sweep(
    structure: &[Csr],
    parts: &[(usize, DnnPartition)],
    cfg: &ReplayConfig,
) -> Vec<(usize, ReplayResult)> {
    parts
        .iter()
        .map(|(p, part)| {
            let plan = CommPlan::build(structure, part);
            (*p, replay(structure, part, &plan, cfg))
        })
        .collect()
}

/// Inference throughput in edges/second (Table 2 metric): `inputs` vectors
/// through a network of `total_nnz` connections in simulated time.
pub fn throughput_edges_per_sec(
    structure: &[Csr],
    part: &DnnPartition,
    plan: &CommPlan,
    comp: ComputeModel,
    batch: usize,
    inputs: usize,
) -> f64 {
    let cfg = ReplayConfig::inference(comp, batch);
    let per_batch = replay(structure, part, plan, &cfg).total();
    let nbatches = (inputs + batch - 1) / batch;
    let total_nnz: u64 = structure.iter().map(|w| w.nnz() as u64).sum();
    (total_nnz as f64 * inputs as f64) / (per_batch * nbatches as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::phases::{hypergraph_partition, PhaseConfig};
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate_structure, RadixNetConfig};

    fn structure() -> Vec<Csr> {
        generate_structure(&RadixNetConfig::graph_challenge(256, 8).unwrap())
    }

    fn cfg() -> ReplayConfig {
        ReplayConfig::training(ComputeModel::haswell_defaults())
    }

    #[test]
    fn compute_shrinks_with_more_ranks() {
        let s = structure();
        let p4 = random_partition(&s, 4, 1);
        let p16 = random_partition(&s, 16, 1);
        let r4 = replay(&s, &p4, &CommPlan::build(&s, &p4), &cfg());
        let r16 = replay(&s, &p16, &CommPlan::build(&s, &p16), &cfg());
        assert!(r16.spmv < r4.spmv, "{} vs {}", r16.spmv, r4.spmv);
        assert!(r16.comm > 0.0 && r4.comm > 0.0);
    }

    #[test]
    fn hypergraph_partition_is_faster_in_model() {
        let s = structure();
        let h = hypergraph_partition(&s, &PhaseConfig::new(8));
        let r = random_partition(&s, 8, 2);
        let th = replay(&s, &h, &CommPlan::build(&s, &h), &cfg()).total();
        let tr = replay(&s, &r, &CommPlan::build(&s, &r), &cfg()).total();
        assert!(th < tr, "H {th} not faster than R {tr}");
    }

    #[test]
    fn single_rank_has_zero_comm() {
        let s = structure();
        let p = random_partition(&s, 1, 1);
        let r = replay(&s, &p, &CommPlan::build(&s, &p), &cfg());
        assert_eq!(r.comm, 0.0);
        assert!(r.spmv > 0.0);
        assert!(r.updt > 0.0);
    }

    #[test]
    fn inference_has_no_update_time() {
        let s = structure();
        let p = random_partition(&s, 4, 1);
        let plan = CommPlan::build(&s, &p);
        let mut c = cfg();
        c.train = false;
        let r = replay(&s, &p, &plan, &c);
        assert_eq!(r.updt, 0.0);
    }

    #[test]
    fn batch_amortizes_latency() {
        // throughput (edges/s) grows with batch size: α is paid once per
        // message regardless of batch width.
        let s = structure();
        let p = random_partition(&s, 8, 1);
        let plan = CommPlan::build(&s, &p);
        let comp = ComputeModel::haswell_defaults();
        let t1 = throughput_edges_per_sec(&s, &p, &plan, comp, 1, 64);
        let t64 = throughput_edges_per_sec(&s, &p, &plan, comp, 64, 64);
        assert!(t64 > t1, "batch 64 {t64} <= batch 1 {t1}");
    }

    #[test]
    fn total_is_sum_of_parts() {
        let s = structure();
        let p = random_partition(&s, 4, 1);
        let r = replay(&s, &p, &CommPlan::build(&s, &p), &cfg());
        assert!((r.total() - (r.spmv + r.updt + r.comm)).abs() < 1e-12);
    }
}
