//! L3 coordinator — the paper's system contribution.
//!
//! - [`worker`]: rank-local state + the SpFF/SpBP step logic (Alg. 2–3),
//!   with the blocking (full-width) engine and the mode dispatch;
//! - [`overlap`]: the split-CSR overlapped engine — local-segment compute
//!   runs while remote activations are in flight;
//! - [`pipeline`]: the send-side pipelined engine — boundary rows compute
//!   first and every outbound payload posts as chunked sub-transfers
//!   before the interior rows, overlapping with the peers' receives;
//! - [`sgd`]: live threaded distributed training/inference over the
//!   simulated fabric, with counter cross-checks against the plan;
//! - [`replay`]: deterministic timing simulator (Fig. 4/5, Table 2) using
//!   calibrated compute rates + the α-β network model;
//! - [`gb_baseline`]: the data-parallel GraphBLAS-style comparator of
//!   Table 2.

pub mod gb_baseline;
pub mod minibatch;
pub mod overlap;
pub mod pipeline;
pub mod replay;
pub mod sgd;
pub mod worker;

pub use replay::{replay, ReplayConfig, ReplayResult};
pub use sgd::{
    infer_distributed, infer_with_plan_mode_traced, run_with_plan_mode_traced, train_distributed,
    TrainRun,
};
pub use worker::{ExecMode, RankScratch, RankState, DEFAULT_CHUNK_ACTS};
