//! Minibatch SGD via SpMM — the §5.1 extension.
//!
//! "Instead of forwarding a single vector x^k between each consecutive
//! layer, multiple vectors can be simultaneously processed in batches …
//! The gradient vector δ^L in the final layer is computed as the averages
//! of gradients obtained over the vectors in the current batch. The SpBP
//! algorithm is executed in the same way, since a single gradient vector
//! is backpropagated." — we implement exactly that semantics: batched SpFF
//! (SpMM), a batch-averaged δ^L, and a single-vector SpBP driven by the
//! batch-mean activations. For batch = 1 this reduces bit-for-bit to the
//! per-sample step (tested).

use super::worker::{ExecMode, RankState, Repr};
use crate::comm::{Endpoint, Phase};
use crate::dnn::SparseNet;
use crate::partition::{CommPlan, DnnPartition};
use crate::runtime::parallel;

impl RankState {
    /// Batched forward on the **blocking** engine that also returns the
    /// per-layer **batch-mean** activation buffers (x̄^0..x̄^L), which
    /// drive the single-vector SpBP. `x0` row-major `[n0 × b]`. Panics on
    /// an overlap-mode state (its compact mirror lives in
    /// [`RankState::train_step_minibatch`]'s overlap arm).
    pub fn forward_batch_with_means(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        b: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>) {
        let depth = self.depth();
        let mut means: Vec<Vec<f32>> = Vec::with_capacity(depth + 1);
        let mut cur = vec![0f32; self.dims[0] * b];
        for &j in &self.input_rows {
            let j = j as usize;
            cur[j * b..(j + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        let blocks = match &self.repr {
            Repr::Full { blocks } => blocks,
            Repr::Split { .. } => {
                panic!("forward_batch_with_means requires ExecMode::Blocking")
            }
        };
        for k in 0..depth {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cf = self.codecs[k].0;
            self.timer.time("comm", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = ep.take_buf();
                    payload.reserve(t.indices.len() * b);
                    for &j in &t.indices {
                        let j = j as usize;
                        payload.extend_from_slice(&cur[j * b..(j + 1) * b]);
                    }
                    ep.send_encoded(t.to, k as u32, Phase::Forward, tid, 0, cf, payload);
                }
            });
            self.timer.time("wait", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.from, k as u32, Phase::Forward, tid);
                    let payload = ep.decode_payload(cf, payload);
                    for (i, &j) in t.indices.iter().enumerate() {
                        let j = j as usize;
                        cur[j * b..(j + 1) * b].copy_from_slice(&payload[i * b..(i + 1) * b]);
                    }
                    ep.recycle(payload);
                }
            });
            // x̄^{k}: mean input to weight layer k INCLUDING entries just
            // received — the weight update (∇W = δ ⊗ x̄) needs them.
            means.push(row_means(&cur, b));
            let blk = &blocks[k];
            let bias = &self.biases[k];
            let act = self.activation;
            let mut z = vec![0f32; blk.nrows * b];
            self.timer.time("spmv", || {
                blk.spmm_fused_rowmajor(&cur, &mut z, b, act.fused_bias_epilogue(bias));
            });
            let mut out = vec![0f32; self.dims[k + 1] * b];
            for (i, &r) in self.rows[k].iter().enumerate() {
                out[r as usize * b..(r as usize + 1) * b].copy_from_slice(&z[i * b..(i + 1) * b]);
            }
            // mean over the batch, only rows this rank knows (owned rows of
            // this layer); remote rows stay 0 and are neither read locally
            // nor part of δ (each rank only needs means of rows it owns or
            // received — received rows' means are recomputed from `cur` at
            // the next layer, which holds the received values).
            cur = out;
        }
        means.push(row_means(&cur, b)); // x̄^L (reporting only)
        (cur, means)
    }

    /// One minibatch SGD step (§5.1): batched SpFF + batch-averaged δ^L +
    /// single-vector SpBP over the batch-mean activations. Returns this
    /// rank's partial (batch-averaged) loss. Dispatches on the build mode.
    pub fn train_step_minibatch(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        y: &[f32],
        b: usize,
        eta: f32,
    ) -> f32 {
        match self.mode() {
            ExecMode::Blocking => self.train_step_minibatch_blocking(ep, plan, x0, y, b, eta),
            ExecMode::Overlap => self.train_step_overlap(ep, plan, x0, y, b, eta),
            ExecMode::Pipelined { .. } => self.train_step_pipelined(ep, plan, x0, y, b, eta),
        }
    }

    /// Blocking-engine minibatch step (the seed schedule).
    fn train_step_minibatch_blocking(
        &mut self,
        ep: &mut Endpoint,
        plan: &CommPlan,
        x0: &[f32],
        y: &[f32],
        b: usize,
        eta: f32,
    ) -> f32 {
        let depth = self.depth();
        let (xl, means) = self.forward_batch_with_means(ep, plan, x0, b);

        // δ^L averaged over the batch (Eq. 6, then mean over columns)
        let last_rows = self.rows[depth - 1].clone();
        let mut delta = Vec::with_capacity(last_rows.len());
        let mut local_loss = 0f32;
        let inv_b = 1.0 / b as f32;
        for &r in &last_rows {
            let r = r as usize;
            let mut d = 0f32;
            for j in 0..b {
                let xr = xl[r * b + j];
                let yr = y[r * b + j];
                local_loss += 0.5 * (xr - yr) * (xr - yr) * inv_b;
                d += (xr - yr) * self.activation.derivative_from_output(xr);
            }
            delta.push(d * inv_b);
        }

        // single-vector SpBP over mean activations (paper §5.1)
        let blocks = match &mut self.repr {
            Repr::Full { blocks } => blocks,
            Repr::Split { .. } => unreachable!("dispatched on Full"),
        };
        for k in (0..depth).rev() {
            let lp = &plan.layers[k];
            let me = self.rank as usize;
            let cb = self.codecs[k].1;
            let mut s = vec![0f32; blocks[k].ncols];
            self.timer.time("spmv", || {
                blocks[k].spmv_t_add(&delta, &mut s);
            });
            self.timer.time("comm", || {
                for &tid in &lp.recv_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let mut payload = ep.take_buf();
                    payload.extend(t.indices.iter().map(|&j| s[j as usize]));
                    ep.send_encoded(t.from, k as u32, Phase::Backward, tid, 0, cb, payload);
                }
            });
            if let Some(gr) = self.collect.as_mut() {
                // collect mode: record the gradient instead of updating —
                // the replica driver exchanges and applies it after the step
                self.timer.time("updt", || {
                    gr[k].clear();
                    blocks[k].outer_grad(&delta, &means[k], &mut gr[k]);
                    gr[k].extend_from_slice(&delta);
                });
            } else {
                self.timer.time("updt", || {
                    blocks[k].sgd_update(&delta, &means[k], eta);
                });
                for (i, d) in delta.iter().enumerate() {
                    self.biases[k][i] -= eta * d;
                }
            }
            self.timer.time("wait", || {
                for &tid in &lp.send_of[me] {
                    let t = &lp.transfers[tid as usize];
                    let payload = ep.recv(t.to, k as u32, Phase::Backward, tid);
                    let payload = ep.decode_payload(cb, payload);
                    for (i, &j) in t.indices.iter().enumerate() {
                        s[j as usize] += payload[i];
                    }
                    ep.recycle(payload);
                }
            });
            if k > 0 {
                let owned = self.rows[k - 1].clone();
                let mut next = Vec::with_capacity(owned.len());
                for &j in owned.iter() {
                    let yj = means[k][j as usize];
                    next.push(s[j as usize] * self.activation.derivative_from_output(yj));
                }
                delta = next;
            }
        }
        local_loss
    }
}

/// Row means of a row-major `[n × b]` buffer (shared with the overlapped
/// engine, which feeds it compact activations and retained payloads).
pub(crate) fn row_means(x: &[f32], b: usize) -> Vec<f32> {
    let n = x.len() / b;
    let inv = 1.0 / b as f32;
    (0..n)
        .map(|r| x[r * b..(r + 1) * b].iter().sum::<f32>() * inv)
        .collect()
}

/// Minibatch training driver: consumes the dataset in batches of `b`.
pub fn train_distributed_minibatch(
    net: &SparseNet,
    part: &DnnPartition,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    b: usize,
    eta: f32,
    epochs: usize,
) -> super::sgd::TrainRun {
    let structure: Vec<_> = net.layers.clone();
    part.validate(&structure).expect("invalid partition");
    let plan = CommPlan::build(&structure, part);
    train_minibatch_with_plan(net, part, &plan, inputs, targets, b, eta, epochs)
}

/// [`train_distributed_minibatch`] over a caller-provided plan — the
/// codec-aware drivers build the plan once, set per-phase wire codecs on
/// it, and train through here.
#[allow(clippy::too_many_arguments)]
pub fn train_minibatch_with_plan(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    b: usize,
    eta: f32,
    epochs: usize,
) -> super::sgd::TrainRun {
    assert_eq!(inputs.len(), targets.len());
    let nparts = part.nparts;
    let nbatches = inputs.len() / b;
    let steps = nbatches * epochs;
    let n0 = net.input_dim();
    let nl = net.output_dim();

    // pack batches once (row-major [dim × b])
    let pack = |vecs: &[Vec<f32>], dim: usize, lo: usize| -> Vec<f32> {
        let mut out = vec![0f32; dim * b];
        for (j, v) in vecs[lo..lo + b].iter().enumerate() {
            for i in 0..dim {
                out[i * b + j] = v[i];
            }
        }
        out
    };
    let xbatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(inputs, n0, i * b)).collect();
    let ybatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(targets, nl, i * b)).collect();

    let run = parallel::run_ranks(nparts, |rank, ep| {
        let mut state = RankState::build(net, part, plan, rank as u32, ExecMode::Overlap);
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..epochs {
            for (x, y) in xbatches.iter().zip(ybatches.iter()) {
                losses.push(state.train_step_minibatch(ep, plan, x, y, b, eta));
            }
        }
        (state, losses)
    })
    .unwrap_or_else(|f| panic!("distributed minibatch training failed: {f}"));

    let timer = run.merged_timer(|(state, _)| &state.timer);
    let sent = run.sent;
    let mut out = net.clone();
    let mut losses = vec![0f32; steps];
    for (state, local) in run.outputs {
        state.merge_into(&mut out);
        for (i, l) in local.into_iter().enumerate() {
            losses[i] += l;
        }
    }
    super::sgd::TrainRun {
        net: out,
        losses,
        sent,
        timer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sgd::train_distributed;
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate, RadixNetConfig};
    use crate::util::Rng;

    fn setup() -> (SparseNet, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let net = generate(&RadixNetConfig::graph_challenge(64, 4).unwrap());
        let mut rng = Rng::new(3);
        let inputs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..64).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let mut y = vec![0f32; 64];
                y[i % 10] = 1.0;
                y
            })
            .collect();
        (net, inputs, targets)
    }

    #[test]
    fn batch_one_equals_per_sample_step() {
        let (net, inputs, targets) = setup();
        let part = random_partition(&net.layers, 4, 1);
        let a = train_distributed_minibatch(&net, &part, &inputs, &targets, 1, 0.3, 1);
        let bnet = train_distributed(&net, &part, &inputs, &targets, 0.3, 1);
        for (x, y) in a.losses.iter().zip(bnet.losses.iter()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
        for k in 0..net.depth() {
            for (u, v) in a.net.layers[k].vals.iter().zip(bnet.net.layers[k].vals.iter()) {
                assert!((u - v).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn minibatch_reduces_loss() {
        let (net, inputs, targets) = setup();
        let part = random_partition(&net.layers, 3, 2);
        let run = train_distributed_minibatch(&net, &part, &inputs, &targets, 4, 0.8, 40);
        let first = run.losses[0];
        let last = *run.losses.last().unwrap();
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn minibatch_comm_volume_scales_with_batch() {
        let (net, inputs, targets) = setup();
        let part = random_partition(&net.layers, 4, 1);
        let plan = CommPlan::build(&net.layers, &part);
        let run = train_distributed_minibatch(&net, &part, &inputs, &targets, 4, 0.1, 1);
        // fwd words × batch + bwd words × 1 (single averaged gradient)
        let fwd_send = plan.fwd_send_volume_per_rank();
        let fwd_recv = plan.fwd_recv_volume_per_rank();
        let steps = 2u64; // 8 inputs / batch 4
        for r in 0..4usize {
            let expect = steps * (4 * fwd_send[r] + fwd_recv[r]);
            assert_eq!(run.sent[r].0, expect, "rank {r}");
        }
    }

    #[test]
    fn minibatch_same_answer_any_rank_count() {
        let (net, inputs, targets) = setup();
        let p2 = random_partition(&net.layers, 2, 5);
        let p8 = random_partition(&net.layers, 8, 6);
        let a = train_distributed_minibatch(&net, &p2, &inputs, &targets, 4, 0.2, 2);
        let b = train_distributed_minibatch(&net, &p8, &inputs, &targets, 4, 0.2, 2);
        for (x, y) in a.losses.iter().zip(b.losses.iter()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        for k in 0..net.depth() {
            for (u, v) in a.net.layers[k].vals.iter().zip(b.net.layers[k].vals.iter()) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }
}
