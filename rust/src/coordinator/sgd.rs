//! Live distributed SGD driver over the shared-memory parallel engine
//! ([`crate::runtime::parallel`]): one OS thread per rank runs the full
//! Alg. 2 + Alg. 3 schedule concurrently.
//!
//! The driver is the "leader": it carves the model into rank states, hands
//! the engine a per-rank worker, reduces losses, merges the trained row
//! blocks back into a global model, and cross-checks the live
//! communication counters against the precomputed [`CommPlan`].

use super::worker::{ExecMode, RankState};
use crate::dnn::SparseNet;
use crate::obs::{TraceMode, Tracer};
use crate::partition::{CommPlan, DnnPartition};
use crate::runtime::parallel;
use crate::util::PhaseTimer;

/// Result of a distributed training run.
pub struct TrainRun {
    /// The trained model (row blocks merged back).
    pub net: SparseNet,
    /// Per-step global losses.
    pub losses: Vec<f32>,
    /// Per-rank (words, messages) actually sent — must equal the plan.
    pub sent: Vec<(u64, u64)>,
    /// Merged per-phase timers (sum over ranks).
    pub timer: PhaseTimer,
}

/// Train `net` on `(inputs, targets)` for `epochs` passes with `nparts`
/// live ranks. Panics if the partition is invalid for the model.
pub fn train_distributed(
    net: &SparseNet,
    part: &DnnPartition,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    eta: f32,
    epochs: usize,
) -> TrainRun {
    let structure: Vec<_> = net.layers.clone();
    part.validate(&structure).expect("invalid partition");
    let plan = CommPlan::build(&structure, part);
    run_with_plan(net, part, &plan, inputs, targets, eta, epochs)
}

/// Same as [`train_distributed`] with a caller-provided plan (overlapped
/// engine).
pub fn run_with_plan(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    eta: f32,
    epochs: usize,
) -> TrainRun {
    run_with_plan_mode(net, part, plan, inputs, targets, eta, epochs, ExecMode::Overlap)
}

/// [`run_with_plan`] with an explicit execution mode — the live
/// blocking-vs-overlap breakdown (Fig. 5 live section) trains the same
/// model both ways and compares the per-phase timers.
#[allow(clippy::too_many_arguments)]
pub fn run_with_plan_mode(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    eta: f32,
    epochs: usize,
    mode: ExecMode,
) -> TrainRun {
    run_with_plan_mode_traced(
        net,
        part,
        plan,
        inputs,
        targets,
        eta,
        epochs,
        mode,
        TraceMode::from_env(),
    )
    .0
}

/// [`run_with_plan_mode`] with an explicit [`TraceMode`], returning the
/// per-rank flight recorders alongside the run — the `spdnn trace` CLI
/// and the trace tests drive this directly instead of going through the
/// `SPDNN_TRACE` environment contract.
#[allow(clippy::too_many_arguments)]
pub fn run_with_plan_mode_traced(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    eta: f32,
    epochs: usize,
    mode: ExecMode,
    trace: TraceMode,
) -> (TrainRun, Vec<Tracer>) {
    assert_eq!(inputs.len(), targets.len());
    let nparts = part.nparts;
    let steps = inputs.len() * epochs;

    let run = parallel::run_ranks(nparts, |rank, ep| {
        let mut state = RankState::build_traced(net, part, plan, rank as u32, mode, trace);
        let mut local_losses = Vec::with_capacity(steps);
        for _ in 0..epochs {
            for (x, y) in inputs.iter().zip(targets.iter()) {
                local_losses.push(state.train_step(ep, plan, x, y, eta));
            }
        }
        (state, local_losses)
    })
    .unwrap_or_else(|f| panic!("distributed training failed: {f}"));

    // merge blocks, reduce losses & timers (engine-aggregated)
    let timer: PhaseTimer = run.merged_timer(|(state, _)| &state.timer);
    let sent = run.sent;
    let mut out = net.clone();
    let mut losses = vec![0f32; steps];
    let mut tracers = Vec::with_capacity(nparts);
    for (mut state, local_losses) in run.outputs {
        tracers.push(std::mem::take(&mut state.tracer));
        state.merge_into(&mut out);
        for (i, l) in local_losses.into_iter().enumerate() {
            losses[i] += l;
        }
    }
    (
        TrainRun {
            net: out,
            losses,
            sent,
            timer,
        },
        tracers,
    )
}

/// Distributed batched inference (H-SpFF with SpMM): returns the output
/// `[nL × b]` row-major matrix plus per-rank counters.
pub fn infer_distributed(
    net: &SparseNet,
    part: &DnnPartition,
    x0: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<(u64, u64)>) {
    let structure: Vec<_> = net.layers.clone();
    part.validate(&structure).expect("invalid partition");
    let plan = CommPlan::build(&structure, part);
    infer_with_plan(net, part, &plan, x0, b)
}

/// Same as [`infer_distributed`] with a caller-provided plan — the serving
/// path reuses one plan across requests (plans never change per input).
///
/// This one-shot form builds each rank's state and runs the same
/// [`RankState::infer_owned_outputs`] body the persistent
/// [`crate::serving::RankPool`] dispatches to its long-lived rank threads.
/// Runs the overlapped split-CSR engine.
pub fn infer_with_plan(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    x0: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<(u64, u64)>) {
    infer_with_plan_mode(net, part, plan, x0, b, ExecMode::Overlap)
}

/// [`infer_with_plan`] with an explicit execution mode — the
/// overlap-vs-blocking throughput section of `benches/table2_throughput`
/// measures both engines over the same plan.
pub fn infer_with_plan_mode(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    x0: &[f32],
    b: usize,
    mode: ExecMode,
) -> (Vec<f32>, Vec<(u64, u64)>) {
    let (out, sent, _) =
        infer_with_plan_mode_traced(net, part, plan, x0, b, mode, TraceMode::from_env());
    (out, sent)
}

/// [`infer_with_plan_mode`] with an explicit [`TraceMode`], returning the
/// per-rank flight recorders alongside the output — each tracer's spans
/// reconstruct that rank's send/compute/recv interleaving for the layer
/// schedule that produced the result.
pub fn infer_with_plan_mode_traced(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    x0: &[f32],
    b: usize,
    mode: ExecMode,
    trace: TraceMode,
) -> (Vec<f32>, Vec<(u64, u64)>, Vec<Tracer>) {
    let nparts = part.nparts;
    let run = parallel::run_ranks(nparts, |rank, ep| {
        let mut state = RankState::build_traced(net, part, plan, rank as u32, mode, trace);
        let mut scratch = crate::coordinator::worker::RankScratch::new();
        let rows = state.infer_owned_outputs(ep, plan, x0, b, &mut scratch);
        (rows, std::mem::take(&mut state.tracer))
    })
    .unwrap_or_else(|f| panic!("distributed inference failed: {f}"));

    let mut rows = Vec::with_capacity(nparts);
    let mut tracers = Vec::with_capacity(nparts);
    for (r, t) in run.outputs {
        rows.push(r);
        tracers.push(t);
    }
    let output = assemble_outputs(net.output_dim(), b, &rows);
    (output, run.sent, tracers)
}

/// Scatter per-rank owned output rows into the global `[nL × b]` row-major
/// matrix — the driver-side half of the inference rank body, shared by the
/// one-shot path above and the serving pool's batch completion.
pub fn assemble_outputs(nl: usize, b: usize, rank_rows: &[Vec<(u32, Vec<f32>)>]) -> Vec<f32> {
    let mut output = vec![0f32; nl * b];
    for rows in rank_rows {
        for (r, vals) in rows {
            let r = *r as usize;
            output[r * b..(r + 1) * b].copy_from_slice(vals);
        }
    }
    output
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::sgd_serial;
    use crate::partition::phases::{hypergraph_partition, PhaseConfig};
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate, RadixNetConfig};

    fn small_net() -> SparseNet {
        let cfg = RadixNetConfig {
            radices: vec![4, 4],
            layers: 4,
            seed: 17,
            ..RadixNetConfig::default()
        };
        generate(&cfg)
    }

    fn dataset(n: usize, dim: usize, out: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut rng = crate::util::Rng::new(5);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let mut y = vec![0f32; out];
                y[i % out] = 1.0;
                y
            })
            .collect();
        (inputs, targets)
    }

    /// THE equivalence test: distributed == serial for any partition / P.
    #[test]
    fn distributed_matches_serial_random_partition() {
        let net = small_net();
        let (inputs, targets) = dataset(6, 16, 16);
        for &p in &[2usize, 3, 4, 8] {
            let part = random_partition(&net.layers, p, 7 + p as u64);
            let run = train_distributed(&net, &part, &inputs, &targets, 0.3, 2);
            let mut serial = net.clone();
            let serial_losses =
                sgd_serial::train(&mut serial, &inputs, &targets, 0.3, 2);
            for (a, b) in run.losses.iter().zip(serial_losses.iter()) {
                assert!((a - b).abs() < 1e-4, "P={p}: loss {a} vs serial {b}");
            }
            for k in 0..net.depth() {
                for (a, b) in run.net.layers[k]
                    .vals
                    .iter()
                    .zip(serial.layers[k].vals.iter())
                {
                    assert!((a - b).abs() < 1e-4, "P={p} layer {k}: {a} vs {b}");
                }
                for (a, b) in run.net.biases[k].iter().zip(serial.biases[k].iter()) {
                    assert!((a - b).abs() < 1e-4, "P={p} layer {k} bias");
                }
            }
        }
    }

    #[test]
    fn distributed_matches_serial_hypergraph_partition() {
        let net = small_net();
        let (inputs, targets) = dataset(4, 16, 16);
        let part = hypergraph_partition(&net.layers, &PhaseConfig::new(4));
        let run = train_distributed(&net, &part, &inputs, &targets, 0.5, 1);
        let mut serial = net.clone();
        let sl = sgd_serial::train(&mut serial, &inputs, &targets, 0.5, 1);
        for (a, b) in run.losses.iter().zip(sl.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for k in 0..net.depth() {
            for (a, b) in run.net.layers[k]
                .vals
                .iter()
                .zip(serial.layers[k].vals.iter())
            {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    /// Live counters exactly match the precomputed plan (both directions of
    /// the mirror argument of Section 4.2).
    #[test]
    fn live_counters_match_plan() {
        let net = small_net();
        let (inputs, targets) = dataset(3, 16, 16);
        let part = random_partition(&net.layers, 4, 3);
        let plan = CommPlan::build(&net.layers, &part);
        let run = run_with_plan(&net, &part, &plan, &inputs, &targets, 0.1, 1);
        let fwd_send = plan.fwd_send_volume_per_rank();
        let fwd_recv = plan.fwd_recv_volume_per_rank();
        let fwd_smsg = plan.fwd_send_msgs_per_rank();
        let fwd_rmsg = plan.fwd_recv_msgs_per_rank();
        let steps = inputs.len() as u64;
        for r in 0..4usize {
            let expect_words = steps * (fwd_send[r] + fwd_recv[r]);
            let expect_msgs = steps * (fwd_smsg[r] + fwd_rmsg[r]);
            assert_eq!(run.sent[r].0, expect_words, "rank {r} words");
            assert_eq!(run.sent[r].1, expect_msgs, "rank {r} msgs");
        }
    }

    #[test]
    fn distributed_inference_matches_serial_batch() {
        let net = small_net();
        let b = 5;
        let mut rng = crate::util::Rng::new(9);
        let x0: Vec<f32> = (0..16 * b)
            .map(|_| if rng.gen_bool(0.4) { 1.0 } else { 0.0 })
            .collect();
        let serial = crate::dnn::inference::infer_batch(&net, &x0, b);
        for &p in &[2usize, 4] {
            let part = random_partition(&net.layers, p, 1);
            let (out, _) = infer_distributed(&net, &part, &x0, b);
            for (a, s) in out.iter().zip(serial.iter()) {
                assert!((a - s).abs() < 1e-5, "P={p}");
            }
        }
    }

    #[test]
    fn loss_decreases_under_distributed_training() {
        let net = small_net();
        let (inputs, targets) = dataset(8, 16, 16);
        let part = random_partition(&net.layers, 4, 2);
        let run = train_distributed(&net, &part, &inputs, &targets, 0.5, 30);
        let first: f32 = run.losses[..8].iter().sum();
        let last: f32 = run.losses[run.losses.len() - 8..].iter().sum();
        assert!(last < first * 0.7, "loss {first} -> {last}");
    }
}
