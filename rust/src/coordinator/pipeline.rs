//! The split-CSR **pipelined** execution engine ([`ExecMode::Pipelined`]):
//! send-side row-range pipelining on top of the overlap layout.
//!
//! The overlap engine ([`ExecMode::Overlap`]) hides the *receive* wait
//! behind local-segment compute, but every outbound payload still waits
//! for the whole previous layer to finish — the sender side of Alg. 2's
//! SpMV pipeline stays bulk-synchronous. This engine fixes that: at build
//! time each layer's rows are regrouped so **boundary rows** (rows whose
//! activations feed a remote destination in the next layer) are packed
//! first, grouped per outbound chunk ([`crate::sparse::regroup_rows`]),
//! and every layer step runs:
//!
//! 1. local-segment pass over the **boundary rows only**;
//! 2. drain inbound chunk payloads — each applied to the boundary rows
//!    the moment it lands (non-blocking first, then in arrival order),
//!    with **interior local tiles computed between polls** so the rank is
//!    never idle while payloads are in flight;
//! 3. **each outbound chunk of the next layer posts the moment its own
//!    `ready` prefix is final** (the prefix lengths `regroup_rows`
//!    computes): rows below every pending segment's first nonzero have
//!    all contributions in, so the epilogue advances to the chunk's ready
//!    point and its payload goes out — earliest-finished chunks leave
//!    while later boundary rows (and all interior rows) are still
//!    uncomputed, so peers' receives overlap this rank's remaining work;
//! 4. finish the interior local rows, apply every payload's interior
//!    contribution, interior epilogue.
//!
//! The backward mirror posts each remote segment's partial gradient as
//! the same sub-transfer chunks *before* the weight-update window, and
//! drains the mirrored gradient receives behind it in arrival order.
//! Layer-0 sends (the network input is available immediately) post at the
//! very start of the step.
//!
//! Like the overlap twins, the inference step here and the retaining one
//! in `RankState::train_step_pipelined` are intentional mirrors — a
//! change to the send/drain schedule in one must be mirrored in the other.

use super::minibatch::row_means;
use super::worker::{ChunkSend, RankScratch, RankState, Repr, SplitLayer};
use crate::comm::{Endpoint, Phase, Want};
use crate::obs::NO_CHUNK;
use crate::partition::CommPlan;

/// Interior rows computed per tile between receive polls: small enough to
/// notice a landing payload quickly, large enough to amortize the sweep.
const INTERIOR_TILE_ROWS: usize = 64;

impl RankState {
    /// Pipelined batched forward over compact activations (permuted,
    /// boundary-first row layout per layer; the last layer keeps its
    /// original order). Returns the final layer's owned rows
    /// `[local_L × b]` row-major, borrowed from `scratch.ping`.
    pub(crate) fn infer_pipelined_compact<'s>(
        &mut self,
        ep: &mut Endpoint,
        _plan: &CommPlan, // schedule is fully precompiled into the split layers
        x0: &[f32],
        b: usize,
        scratch: &'s mut RankScratch,
    ) -> &'s [f32] {
        let depth = self.depth();
        let maxcompact = self
            .input_rows
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        scratch.ensure(maxcompact * b, 0);
        for (i, &j) in self.input_rows.iter().enumerate() {
            let j = j as usize;
            scratch.ping[i * b..(i + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        let layers = match &self.repr {
            Repr::Split { layers } => layers,
            Repr::Full { .. } => unreachable!("pipelined path dispatched on Split"),
        };
        let input_sends = &self.input_sends;
        for (k, sl) in layers.iter().enumerate().take(depth) {
            let pipe = sl.pipe.as_ref().expect("pipelined layer schedule");
            let inw = sl.mat.local_gcols.len();
            let nloc = sl.mat.nrows;
            let nb = pipe.boundary_end;
            let cf = self.codecs[k].0;
            // outbound chunks posted during this layer are tagged k+1 and
            // decoded by the receiver with THAT layer's forward codec
            let cf_next = self.codecs.get(k + 1).map_or(cf, |c| c.0);
            // 0. layer 0 only: the input vector is available the moment the
            // step starts — post its outbound chunks immediately. Deeper
            // layers' inputs were posted during the previous layer's step.
            if k == 0 {
                let cur = &scratch.ping[..inw * b];
                let sp = self.tracer.start();
                let mut moved = 0u64;
                self.timer.time("comm", || {
                    for s in input_sends {
                        let mut payload = ep.take_buf();
                        payload.reserve(s.pos.len() * b);
                        for &p in &s.pos {
                            let p = p as usize;
                            payload.extend_from_slice(&cur[p * b..(p + 1) * b]);
                        }
                        moved += 4 * payload.len() as u64;
                        ep.send_encoded(s.to, 0, Phase::Forward, s.tid, s.chunk, cf, payload);
                    }
                });
                self.tracer.end(sp, "send", "fwd", 0, NO_CHUNK, moved);
            }
            // 1. local pass over the boundary rows only
            {
                let x = &scratch.ping[..inw * b];
                let z = &mut scratch.pong[..nloc * b];
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    sl.mat.local.spmm_fused_range_rowmajor(x, z, b, 0, nb, |_, _| {});
                });
                self.tracer.end(sp, "spmv.boundary", "fwd", k as u32, NO_CHUNK, 0);
            }
            // 2. drain arrivals / interleave interior tiles / post outbound
            scratch.wants.clear();
            scratch.want_seg.clear();
            for (si, &w) in sl.recv_wants.iter().enumerate() {
                scratch.wants.push(w);
                scratch.want_seg.push(si);
            }
            scratch.held.clear();
            scratch.held.resize_with(sl.mat.remote.len(), || None);
            let mut interior_done = nb;
            let mut epi_done = 0usize;
            let mut next_post = 0usize;
            loop {
                // 3. each outbound chunk posts the moment *its* `ready`
                // prefix is final: every row below the smallest pending
                // segment's first nonzero has all contributions in, so the
                // epilogue extends up to the chunk's ready point and the
                // payload gathers activated values — interior rows (and
                // later chunks' rows) are still uncomputed at this point.
                let safe = scratch
                    .want_seg
                    .iter()
                    .map(|&si| pipe.seg_first_row[si])
                    .fold(nb, usize::min);
                while next_post < pipe.out_sends.len() && pipe.ready[next_post] <= safe {
                    let upto = pipe.ready[next_post];
                    if epi_done < upto {
                        let z = &mut scratch.pong[..nloc * b];
                        let bias = &self.biases[k];
                        let act = self.activation;
                        let perm = &pipe.perm;
                        let sp = self.tracer.start();
                        self.timer.time("spmv", || {
                            let mut epi = act.fused_bias_epilogue(bias);
                            for r in epi_done..upto {
                                epi(perm[r] as usize, &mut z[r * b..(r + 1) * b]);
                            }
                        });
                        self.tracer
                            .end(sp, "epilogue.boundary", "fwd", k as u32, NO_CHUNK, 0);
                        epi_done = upto;
                    }
                    let s = &pipe.out_sends[next_post];
                    let z = &scratch.pong[..nloc * b];
                    let sp = self.tracer.start();
                    let mut moved = 0u64;
                    self.timer.time("comm", || {
                        let mut payload = ep.take_buf();
                        payload.reserve(s.pos.len() * b);
                        for &p in &s.pos {
                            let p = p as usize;
                            payload.extend_from_slice(&z[p * b..(p + 1) * b]);
                        }
                        moved = 4 * payload.len() as u64;
                        ep.send_encoded(
                            s.to,
                            (k + 1) as u32,
                            Phase::Forward,
                            s.tid,
                            s.chunk,
                            cf_next,
                            payload,
                        );
                    });
                    self.tracer.end(sp, "post", "fwd", k as u32, s.chunk, moved);
                    next_post += 1;
                }
                if scratch.wants.is_empty() {
                    break;
                }
                // non-blocking sweep of everything already here
                let mut progressed = false;
                let mut i = 0;
                while i < scratch.wants.len() {
                    let (src, tid, chunk) = scratch.wants[i];
                    if let Some(payload) =
                        ep.try_recv_chunk(src, k as u32, Phase::Forward, tid, chunk)
                    {
                        let payload = ep.decode_payload(cf, payload);
                        let si = scratch.want_seg[i];
                        scratch.wants.swap_remove(i);
                        scratch.want_seg.swap_remove(i);
                        let z = &mut scratch.pong[..nloc * b];
                        let seg = &sl.mat.remote[si].csr;
                        let sp = self.tracer.start();
                        self.timer
                            .time("spmv", || seg.spmm_add_range_rowmajor(&payload, z, b, 0, nb));
                        self.tracer
                            .end(sp, "spmv.seg", "fwd", k as u32, chunk, 4 * payload.len() as u64);
                        scratch.held[si] = Some(payload);
                        progressed = true;
                    } else {
                        i += 1;
                    }
                }
                if progressed {
                    continue; // recheck the post condition first
                }
                // nothing has landed: compute an interior tile between
                // polls, or block once the interior is exhausted
                if interior_done < nloc {
                    let hi = (interior_done + INTERIOR_TILE_ROWS).min(nloc);
                    let x = &scratch.ping[..inw * b];
                    let z = &mut scratch.pong[..nloc * b];
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        sl.mat
                            .local
                            .spmm_fused_range_rowmajor(x, z, b, interior_done, hi, |_, _| {});
                    });
                    self.tracer
                        .end(sp, "spmv.interior", "fwd", k as u32, NO_CHUNK, 0);
                    interior_done = hi;
                    continue;
                }
                let sp = self.tracer.start();
                let (i, payload) = {
                    let wants = &scratch.wants;
                    self.timer
                        .time("wait", || ep.recv_any(k as u32, Phase::Forward, wants))
                };
                self.tracer
                    .end(sp, "wait", "fwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                let payload = ep.decode_payload(cf, payload);
                let si = scratch.want_seg[i];
                let chunk = scratch.wants[i].2;
                scratch.wants.swap_remove(i);
                scratch.want_seg.swap_remove(i);
                let z = &mut scratch.pong[..nloc * b];
                let seg = &sl.mat.remote[si].csr;
                let sp = self.tracer.start();
                self.timer
                    .time("spmv", || seg.spmm_add_range_rowmajor(&payload, z, b, 0, nb));
                self.tracer
                    .end(sp, "spmv.seg", "fwd", k as u32, chunk, 4 * payload.len() as u64);
                scratch.held[si] = Some(payload);
            }
            // finish the boundary epilogue over rows no outbound chunk
            // gathered (every want has drained, so the whole block is final)
            if epi_done < nb {
                let z = &mut scratch.pong[..nloc * b];
                let bias = &self.biases[k];
                let act = self.activation;
                let perm = &pipe.perm;
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    let mut epi = act.fused_bias_epilogue(bias);
                    for r in epi_done..nb {
                        epi(perm[r] as usize, &mut z[r * b..(r + 1) * b]);
                    }
                });
                self.tracer
                    .end(sp, "epilogue.boundary", "fwd", k as u32, NO_CHUNK, 0);
            }
            // 4. finish interior local rows, add every payload's interior
            // contribution, interior epilogue
            if interior_done < nloc {
                let x = &scratch.ping[..inw * b];
                let z = &mut scratch.pong[..nloc * b];
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    sl.mat
                        .local
                        .spmm_fused_range_rowmajor(x, z, b, interior_done, nloc, |_, _| {});
                });
                self.tracer
                    .end(sp, "spmv.interior", "fwd", k as u32, NO_CHUNK, 0);
            }
            for (si, held) in scratch.held.iter_mut().enumerate() {
                if let Some(payload) = held.take() {
                    let z = &mut scratch.pong[..nloc * b];
                    let seg = &sl.mat.remote[si];
                    let sp = self.tracer.start();
                    self.timer
                        .time("spmv", || seg.csr.spmm_add_range_rowmajor(&payload, z, b, nb, nloc));
                    self.tracer.end(sp, "spmv.seg", "fwd", k as u32, seg.chunk, 0);
                    ep.recycle(payload);
                }
            }
            {
                let z = &mut scratch.pong[..nloc * b];
                let bias = &self.biases[k];
                let act = self.activation;
                let perm = &pipe.perm;
                let sp = self.tracer.start();
                self.timer.time("spmv", || {
                    let mut epi = act.fused_bias_epilogue(bias);
                    for r in nb..nloc {
                        epi(perm[r] as usize, &mut z[r * b..(r + 1) * b]);
                    }
                });
                self.tracer
                    .end(sp, "epilogue.interior", "fwd", k as u32, NO_CHUNK, 0);
            }
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
        }
        &scratch.ping[..self.rows[depth - 1].len() * b]
    }

    /// Pipelined minibatch train step (§5.1 semantics, like
    /// [`RankState::train_step_overlap`] — `b = 1` is the per-sample
    /// step). Forward retains the permuted-layout activations and the
    /// received chunk payloads for the update; backward posts each chunk's
    /// partial gradient before the update window and drains the mirrored
    /// receives behind it. Returns this rank's partial (batch-averaged)
    /// loss.
    pub(crate) fn train_step_pipelined(
        &mut self,
        ep: &mut Endpoint,
        _plan: &CommPlan, // schedule is fully precompiled into the split layers
        x0: &[f32],
        y: &[f32],
        b: usize,
        eta: f32,
    ) -> f32 {
        let depth = self.depth();

        // ---- pipelined forward, retaining per-layer activations (in each
        // layer's permuted row layout) and the received payloads; mirrors
        // `infer_pipelined_compact` — keep the two in sync ----
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(depth + 1);
        let mut payloads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(depth);
        let mut a0 = vec![0f32; self.input_rows.len() * b];
        for (i, &j) in self.input_rows.iter().enumerate() {
            let j = j as usize;
            a0[i * b..(i + 1) * b].copy_from_slice(&x0[j * b..(j + 1) * b]);
        }
        acts.push(a0);
        {
            let layers = match &self.repr {
                Repr::Split { layers } => layers,
                Repr::Full { .. } => unreachable!("pipelined path dispatched on Split"),
            };
            let input_sends = &self.input_sends;
            for (k, sl) in layers.iter().enumerate().take(depth) {
                let pipe = sl.pipe.as_ref().expect("pipelined layer schedule");
                let nloc = sl.mat.nrows;
                let nb = pipe.boundary_end;
                let cf = self.codecs[k].0;
                let cf_next = self.codecs.get(k + 1).map_or(cf, |c| c.0);
                let mut z = vec![0f32; nloc * b];
                if k == 0 {
                    let cur = &acts[0];
                    let sp = self.tracer.start();
                    let mut moved = 0u64;
                    self.timer.time("comm", || {
                        for s in input_sends {
                            let mut payload = ep.take_buf();
                            payload.reserve(s.pos.len() * b);
                            for &p in &s.pos {
                                let p = p as usize;
                                payload.extend_from_slice(&cur[p * b..(p + 1) * b]);
                            }
                            moved += 4 * payload.len() as u64;
                            ep.send_encoded(s.to, 0, Phase::Forward, s.tid, s.chunk, cf, payload);
                        }
                    });
                    self.tracer.end(sp, "send", "fwd", 0, NO_CHUNK, moved);
                }
                {
                    let cur = &acts[k];
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        sl.mat.local.spmm_fused_range_rowmajor(cur, &mut z, b, 0, nb, |_, _| {});
                    });
                    self.tracer
                        .end(sp, "spmv.boundary", "fwd", k as u32, NO_CHUNK, 0);
                }
                let nsegs = sl.mat.remote.len();
                let mut lay_payloads: Vec<Vec<f32>> = vec![Vec::new(); nsegs];
                let mut wants: Vec<Want> = sl.recv_wants.clone();
                let mut want_seg: Vec<usize> = (0..nsegs).collect();
                let mut interior_done = nb;
                let mut epi_done = 0usize;
                let mut next_post = 0usize;
                loop {
                    // each outbound chunk posts the moment its `ready`
                    // prefix is final — see `infer_pipelined_compact`
                    let safe = want_seg
                        .iter()
                        .map(|&si| pipe.seg_first_row[si])
                        .fold(nb, usize::min);
                    while next_post < pipe.out_sends.len() && pipe.ready[next_post] <= safe {
                        let upto = pipe.ready[next_post];
                        if epi_done < upto {
                            let bias = &self.biases[k];
                            let act = self.activation;
                            let perm = &pipe.perm;
                            let zb = &mut z;
                            let sp = self.tracer.start();
                            self.timer.time("spmv", || {
                                let mut epi = act.fused_bias_epilogue(bias);
                                for r in epi_done..upto {
                                    epi(perm[r] as usize, &mut zb[r * b..(r + 1) * b]);
                                }
                            });
                            self.tracer
                                .end(sp, "epilogue.boundary", "fwd", k as u32, NO_CHUNK, 0);
                            epi_done = upto;
                        }
                        let s = &pipe.out_sends[next_post];
                        let zr = &z;
                        let sp = self.tracer.start();
                        let mut moved = 0u64;
                        self.timer.time("comm", || {
                            let mut payload = ep.take_buf();
                            payload.reserve(s.pos.len() * b);
                            for &p in &s.pos {
                                let p = p as usize;
                                payload.extend_from_slice(&zr[p * b..(p + 1) * b]);
                            }
                            moved = 4 * payload.len() as u64;
                            ep.send_encoded(
                                s.to,
                                (k + 1) as u32,
                                Phase::Forward,
                                s.tid,
                                s.chunk,
                                cf_next,
                                payload,
                            );
                        });
                        self.tracer.end(sp, "post", "fwd", k as u32, s.chunk, moved);
                        next_post += 1;
                    }
                    if wants.is_empty() {
                        break;
                    }
                    let mut progressed = false;
                    let mut i = 0;
                    while i < wants.len() {
                        let (src, tid, chunk) = wants[i];
                        if let Some(payload) =
                            ep.try_recv_chunk(src, k as u32, Phase::Forward, tid, chunk)
                        {
                            let payload = ep.decode_payload(cf, payload);
                            let si = want_seg[i];
                            wants.swap_remove(i);
                            want_seg.swap_remove(i);
                            let seg = &sl.mat.remote[si].csr;
                            let sp = self.tracer.start();
                            self.timer.time("spmv", || {
                                seg.spmm_add_range_rowmajor(&payload, &mut z, b, 0, nb)
                            });
                            self.tracer.end(
                                sp,
                                "spmv.seg",
                                "fwd",
                                k as u32,
                                chunk,
                                4 * payload.len() as u64,
                            );
                            lay_payloads[si] = payload;
                            progressed = true;
                        } else {
                            i += 1;
                        }
                    }
                    if progressed {
                        continue;
                    }
                    if interior_done < nloc {
                        let hi = (interior_done + INTERIOR_TILE_ROWS).min(nloc);
                        let cur = &acts[k];
                        let sp = self.tracer.start();
                        self.timer.time("spmv", || {
                            sl.mat.local.spmm_fused_range_rowmajor(
                                cur,
                                &mut z,
                                b,
                                interior_done,
                                hi,
                                |_, _| {},
                            );
                        });
                        self.tracer
                            .end(sp, "spmv.interior", "fwd", k as u32, NO_CHUNK, 0);
                        interior_done = hi;
                        continue;
                    }
                    let sp = self.tracer.start();
                    let (i, payload) = self
                        .timer
                        .time("wait", || ep.recv_any(k as u32, Phase::Forward, &wants));
                    self.tracer
                        .end(sp, "wait", "fwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                    let payload = ep.decode_payload(cf, payload);
                    let si = want_seg[i];
                    let chunk = wants[i].2;
                    wants.swap_remove(i);
                    want_seg.swap_remove(i);
                    let seg = &sl.mat.remote[si].csr;
                    let sp = self.tracer.start();
                    self.timer
                        .time("spmv", || seg.spmm_add_range_rowmajor(&payload, &mut z, b, 0, nb));
                    self.tracer
                        .end(sp, "spmv.seg", "fwd", k as u32, chunk, 4 * payload.len() as u64);
                    lay_payloads[si] = payload;
                }
                // finish the boundary epilogue over rows no outbound chunk
                // gathered
                if epi_done < nb {
                    let bias = &self.biases[k];
                    let act = self.activation;
                    let perm = &pipe.perm;
                    let zb = &mut z;
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        let mut epi = act.fused_bias_epilogue(bias);
                        for r in epi_done..nb {
                            epi(perm[r] as usize, &mut zb[r * b..(r + 1) * b]);
                        }
                    });
                    self.tracer
                        .end(sp, "epilogue.boundary", "fwd", k as u32, NO_CHUNK, 0);
                }
                if interior_done < nloc {
                    let cur = &acts[k];
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        sl.mat.local.spmm_fused_range_rowmajor(
                            cur,
                            &mut z,
                            b,
                            interior_done,
                            nloc,
                            |_, _| {},
                        );
                    });
                    self.tracer
                        .end(sp, "spmv.interior", "fwd", k as u32, NO_CHUNK, 0);
                }
                for (si, p) in lay_payloads.iter().enumerate() {
                    let seg = &sl.mat.remote[si];
                    let sp = self.tracer.start();
                    self.timer
                        .time("spmv", || seg.csr.spmm_add_range_rowmajor(p, &mut z, b, nb, nloc));
                    self.tracer.end(sp, "spmv.seg", "fwd", k as u32, seg.chunk, 0);
                }
                {
                    let bias = &self.biases[k];
                    let act = self.activation;
                    let perm = &pipe.perm;
                    let zb = &mut z;
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || {
                        let mut epi = act.fused_bias_epilogue(bias);
                        for r in nb..nloc {
                            epi(perm[r] as usize, &mut zb[r * b..(r + 1) * b]);
                        }
                    });
                    self.tracer
                        .end(sp, "epilogue.interior", "fwd", k as u32, NO_CHUNK, 0);
                }
                acts.push(z);
                payloads.push(lay_payloads);
            }
        }

        // ---- δ^L averaged over the batch (Alg. 3 line 2 / Eq. 6); the
        // last layer keeps its original row order, so this matches the
        // overlap engine exactly ----
        let act = self.activation;
        let inv_b = 1.0 / b as f32;
        let last = &self.rows[depth - 1];
        let xl = &acts[depth];
        let mut delta: Vec<f32> = Vec::with_capacity(last.len());
        let mut local_loss = 0f32;
        for (i, &r) in last.iter().enumerate() {
            let r = r as usize;
            let mut d = 0f32;
            for j in 0..b {
                let xr = xl[i * b + j];
                let yr = y[r * b + j];
                local_loss += 0.5 * (xr - yr) * (xr - yr) * inv_b;
                d += (xr - yr) * act.derivative_from_output(xr);
            }
            delta.push(d * inv_b);
        }

        // ---- pipelined backward (Alg. 3, mirror schedule): the partial
        // gradient of every inbound chunk is posted before the update
        // window; the mirrored receives drain behind it ----
        for k in (0..depth).rev() {
            let (inw, mx_local, mut s_local) = {
                let layers = match &mut self.repr {
                    Repr::Split { layers } => layers,
                    Repr::Full { .. } => unreachable!("pipelined path dispatched on Split"),
                };
                let SplitLayer { mat, pipe, .. } = &mut layers[k];
                let pipe = pipe.as_ref().expect("pipelined layer schedule");
                let inw = mat.local_gcols.len();
                let cb = self.codecs[k].1;
                // 1. per-chunk partial gradients, sent the moment each is
                // ready — before the local transpose and the update
                for seg in &mat.remote {
                    let mut sseg = ep.take_buf();
                    sseg.resize(seg.csr.ncols, 0.0);
                    let sp = self.tracer.start();
                    self.timer.time("spmv", || seg.csr.spmv_t_add(&delta, &mut sseg));
                    self.tracer.end(sp, "spmvt.seg", "bwd", k as u32, seg.chunk, 0);
                    let moved = 4 * sseg.len() as u64;
                    let sp = self.tracer.start();
                    self.timer.time("comm", || {
                        ep.send_encoded(
                            seg.src,
                            k as u32,
                            Phase::Backward,
                            seg.tid,
                            seg.chunk,
                            cb,
                            sseg,
                        )
                    });
                    self.tracer.end(sp, "send", "bwd", k as u32, seg.chunk, moved);
                }
                // 2. local transpose over the compact input slots
                let mut s_local = vec![0f32; inw];
                let sp = self.tracer.start();
                self.timer.time("spmv", || mat.local.spmv_t_add(&delta, &mut s_local));
                self.tracer.end(sp, "spmvt", "bwd", k as u32, NO_CHUNK, 0);
                // 3. weight + bias update in the overlap window, against
                // the batch-mean activations (delta and the split rows
                // share the permuted layout; biases are canonical, so the
                // bias index goes through perm)
                let mx_local = row_means(&acts[k], b);
                let mx_segs: Vec<Vec<f32>> = payloads[k].iter().map(|p| row_means(p, b)).collect();
                let sp = self.tracer.start();
                if let Some(gr) = self.collect.as_mut() {
                    // collect mode: record the gradient (weights in split
                    // storage order, biases in the permuted delta layout)
                    // instead of updating — the replica driver exchanges
                    // and applies it after the step.
                    self.timer.time("updt", || {
                        gr[k].clear();
                        mat.outer_grad(&delta, &mx_local, &mx_segs, &mut gr[k]);
                        gr[k].extend_from_slice(&delta);
                    });
                } else {
                    self.timer
                        .time("updt", || mat.sgd_update(&delta, &mx_local, &mx_segs, eta));
                    for (r, d) in delta.iter().enumerate() {
                        self.biases[k][pipe.perm[r] as usize] -= eta * d;
                    }
                }
                self.tracer.end(sp, "updt", "bwd", k as u32, NO_CHUNK, 0);
                (inw, mx_local, s_local)
            };
            // 4. mirrored receives in arrival order (behind the update):
            // the gradients for the chunks this rank posted during layer
            // k-1 (the input sends for k = 0)
            let layers = match &self.repr {
                Repr::Split { layers } => layers,
                Repr::Full { .. } => unreachable!("pipelined path dispatched on Split"),
            };
            let in_sends: &[ChunkSend] = if k > 0 {
                &layers[k - 1]
                    .pipe
                    .as_ref()
                    .expect("pipelined layer schedule")
                    .out_sends
            } else {
                &self.input_sends
            };
            if !in_sends.is_empty() {
                let cb = self.codecs[k].1;
                let mut wants: Vec<Want> =
                    in_sends.iter().map(|s| (s.to, s.tid, s.chunk)).collect();
                let mut which: Vec<usize> = (0..in_sends.len()).collect();
                while !wants.is_empty() {
                    let sp = self.tracer.start();
                    let (i, payload) = self
                        .timer
                        .time("wait", || ep.recv_any(k as u32, Phase::Backward, &wants));
                    self.tracer
                        .end(sp, "wait", "bwd", k as u32, NO_CHUNK, 4 * payload.len() as u64);
                    let payload = ep.decode_payload(cb, payload);
                    let sj = which[i];
                    wants.swap_remove(i);
                    which.swap_remove(i);
                    for (idx, &p) in in_sends[sj].pos.iter().enumerate() {
                        s_local[p as usize] += payload[idx];
                    }
                    ep.recycle(payload);
                }
            }
            // 5. δ^{k-1} = s ⊙ f'(x̄^k) over the compact input slots (the
            // previous layer's permuted output layout)
            if k > 0 {
                let mut next = Vec::with_capacity(inw);
                for i in 0..inw {
                    next.push(s_local[i] * act.derivative_from_output(mx_local[i]));
                }
                delta = next;
            }
        }
        // return the retained payload allocations to the endpoint pool
        for lay in payloads {
            for p in lay {
                if p.capacity() > 0 {
                    ep.recycle(p);
                }
            }
        }
        local_loss
    }
}
