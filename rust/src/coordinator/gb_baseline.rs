//! "GB" baseline — the data-parallel, shared-memory, whole-model-replicated
//! inference solution the paper compares against in Table 2 (Davis et al.,
//! SuiteSparse:GraphBLAS, a GraphChallenge 2019 champion).
//!
//! We reimplement its computational shape in Rust: the full model on one
//! node, the input batch split across `workers` threads, each thread
//! running batched CSR SpMM over **the whole network**. On this 1-core
//! host the multi-worker number is modeled: measure the real single-core
//! edges/s on the full model (which naturally degrades as N grows and the
//! working set falls out of cache — the same memory-capacity effect that
//! forced the paper's GB onto fat nodes), then scale by `workers ×
//! efficiency`. The paper's crossover (GB wins at small N, H-SpFF at large
//! N) is driven by exactly these two effects.

use crate::dnn::{inference, SparseNet};
use crate::util::Stopwatch;

/// Shared-memory data-parallel configuration (paper: 16-core node).
#[derive(Debug, Clone, Copy)]
pub struct GbConfig {
    pub workers: usize,
    /// Parallel efficiency of the shared-memory SpMM (memory-bandwidth
    /// contention keeps it below 1; 0.8 matches GraphBLAS-class scaling on
    /// Haswell).
    pub efficiency: f64,
    /// Batch width per SpMM call.
    pub batch: usize,
}

impl GbConfig {
    pub fn paper_node() -> Self {
        Self {
            workers: 16,
            efficiency: 0.8,
            batch: 64,
        }
    }
}

/// Measured single-core inference rate on the full model, edges/second.
/// `sample_inputs` bounds the measurement cost; the rate is per-edge so it
/// extrapolates to any input count.
pub fn measure_single_core_rate(net: &SparseNet, batch: usize, sample_inputs: usize) -> f64 {
    let d = net.input_dim();
    let b = batch.min(sample_inputs.max(1));
    // synthetic 0/1 inputs with MNIST-like density
    let mut rng = crate::util::Rng::new(123);
    let x0: Vec<f32> = (0..d * b)
        .map(|_| if rng.gen_bool(0.2) { 1.0 } else { 0.0 })
        .collect();
    // warm-up
    let _ = inference::infer_batch(net, &x0, b);
    let mut processed = 0usize;
    let sw = Stopwatch::start();
    while processed < sample_inputs {
        let _ = inference::infer_batch(net, &x0, b);
        processed += b;
    }
    let secs = sw.elapsed_secs();
    let edges = net.total_nnz() as f64 * processed as f64;
    edges / secs
}

/// Modeled GB throughput (edges/s) on a `cfg.workers`-core node.
pub fn gb_throughput(net: &SparseNet, cfg: &GbConfig, sample_inputs: usize) -> f64 {
    let single = measure_single_core_rate(net, cfg.batch, sample_inputs);
    single * cfg.workers as f64 * cfg.efficiency
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::radixnet::{generate, RadixNetConfig};

    #[test]
    fn rate_is_positive_and_sane() {
        let net = generate(&RadixNetConfig::graph_challenge(256, 4).unwrap());
        let r = measure_single_core_rate(&net, 8, 16);
        // between 1M and 100G edges/s on any plausible host
        assert!(r > 1e6 && r < 1e11, "rate {r}");
    }

    #[test]
    fn workers_scale_modeled_throughput() {
        let net = generate(&RadixNetConfig::graph_challenge(64, 3).unwrap());
        let one = GbConfig {
            workers: 1,
            efficiency: 1.0,
            batch: 8,
        };
        let sixteen = GbConfig {
            workers: 16,
            efficiency: 0.8,
            batch: 8,
        };
        let t1 = gb_throughput(&net, &one, 16);
        let t16 = gb_throughput(&net, &sixteen, 16);
        // modeled scaling: within noise of 12.8x (single rates vary run to
        // run on a busy host, so just require a healthy gap)
        assert!(t16 > t1 * 4.0, "t16 {t16} vs t1 {t1}");
    }
}
