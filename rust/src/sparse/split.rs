//! Split-CSR: a rank's row block reordered into a **local-column segment**
//! plus one **remote segment per source rank** (Hidayetoğlu et al.,
//! arXiv:2007.14152 — the at-scale sparse-DNN overlap layout).
//!
//! The local segment's columns are renumbered into the rank's *compact
//! owned-activation space* (position in the ascending list of activation
//! entries the rank computes itself), and each remote segment's columns are
//! renumbered into *payload positions* of the one inbound transfer carrying
//! them. The overlapped engine can therefore run the local segment the
//! moment the previous layer finishes — no full-width activation buffer,
//! no receive-side scatter — and apply each remote segment directly on a
//! payload the instant it lands.

use super::Csr;

/// One remote segment: the nonzeros of the row block whose columns arrive
/// in a single inbound transfer (or one sub-transfer **chunk** of it, for
/// the pipelined schedule), with columns renumbered to payload positions.
#[derive(Debug, Clone)]
pub struct SplitSegment {
    /// Source rank of the transfer feeding this segment.
    pub src: u32,
    /// Transfer id within the layer's [`crate::partition::LayerPlan`].
    pub tid: u32,
    /// Sub-transfer chunk id (0 for whole-transfer segments).
    pub chunk: u32,
    /// `nrows × payload_len`; column j reads payload position j.
    pub csr: Csr,
    /// Global activation index per payload position (== transfer indices).
    pub gcols: Vec<u32>,
}

/// A row block split into local + per-source remote segments. Values live
/// here (not in the original block): training updates and merges operate
/// on the split representation directly.
#[derive(Debug, Clone)]
pub struct SplitCsr {
    pub nrows: usize,
    /// Width of the global (full) activation space, for bookkeeping.
    pub full_width: usize,
    /// `nrows × local_gcols.len()`; column j reads compact owned slot j.
    pub local: Csr,
    /// Global activation index per compact local column, ascending — the
    /// rank's owned-activation list for this layer's input.
    pub local_gcols: Vec<u32>,
    /// One segment per inbound transfer, in the layer plan's receive order.
    pub remote: Vec<SplitSegment>,
}

/// Column destination during the split: local slot or (segment, position).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Dest {
    Unmapped,
    Local(u32),
    Remote(u32, u32),
}

impl SplitCsr {
    /// Split `block` (a rank's row block, global column space) against the
    /// rank's owned-activation list and its inbound transfers
    /// `(src, tid, chunk, indices)` — one per inbound payload (whole
    /// transfers with chunk 0, or chunk-granular sub-transfers for the
    /// pipelined schedule), in receive order.
    /// `owned_acts` is usually ascending, but the pipelined engine passes
    /// it in **boundary-first permuted order** (the previous layer's output
    /// layout); compact local columns are re-sorted per row in that case so
    /// the CSR invariant holds either way.
    /// Every column with a nonzero must be owned or covered by exactly one
    /// transfer (the communication-plan invariant); anything else is an
    /// error.
    pub fn build(
        block: &Csr,
        owned_acts: &[u32],
        inbound: &[(u32, u32, u32, &[u32])],
    ) -> Result<SplitCsr, String> {
        let mut dest = vec![Dest::Unmapped; block.ncols];
        for (pos, &j) in owned_acts.iter().enumerate() {
            if j as usize >= block.ncols {
                return Err(format!("owned activation {j} out of bounds"));
            }
            if dest[j as usize] != Dest::Unmapped {
                return Err(format!("owned activation {j} listed twice"));
            }
            dest[j as usize] = Dest::Local(pos as u32);
        }
        for (s, (_, _, _, indices)) in inbound.iter().enumerate() {
            for (pos, &j) in indices.iter().enumerate() {
                if j as usize >= block.ncols {
                    return Err(format!("transfer index {j} out of bounds"));
                }
                if dest[j as usize] != Dest::Unmapped {
                    return Err(format!("column {j} covered twice (segment {s})"));
                }
                dest[j as usize] = Dest::Remote(s as u32, pos as u32);
            }
        }

        // Per-target CSR builders. Global columns are sorted within each
        // row and transfer indices ascend, so remote compact columns stay
        // sorted per target without re-sorting; the local segment is
        // re-sorted below when owned_acts is permuted.
        let mut local = CsrBuilder::new(owned_acts.len());
        let mut segs: Vec<CsrBuilder> = inbound
            .iter()
            .map(|(_, _, _, idx)| CsrBuilder::new(idx.len()))
            .collect();
        for r in 0..block.nrows {
            let (cols, vals) = block.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                match dest[c as usize] {
                    Dest::Local(p) => local.push(p, v),
                    Dest::Remote(s, p) => segs[s as usize].push(p, v),
                    Dest::Unmapped => {
                        return Err(format!(
                            "row {r} column {c} neither owned nor received"
                        ))
                    }
                }
            }
            local.end_row();
            for s in segs.iter_mut() {
                s.end_row();
            }
        }
        let mut local = local.finish();
        if !owned_acts.windows(2).all(|w| w[0] < w[1]) {
            sort_rows_by_column(&mut local);
        }
        let remote = segs
            .into_iter()
            .zip(inbound.iter())
            .map(|(b, &(src, tid, chunk, indices))| SplitSegment {
                src,
                tid,
                chunk,
                csr: b.finish(),
                gcols: indices.to_vec(),
            })
            .collect();
        Ok(SplitCsr {
            nrows: block.nrows,
            full_width: block.ncols,
            local,
            local_gcols: owned_acts.to_vec(),
            remote,
        })
    }

    /// Total nonzeros across all segments (== the original block's nnz).
    pub fn nnz(&self) -> usize {
        self.local.nnz() + self.remote.iter().map(|s| s.csr.nnz()).sum::<usize>()
    }

    /// Gradient update on every stored nonzero (Eq. 4–5) against the
    /// compact activations that fed the forward pass: `x_local` over the
    /// owned slots and one `x_segs[i]` per remote segment (the retained
    /// forward payload, or its batch mean).
    pub fn sgd_update(&mut self, delta: &[f32], x_local: &[f32], x_segs: &[Vec<f32>], eta: f32) {
        debug_assert_eq!(x_segs.len(), self.remote.len());
        self.local.sgd_update(delta, x_local, eta);
        for (seg, x) in self.remote.iter_mut().zip(x_segs.iter()) {
            seg.csr.sgd_update(delta, x, eta);
        }
    }

    /// Append the gradient of every stored nonzero to `out`, in **storage
    /// order**: the local segment's entries first ([`Csr::outer_grad`]
    /// order), then each remote segment's in [`SplitCsr::remote`] order —
    /// exactly the order [`SplitCsr::sgd_update`] walks, and identical
    /// across replica groups built from the same plan, which is what makes
    /// the flat gradient vector all-reduce-safe. `apply_grad` consumes the
    /// same layout.
    pub fn outer_grad(
        &self,
        delta: &[f32],
        x_local: &[f32],
        x_segs: &[Vec<f32>],
        out: &mut Vec<f32>,
    ) {
        debug_assert_eq!(x_segs.len(), self.remote.len());
        self.local.outer_grad(delta, x_local, out);
        for (seg, x) in self.remote.iter().zip(x_segs.iter()) {
            seg.csr.outer_grad(delta, x, out);
        }
    }

    /// Apply a flat gradient in [`SplitCsr::outer_grad`] storage order:
    /// `vals[i] -= eta * g[i]` across the local then remote segments.
    /// `g.len()` must equal [`SplitCsr::nnz`].
    pub fn apply_grad(&mut self, g: &[f32], eta: f32) {
        debug_assert_eq!(g.len(), self.nnz());
        let (gl, mut rest) = g.split_at(self.local.nnz());
        self.local.apply_grad(gl, eta);
        for seg in self.remote.iter_mut() {
            let (gs, tail) = rest.split_at(seg.csr.nnz());
            seg.csr.apply_grad(gs, eta);
            rest = tail;
        }
    }

    /// One row's `(global column, value)` pairs, sorted by global column —
    /// exactly the original block's row layout, for merging trained values
    /// back into the global model.
    pub fn gather_row(&self, r: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::with_capacity(self.local.row_nnz(r));
        let (cols, vals) = self.local.row(r);
        for (&c, &v) in cols.iter().zip(vals.iter()) {
            out.push((self.local_gcols[c as usize], v));
        }
        for seg in &self.remote {
            let (cols, vals) = seg.csr.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                out.push((seg.gcols[c as usize], v));
            }
        }
        out.sort_unstable_by_key(|&(c, _)| c);
        out
    }

    /// Reassemble the original (global-column) row block — test helper and
    /// cross-check for the split invariants.
    pub fn unsplit(&self) -> Csr {
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0u32);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            for (c, v) in self.gather_row(r) {
                indices.push(c);
                vals.push(v);
            }
            indptr.push(indices.len() as u32);
        }
        Csr {
            nrows: self.nrows,
            ncols: self.full_width,
            indptr,
            indices,
            vals,
        }
    }
}

/// Restore the per-row sorted-column CSR invariant after a permuted
/// compact renumbering.
fn sort_rows_by_column(m: &mut Csr) {
    for r in 0..m.nrows {
        let lo = m.indptr[r] as usize;
        let hi = m.indptr[r + 1] as usize;
        let mut pairs: Vec<(u32, f32)> = m.indices[lo..hi]
            .iter()
            .copied()
            .zip(m.vals[lo..hi].iter().copied())
            .collect();
        pairs.sort_unstable_by_key(|&(c, _)| c);
        for (i, (c, v)) in pairs.into_iter().enumerate() {
            m.indices[lo + i] = c;
            m.vals[lo + i] = v;
        }
    }
}

/// Boundary/interior row regrouping for the **pipelined send schedule**:
/// given, per outbound chunk, the local rows whose activations it carries,
/// produce a row permutation that packs those "boundary" rows first —
/// grouped by the chunk that first needs them, in post order — followed by
/// the interior (local-only) rows. Under this order every chunk's rows lie
/// inside a prefix, so the sender can post chunk `i`'s payload the moment
/// `ready[i]` rows are finished, while interior rows are still computing.
#[derive(Debug, Clone)]
pub struct RowRegroup {
    /// New row `r'` holds old row `perm[r']`.
    pub perm: Vec<u32>,
    /// Old row `p` now sits at row `inv[p]`.
    pub inv: Vec<u32>,
    /// Rows `[0, boundary_end)` feed at least one outbound chunk.
    pub boundary_end: usize,
    /// Per input group: number of prefix rows (in the new order) that must
    /// be finished before that chunk's payload is complete. A group whose
    /// rows were all claimed by earlier groups (an *empty boundary range*)
    /// gets the earlier prefix it depends on.
    pub ready: Vec<usize>,
}

/// Compute a [`RowRegroup`] for `nrows` rows and per-chunk row lists
/// (`groups[i]` = old row indices feeding outbound chunk `i`). Shared rows
/// are claimed by the first group that needs them; interior rows keep
/// their relative (ascending) order after the boundary block.
pub fn regroup_rows(nrows: usize, groups: &[Vec<u32>]) -> RowRegroup {
    let mut inv = vec![u32::MAX; nrows];
    let mut perm: Vec<u32> = Vec::with_capacity(nrows);
    let mut ready = Vec::with_capacity(groups.len());
    for rows in groups {
        let mut hi = 0usize;
        for &p in rows {
            debug_assert!((p as usize) < nrows, "group row out of bounds");
            if inv[p as usize] == u32::MAX {
                inv[p as usize] = perm.len() as u32;
                perm.push(p);
            }
            hi = hi.max(inv[p as usize] as usize + 1);
        }
        ready.push(hi);
    }
    let boundary_end = perm.len();
    for p in 0..nrows as u32 {
        if inv[p as usize] == u32::MAX {
            inv[p as usize] = perm.len() as u32;
            perm.push(p);
        }
    }
    RowRegroup {
        perm,
        inv,
        boundary_end,
        ready,
    }
}

/// Incremental CSR assembly in row order.
struct CsrBuilder {
    ncols: usize,
    indptr: Vec<u32>,
    indices: Vec<u32>,
    vals: Vec<f32>,
}

impl CsrBuilder {
    fn new(ncols: usize) -> Self {
        Self {
            ncols,
            indptr: vec![0],
            indices: Vec::new(),
            vals: Vec::new(),
        }
    }

    fn push(&mut self, col: u32, val: f32) {
        self.indices.push(col);
        self.vals.push(val);
    }

    fn end_row(&mut self) {
        self.indptr.push(self.indices.len() as u32);
    }

    fn finish(self) -> Csr {
        Csr {
            nrows: self.indptr.len() - 1,
            ncols: self.ncols,
            indptr: self.indptr,
            indices: self.indices,
            vals: self.vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::{prop, Rng};

    /// Random block + a random cover of its columns into owned + segments.
    fn random_split(
        rng: &mut Rng,
        nrows: usize,
        ncols: usize,
    ) -> (Csr, Vec<u32>, Vec<Vec<u32>>) {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.gen_bool(0.35) {
                    coo.push(r, c, rng.gen_f32_range(-1.0, 1.0));
                }
            }
        }
        let block = coo.to_csr();
        let nsegs = rng.gen_range(3); // 0..=2 remote sources
        let mut owned = Vec::new();
        let mut segs: Vec<Vec<u32>> = vec![Vec::new(); nsegs];
        for c in 0..ncols as u32 {
            let pick = rng.gen_range(nsegs + 1);
            if pick == 0 {
                owned.push(c);
            } else {
                segs[pick - 1].push(c);
            }
        }
        (block, owned, segs)
    }

    fn build_from(block: &Csr, owned: &[u32], segs: &[Vec<u32>]) -> Result<SplitCsr, String> {
        let inbound: Vec<(u32, u32, u32, &[u32])> = segs
            .iter()
            .enumerate()
            .map(|(i, idx)| (i as u32 + 1, i as u32, 0, idx.as_slice()))
            .collect();
        SplitCsr::build(block, owned, &inbound)
    }

    #[test]
    fn split_preserves_nnz_and_unsplits_exactly() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(12), 1 + rng.gen_range(12));
            let (block, owned, segs) = random_split(rng, nr, nc);
            let split = build_from(&block, &owned, &segs).expect("valid cover");
            assert_eq!(split.nnz(), block.nnz());
            assert_eq!(split.unsplit(), block);
            for seg in &split.remote {
                assert!(seg.csr.validate().is_ok());
            }
            assert!(split.local.validate().is_ok());
        });
    }

    #[test]
    fn local_plus_segments_equals_full_spmv() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(15), 1 + rng.gen_range(15));
            let (block, owned, segs) = random_split(rng, nr, nc);
            let split = build_from(&block, &owned, &segs).expect("valid cover");
            let x: Vec<f32> = (0..nc).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            // reference: full-width SpMV
            let mut want = vec![0.0; nr];
            block.spmv(&x, &mut want);
            // split: local over compact owned slots, then segment payloads
            let x_local: Vec<f32> = split.local_gcols.iter().map(|&j| x[j as usize]).collect();
            let mut got = vec![0.0; nr];
            split.local.spmv(&x_local, &mut got);
            for seg in &split.remote {
                let payload: Vec<f32> = seg.gcols.iter().map(|&j| x[j as usize]).collect();
                seg.csr.spmv_add(&payload, &mut got);
            }
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g - w).abs() < 1e-5, "{g} vs {w}");
            }
        });
    }

    #[test]
    fn split_sgd_update_matches_full_update() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
            let (block, owned, segs) = random_split(rng, nr, nc);
            let mut split = build_from(&block, &owned, &segs).expect("valid cover");
            let x: Vec<f32> = (0..nc).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let delta: Vec<f32> = (0..nr).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let x_local: Vec<f32> = split.local_gcols.iter().map(|&j| x[j as usize]).collect();
            let x_segs: Vec<Vec<f32>> = split
                .remote
                .iter()
                .map(|s| s.gcols.iter().map(|&j| x[j as usize]).collect())
                .collect();
            split.sgd_update(&delta, &x_local, &x_segs, 0.3);
            let mut full = block.clone();
            full.sgd_update(&delta, &x, 0.3);
            assert_eq!(split.unsplit(), full);
        });
    }

    #[test]
    fn split_outer_grad_then_apply_matches_split_update() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
            let (block, owned, segs) = random_split(rng, nr, nc);
            let split = build_from(&block, &owned, &segs).expect("valid cover");
            let x: Vec<f32> = (0..nc).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let delta: Vec<f32> = (0..nr).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let x_local: Vec<f32> = split.local_gcols.iter().map(|&j| x[j as usize]).collect();
            let x_segs: Vec<Vec<f32>> = split
                .remote
                .iter()
                .map(|s| s.gcols.iter().map(|&j| x[j as usize]).collect())
                .collect();
            let mut g = Vec::new();
            split.outer_grad(&delta, &x_local, &x_segs, &mut g);
            assert_eq!(g.len(), split.nnz());
            let mut via_grad = split.clone();
            via_grad.apply_grad(&g, 0.4);
            let mut direct = split.clone();
            direct.sgd_update(&delta, &x_local, &x_segs, 0.4);
            let a = via_grad.unsplit();
            let b = direct.unsplit();
            for (u, v) in a.vals.iter().zip(b.vals.iter()) {
                assert!((u - v).abs() < 1e-6, "{u} vs {v}");
            }
        });
    }

    #[test]
    fn uncovered_and_double_covered_columns_rejected() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 2, 2.0);
        let block = coo.to_csr();
        // column 2 has a nonzero but is neither owned nor received
        let err = build_from(&block, &[0], &[vec![1]]).expect_err("uncovered");
        assert!(err.contains("neither owned nor received"), "{err}");
        // column 1 claimed by both the owned list and a transfer
        let err = build_from(&block, &[0, 1], &[vec![1, 2]]).expect_err("double");
        assert!(err.contains("covered twice"), "{err}");
        // out-of-bounds transfer index
        let err = build_from(&block, &[0, 1, 2], &[vec![9]]).expect_err("oob");
        assert!(err.contains("out of bounds"), "{err}");
    }

    #[test]
    fn permuted_owned_list_builds_sorted_local_and_same_spmv() {
        // the pipelined engine passes owned_acts in boundary-first permuted
        // order; the split must stay a valid CSR and compute the same SpMV
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(12), 2 + rng.gen_range(12));
            let (block, owned, segs) = random_split(rng, nr, nc);
            if owned.len() < 2 {
                return;
            }
            let shuffled: Vec<u32> = {
                let idx = rng.permutation(owned.len());
                idx.iter().map(|&i| owned[i as usize]).collect()
            };
            let inbound: Vec<(u32, u32, u32, &[u32])> = segs
                .iter()
                .enumerate()
                .map(|(i, s)| (i as u32 + 1, i as u32, 0, s.as_slice()))
                .collect();
            let split = SplitCsr::build(&block, &shuffled, &inbound).expect("valid cover");
            assert!(split.local.validate().is_ok(), "local rows must stay sorted");
            assert_eq!(split.unsplit(), block);
            let x: Vec<f32> = (0..nc).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut want = vec![0.0; nr];
            block.spmv(&x, &mut want);
            let x_local: Vec<f32> = split.local_gcols.iter().map(|&j| x[j as usize]).collect();
            let mut got = vec![0.0; nr];
            split.local.spmv(&x_local, &mut got);
            for seg in &split.remote {
                let payload: Vec<f32> = seg.gcols.iter().map(|&j| x[j as usize]).collect();
                seg.csr.spmv_add(&payload, &mut got);
            }
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g - w).abs() < 1e-5, "{g} vs {w}");
            }
        });
    }

    #[test]
    fn regroup_rows_packs_boundary_prefix_per_group() {
        // groups over 8 rows: chunk 0 needs {5,1}, chunk 1 needs {1,6}
        // (row 1 shared — claimed by chunk 0), chunk 2 needs nothing new
        let g = regroup_rows(8, &[vec![5, 1], vec![1, 6], vec![5]]);
        assert_eq!(&g.perm[..3], &[5, 1, 6], "boundary rows in claim order");
        assert_eq!(g.boundary_end, 3);
        // chunk 0 complete after 2 prefix rows, chunk 1 after 3, chunk 2
        // (all rows claimed earlier — an empty boundary range) after 1
        assert_eq!(g.ready, vec![2, 3, 1]);
        // interior rows follow in ascending order
        assert_eq!(&g.perm[3..], &[0, 2, 3, 4, 7]);
        // perm/inv are mutual inverses
        for (r, &p) in g.perm.iter().enumerate() {
            assert_eq!(g.inv[p as usize] as usize, r);
        }
        // every group's rows lie within its ready prefix
        for (gi, rows) in [vec![5u32, 1], vec![1, 6], vec![5]].iter().enumerate() {
            for &p in rows {
                assert!((g.inv[p as usize] as usize) < g.ready[gi]);
            }
        }
    }

    #[test]
    fn regroup_rows_no_groups_is_identity() {
        let g = regroup_rows(4, &[]);
        assert_eq!(g.perm, vec![0, 1, 2, 3]);
        assert_eq!(g.boundary_end, 0);
        assert!(g.ready.is_empty());
    }

    #[test]
    fn empty_cover_pieces_are_fine() {
        // a column with no nonzero may be left unmapped; empty segments and
        // an empty owned list are structurally valid
        let mut coo = Coo::new(2, 4);
        coo.push(0, 1, 1.0);
        coo.push(1, 1, -1.0);
        let block = coo.to_csr();
        let split = build_from(&block, &[], &[vec![1], vec![3]]).expect("valid");
        assert_eq!(split.local.nnz(), 0);
        assert_eq!(split.remote[0].csr.nnz(), 2);
        assert_eq!(split.remote[1].csr.nnz(), 0);
        assert_eq!(split.unsplit(), block);
    }
}
