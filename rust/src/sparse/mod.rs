//! Sparse matrix substrate: COO builder, streaming CSR builder, CSR
//! kernels, text I/O.
//!
//! Everything the paper's SpMV-based SGD needs: `spmv` (Alg. 2 line 6),
//! `spmv_add` (line 9), `spmv_t_add` (Alg. 3 line 4), `sgd_update`
//! (Alg. 3 lines 8–9), `spmm_rowmajor` (§5.1 batched inference),
//! row-block extraction (the rank-local view), transposition. Large
//! matrices (Graph Challenge RadixNet layers) are assembled through
//! [`CsrStream`] so no COO copy is ever materialized.

pub mod coo;
// The only module allowed to use `unsafe` (crate root carries
// `#![deny(unsafe_code)]`): the four unchecked-index kernel sites, each
// justified by a `// SAFETY:` comment tied to `Csr::validate` and
// exercised under Miri in CI.
#[allow(unsafe_code)]
pub mod csr;
pub mod io;
pub mod split;

pub use coo::Coo;
pub use csr::Csr;
pub use io::CsrStream;
pub use split::{regroup_rows, RowRegroup, SplitCsr, SplitSegment};
