//! Compressed sparse row matrix — the workhorse of the whole system.
//!
//! Weight matrices `W^k` are stored CSR row-wise-partitioned among ranks
//! (Section 4 of the paper). The transpose multiply used by backpropagation
//! (`(W^k)^T δ^k`, Alg. 3 line 4) is implemented directly on the CSR
//! structure as a scatter, which is exactly the column-block view the paper
//! describes (row partition of `W` == column partition of `W^T`).

/// Batch columns per pass of the tiled SpMM: 64 f32 row segments keep the
/// accumulator in registers/L1 while A streams through once per tile.
pub const SPMM_TILE: usize = 64;

/// CSR sparse matrix over f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Row pointers, len == nrows + 1.
    pub indptr: Vec<u32>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Empty matrix with no nonzeros.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Stored nonzero count.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices and values of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[r] as usize;
        let hi = self.indptr[r + 1] as usize;
        (&self.indices[lo..hi], &self.vals[lo..hi])
    }

    /// Column indices and mutable values of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> (&[u32], &mut [f32]) {
        let lo = self.indptr[r] as usize;
        let hi = self.indptr[r + 1] as usize;
        (&self.indices[lo..hi], &mut self.vals[lo..hi])
    }

    /// Nonzero count of row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.indptr[r + 1] - self.indptr[r]) as usize
    }

    /// Validate structural invariants (debug/test helper).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.nrows + 1 {
            return Err("indptr length".into());
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err("indptr end != nnz".into());
        }
        if self.indices.len() != self.vals.len() {
            return Err("indices/vals mismatch".into());
        }
        for r in 0..self.nrows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} columns not strictly sorted"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }

    /// y = A x  (dense x, dense y).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let mut acc = 0f32;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                debug_assert!(c < x.len(), "row {r}: column {c} out of bounds");
                // SAFETY: `Csr::validate` guarantees every stored column
                // index is < `ncols`, and `x.len() == ncols` (asserted
                // above), so `c` is in-bounds for `x`.
                acc += self.vals[i] * unsafe { *x.get_unchecked(c) };
            }
            y[r] = acc;
        }
    }

    /// y += A x  — used for accumulating remote contributions (Alg. 2 line 9).
    pub fn spmv_add(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let mut acc = 0f32;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                debug_assert!(c < x.len(), "row {r}: column {c} out of bounds");
                // SAFETY: `Csr::validate` guarantees every stored column
                // index is < `ncols`, and `x.len() == ncols` (asserted
                // above), so `c` is in-bounds for `x`.
                acc += self.vals[i] * unsafe { *x.get_unchecked(c) };
            }
            y[r] += acc;
        }
    }

    /// y = A^T x, computed by scattering over the CSR rows.
    /// `y` must be zeroed (or hold a partial sum to accumulate into).
    pub fn spmv_t_add(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        for r in 0..self.nrows {
            let xv = x[r];
            if xv == 0.0 {
                continue;
            }
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                debug_assert!(c < y.len(), "row {r}: column {c} out of bounds");
                // SAFETY: `Csr::validate` guarantees every stored column
                // index is < `ncols`, and `y.len() == ncols` (asserted
                // above), so `c` is in-bounds for `y`.
                unsafe {
                    *y.get_unchecked_mut(c) += self.vals[i] * xv;
                }
            }
        }
    }

    /// Y = A X for dense X stored column-major: X is `ncols x b`,
    /// Y is `nrows x b`, both column-major (each column is one input vector).
    pub fn spmm_colmajor(&self, x: &[f32], y: &mut [f32], b: usize) {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        for col in 0..b {
            let xs = &x[col * self.ncols..(col + 1) * self.ncols];
            let ys = &mut y[col * self.nrows..(col + 1) * self.nrows];
            self.spmv(xs, ys);
        }
    }

    /// Y = A X for dense X stored **row-major** (X: ncols x b, Y: nrows x b).
    /// Row-major RHS vectorizes across the batch dimension — the layout used
    /// by the batched inference path (§5.1 SpMM discussion).
    pub fn spmm_rowmajor(&self, x: &[f32], y: &mut [f32], b: usize) {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        y.fill(0.0);
        for r in 0..self.nrows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let yrow = &mut y[r * b..(r + 1) * b];
            for i in lo..hi {
                let v = self.vals[i];
                let c = self.indices[i] as usize;
                let xrow = &x[c * b..(c + 1) * b];
                for (yj, xj) in yrow.iter_mut().zip(xrow.iter()) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// Y = A X for **row-major** X with the batch dimension processed in
    /// cache-sized column tiles and a caller-supplied per-row epilogue
    /// (bias + activation on the serving path) fused into the accumulation
    /// pass. Each row tile is accumulated in a stack buffer, so the inner
    /// loop is a fixed-width FMA over hot data; `epilogue(r, tile)` is
    /// invoked once per (row, column-tile) with the finished tile.
    pub fn spmm_fused_rowmajor<F>(&self, x: &[f32], y: &mut [f32], b: usize, mut epilogue: F)
    where
        F: FnMut(usize, &mut [f32]),
    {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        let mut acc = [0f32; SPMM_TILE];
        let mut lo = 0usize;
        while lo < b {
            let w = SPMM_TILE.min(b - lo);
            for r in 0..self.nrows {
                let start = self.indptr[r] as usize;
                let end = self.indptr[r + 1] as usize;
                let tile = &mut acc[..w];
                tile.fill(0.0);
                for i in start..end {
                    let v = self.vals[i];
                    let c = self.indices[i] as usize;
                    let xrow = &x[c * b + lo..c * b + lo + w];
                    for (a, &xv) in tile.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                }
                let yrow = &mut y[r * b + lo..r * b + lo + w];
                yrow.copy_from_slice(tile);
                epilogue(r, yrow);
            }
            lo += w;
        }
    }

    /// Y += A X for **row-major** X, batch dimension tiled like
    /// [`Csr::spmm_fused_rowmajor`] but accumulating into `y` instead of
    /// overwriting it — the remote-segment kernel of the split-CSR
    /// overlapped path, where each in-flight payload's contribution lands
    /// on top of the local-segment partial sums.
    pub fn spmm_add_rowmajor(&self, x: &[f32], y: &mut [f32], b: usize) {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        let mut acc = [0f32; SPMM_TILE];
        let mut lo = 0usize;
        while lo < b {
            let w = SPMM_TILE.min(b - lo);
            for r in 0..self.nrows {
                let start = self.indptr[r] as usize;
                let end = self.indptr[r + 1] as usize;
                if start == end {
                    continue;
                }
                let tile = &mut acc[..w];
                tile.fill(0.0);
                for i in start..end {
                    let v = self.vals[i];
                    let c = self.indices[i] as usize;
                    let xrow = &x[c * b + lo..c * b + lo + w];
                    for (a, &xv) in tile.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                }
                let yrow = &mut y[r * b + lo..r * b + lo + w];
                for (yv, &a) in yrow.iter_mut().zip(tile.iter()) {
                    *yv += a;
                }
            }
            lo += w;
        }
    }

    /// [`Csr::spmm_fused_rowmajor`] restricted to rows `[r0, r1)` —
    /// overwrites exactly those output rows and touches nothing else. The
    /// pipelined engine computes its boundary row block with one call and
    /// streams the interior in tiles between receive polls.
    pub fn spmm_fused_range_rowmajor<F>(
        &self,
        x: &[f32],
        y: &mut [f32],
        b: usize,
        r0: usize,
        r1: usize,
        mut epilogue: F,
    ) where
        F: FnMut(usize, &mut [f32]),
    {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        debug_assert!(r0 <= r1 && r1 <= self.nrows);
        let mut acc = [0f32; SPMM_TILE];
        let mut lo = 0usize;
        while lo < b {
            let w = SPMM_TILE.min(b - lo);
            for r in r0..r1 {
                let start = self.indptr[r] as usize;
                let end = self.indptr[r + 1] as usize;
                let tile = &mut acc[..w];
                tile.fill(0.0);
                for i in start..end {
                    let v = self.vals[i];
                    let c = self.indices[i] as usize;
                    let xrow = &x[c * b + lo..c * b + lo + w];
                    for (a, &xv) in tile.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                }
                let yrow = &mut y[r * b + lo..r * b + lo + w];
                yrow.copy_from_slice(tile);
                epilogue(r, yrow);
            }
            lo += w;
        }
    }

    /// [`Csr::spmm_add_rowmajor`] restricted to rows `[r0, r1)` — the
    /// pipelined engine applies each in-flight payload to the boundary row
    /// block first (so outbound chunks can post) and to the interior rows
    /// later, after their local pass has written them.
    pub fn spmm_add_range_rowmajor(
        &self,
        x: &[f32],
        y: &mut [f32],
        b: usize,
        r0: usize,
        r1: usize,
    ) {
        debug_assert_eq!(x.len(), self.ncols * b);
        debug_assert_eq!(y.len(), self.nrows * b);
        debug_assert!(r0 <= r1 && r1 <= self.nrows);
        let mut acc = [0f32; SPMM_TILE];
        let mut lo = 0usize;
        while lo < b {
            let w = SPMM_TILE.min(b - lo);
            for r in r0..r1 {
                let start = self.indptr[r] as usize;
                let end = self.indptr[r + 1] as usize;
                if start == end {
                    continue;
                }
                let tile = &mut acc[..w];
                tile.fill(0.0);
                for i in start..end {
                    let v = self.vals[i];
                    let c = self.indices[i] as usize;
                    let xrow = &x[c * b + lo..c * b + lo + w];
                    for (a, &xv) in tile.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                }
                let yrow = &mut y[r * b + lo..r * b + lo + w];
                for (yv, &a) in yrow.iter_mut().zip(tile.iter()) {
                    *yv += a;
                }
            }
            lo += w;
        }
    }

    /// Gradient update on existing nonzeros only (Eq. 4–5):
    /// `W(r, c) -= eta * delta(r) * x(c)` for each stored (r, c).
    /// Sparse DNN training never densifies: pruned connections stay pruned.
    pub fn sgd_update(&mut self, delta: &[f32], x: &[f32], eta: f32) {
        debug_assert_eq!(delta.len(), self.nrows);
        debug_assert_eq!(x.len(), self.ncols);
        for r in 0..self.nrows {
            let d = eta * delta[r];
            if d == 0.0 {
                continue;
            }
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                debug_assert!(c < x.len(), "row {r}: column {c} out of bounds");
                // SAFETY: `Csr::validate` guarantees every stored column
                // index is < `ncols`, and `x.len() == ncols` (asserted
                // above), so `c` is in-bounds for `x`.
                self.vals[i] -= d * unsafe { *x.get_unchecked(c) };
            }
        }
    }

    /// Append the gradient of every stored nonzero to `out`, in `vals`
    /// storage order: `g(r, c) = delta(r) * x(c)` for each stored (r, c).
    /// [`Csr::sgd_update`]'s per-entry step detached from the update, so
    /// `apply_grad(g, eta)` after `outer_grad` reproduces
    /// `sgd_update(delta, x, eta)` up to one f32 multiply reassociation
    /// (`eta*(d*x)` vs `(eta*d)*x`, a ≤ 1-ulp difference). Pushes one
    /// entry per stored nonzero (zero rows included) — the replica
    /// gradient exchange relies on the length equalling [`Csr::nnz`].
    pub fn outer_grad(&self, delta: &[f32], x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(delta.len(), self.nrows);
        debug_assert_eq!(x.len(), self.ncols);
        out.reserve(self.nnz());
        for r in 0..self.nrows {
            let d = delta[r];
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                debug_assert!(c < x.len(), "row {r}: column {c} out of bounds");
                // SAFETY: `Csr::validate` guarantees every stored column
                // index is < `ncols`, and `x.len() == ncols` (asserted
                // above), so `c` is in-bounds for `x`.
                out.push(d * unsafe { *x.get_unchecked(c) });
            }
        }
    }

    /// `vals[i] -= eta * g[i]` over the stored nonzeros — the apply half
    /// of [`Csr::outer_grad`], used after the replica all-reduce has
    /// averaged gradients across groups. `g.len()` must equal
    /// [`Csr::nnz`].
    pub fn apply_grad(&mut self, g: &[f32], eta: f32) {
        debug_assert_eq!(g.len(), self.nnz());
        for (v, gi) in self.vals.iter_mut().zip(g.iter()) {
            *v -= eta * gi;
        }
    }

    /// Transpose into a new CSR (i.e., the CSC view of self).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0u32; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        for r in 0..self.nrows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            for i in lo..hi {
                let c = self.indices[i] as usize;
                let at = cursor[c] as usize;
                indices[at] = r as u32;
                vals[at] = self.vals[i];
                cursor[c] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            vals,
        }
    }

    /// Extract the row block given by `rows` (in order). Column space is kept
    /// (no re-indexing): this is exactly the per-rank block `W^k_m`.
    pub fn row_block(&self, rows: &[u32]) -> Csr {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0u32);
        let mut nnz = 0usize;
        for &r in rows {
            nnz += self.row_nnz(r as usize);
            indptr.push(nnz as u32);
        }
        let mut indices = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for &r in rows {
            let (cols, vs) = self.row(r as usize);
            indices.extend_from_slice(cols);
            vals.extend_from_slice(vs);
        }
        Csr {
            nrows: rows.len(),
            ncols: self.ncols,
            indptr,
            indices,
            vals,
        }
    }

    /// Set of distinct columns with at least one nonzero — `cols(·)` in
    /// Eqs. (8)–(9). Returned sorted.
    pub fn cols_used(&self) -> Vec<u32> {
        let mut seen = vec![false; self.ncols];
        for &c in &self.indices {
            seen[c as usize] = true;
        }
        (0..self.ncols as u32)
            .filter(|&c| seen[c as usize])
            .collect()
    }

    /// Dense representation (tests / PJRT path for small blocks).
    pub fn to_dense_rowmajor(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.nrows * self.ncols];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals.iter()) {
                out[r * self.ncols + *c as usize] = *v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::prop;

    fn small() -> Csr {
        // [1 0 2]
        // [0 3 0]
        let mut c = Coo::new(2, 3);
        c.push(0, 0, 1.0);
        c.push(0, 2, 2.0);
        c.push(1, 1, 3.0);
        c.to_csr()
    }

    fn random_csr(rng: &mut crate::util::Rng, nrows: usize, ncols: usize, p: f64) -> Csr {
        let mut c = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for col in 0..ncols {
                if rng.gen_bool(p) {
                    c.push(r, col, rng.gen_f32_range(-1.0, 1.0));
                }
            }
        }
        c.to_csr()
    }

    fn dense_spmv(a: &Csr, x: &[f32]) -> Vec<f32> {
        let d = a.to_dense_rowmajor();
        (0..a.nrows)
            .map(|r| {
                (0..a.ncols)
                    .map(|c| d[r * a.ncols + c] * x[c])
                    .sum::<f32>()
            })
            .collect()
    }

    #[test]
    fn spmv_matches_dense() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = vec![0.0; 2];
        a.spmv(&x, &mut y);
        assert_eq!(y, vec![7.0, 6.0]);
    }

    #[test]
    fn spmv_add_accumulates() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0];
        a.spmv_add(&x, &mut y);
        assert_eq!(y, vec![17.0, 16.0]);
    }

    #[test]
    fn spmv_t_matches_transpose() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(20), 1 + rng.gen_range(20));
            let a = random_csr(rng, nr, nc, 0.3);
            let x: Vec<f32> = (0..a.nrows).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut y1 = vec![0.0; a.ncols];
            a.spmv_t_add(&x, &mut y1);
            let t = a.transpose();
            let mut y2 = vec![0.0; a.ncols];
            t.spmv(&x, &mut y2);
            for (u, v) in y1.iter().zip(y2.iter()) {
                assert!((u - v).abs() < 1e-5, "{u} vs {v}");
            }
        });
    }

    #[test]
    fn transpose_involution() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(15), 1 + rng.gen_range(15));
            let a = random_csr(rng, nr, nc, 0.25);
            let tt = a.transpose().transpose();
            assert_eq!(a, tt);
        });
    }

    #[test]
    fn spmv_random_matches_dense() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(30), 1 + rng.gen_range(30));
            let a = random_csr(rng, nr, nc, 0.2);
            let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32_range(-2.0, 2.0)).collect();
            let mut y = vec![0.0; a.nrows];
            a.spmv(&x, &mut y);
            let yd = dense_spmv(&a, &x);
            for (u, v) in y.iter().zip(yd.iter()) {
                assert!((u - v).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn spmm_rowmajor_matches_repeated_spmv() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(12), 1 + rng.gen_range(12));
            let a = random_csr(rng, nr, nc, 0.3);
            let b = 1 + rng.gen_range(5);
            // build row-major X (ncols x b)
            let x: Vec<f32> = (0..a.ncols * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut y = vec![0.0; a.nrows * b];
            a.spmm_rowmajor(&x, &mut y, b);
            for col in 0..b {
                let xcol: Vec<f32> = (0..a.ncols).map(|r| x[r * b + col]).collect();
                let mut ycol = vec![0.0; a.nrows];
                a.spmv(&xcol, &mut ycol);
                for r in 0..a.nrows {
                    assert!((y[r * b + col] - ycol[r]).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn spmm_fused_matches_plain_spmm_across_tiles() {
        // widths straddling the tile boundary exercise multi-tile passes
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(12), 1 + rng.gen_range(12));
            let a = random_csr(rng, nr, nc, 0.3);
            let b = 1 + rng.gen_range(3 * SPMM_TILE);
            let x: Vec<f32> = (0..a.ncols * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut y1 = vec![0.0; a.nrows * b];
            a.spmm_rowmajor(&x, &mut y1, b);
            let mut y2 = vec![7.0; a.nrows * b]; // poisoned: must be overwritten
            a.spmm_fused_rowmajor(&x, &mut y2, b, |_, _| {});
            for (u, v) in y1.iter().zip(y2.iter()) {
                assert!((u - v).abs() < 1e-4, "{u} vs {v} (b={b})");
            }
        });
    }

    #[test]
    fn spmm_fused_epilogue_equals_post_pass() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
            let a = random_csr(rng, nr, nc, 0.4);
            let b = 1 + rng.gen_range(2 * SPMM_TILE);
            let x: Vec<f32> = (0..a.ncols * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let bias: Vec<f32> = (0..a.nrows).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            // fused: bias + relu in the epilogue
            let mut fused = vec![0.0; a.nrows * b];
            a.spmm_fused_rowmajor(&x, &mut fused, b, |r, row| {
                for v in row.iter_mut() {
                    *v = (*v + bias[r]).max(0.0);
                }
            });
            // reference: plain SpMM then a separate pass
            let mut reference = vec![0.0; a.nrows * b];
            a.spmm_rowmajor(&x, &mut reference, b);
            for r in 0..a.nrows {
                for v in reference[r * b..(r + 1) * b].iter_mut() {
                    *v = (*v + bias[r]).max(0.0);
                }
            }
            for (u, v) in fused.iter().zip(reference.iter()) {
                assert!((u - v).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn spmm_add_accumulates_onto_existing() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(12), 1 + rng.gen_range(12));
            let a = random_csr(rng, nr, nc, 0.3);
            let b = 1 + rng.gen_range(2 * SPMM_TILE);
            let x: Vec<f32> = (0..a.ncols * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let base: Vec<f32> = (0..a.nrows * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut plain = vec![0.0; a.nrows * b];
            a.spmm_rowmajor(&x, &mut plain, b);
            let mut acc = base.clone();
            a.spmm_add_rowmajor(&x, &mut acc, b);
            for i in 0..acc.len() {
                assert!((acc[i] - (base[i] + plain[i])).abs() < 1e-4, "i={i} b={b}");
            }
        });
    }

    #[test]
    fn range_kernels_cover_exactly_their_rows() {
        // stitching disjoint row ranges back together reproduces the
        // whole-matrix kernels, and rows outside the range are untouched
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(14), 1 + rng.gen_range(14));
            let a = random_csr(rng, nr, nc, 0.3);
            let b = 1 + rng.gen_range(2 * SPMM_TILE);
            let x: Vec<f32> = (0..a.ncols * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let cut = rng.gen_range(a.nrows + 1);
            // fused overwrite: [0,cut) then [cut,nr) == full pass
            let mut whole = vec![0.0; a.nrows * b];
            a.spmm_fused_rowmajor(&x, &mut whole, b, |_, _| {});
            let mut stitched = vec![9.0; a.nrows * b]; // poisoned
            a.spmm_fused_range_rowmajor(&x, &mut stitched, b, 0, cut, |_, _| {});
            a.spmm_fused_range_rowmajor(&x, &mut stitched, b, cut, a.nrows, |_, _| {});
            for (u, v) in stitched.iter().zip(whole.iter()) {
                assert!((u - v).abs() < 1e-4, "{u} vs {v} (cut={cut} b={b})");
            }
            // add: ranges accumulate only inside their rows
            let base: Vec<f32> = (0..a.nrows * b).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut acc = base.clone();
            a.spmm_add_range_rowmajor(&x, &mut acc, b, 0, cut);
            for r in cut..a.nrows {
                for j in 0..b {
                    assert_eq!(acc[r * b + j], base[r * b + j], "row {r} outside range touched");
                }
            }
            a.spmm_add_range_rowmajor(&x, &mut acc, b, cut, a.nrows);
            let mut full = base.clone();
            a.spmm_add_rowmajor(&x, &mut full, b);
            for (u, v) in acc.iter().zip(full.iter()) {
                assert!((u - v).abs() < 1e-4, "{u} vs {v}");
            }
        });
    }

    #[test]
    fn range_kernel_empty_range_is_noop() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = vec![5.0; 2];
        a.spmm_fused_range_rowmajor(&x, &mut y, 1, 1, 1, |_, _| {});
        a.spmm_add_range_rowmajor(&x, &mut y, 1, 2, 2);
        assert_eq!(y, vec![5.0, 5.0]);
    }

    #[test]
    fn spmm_add_zero_batch_is_noop() {
        let a = small();
        let mut y: Vec<f32> = Vec::new();
        a.spmm_add_rowmajor(&[], &mut y, 0);
        assert!(y.is_empty());
    }

    #[test]
    fn spmm_fused_zero_batch_is_noop() {
        let a = small();
        let mut y: Vec<f32> = Vec::new();
        let mut calls = 0usize;
        a.spmm_fused_rowmajor(&[], &mut y, 0, |_, _| calls += 1);
        assert_eq!(calls, 0);
    }

    #[test]
    fn validate_fuzz_mutations_rejected() {
        // satellite coverage: empty rows are fine; unsorted / duplicate /
        // out-of-bounds indices and broken indptr are all rejected.
        prop::check(|rng| {
            let (nr, nc) = (2 + rng.gen_range(20), 2 + rng.gen_range(20));
            let a = random_csr(rng, nr, nc, 0.2);
            assert!(a.validate().is_ok());

            if a.nnz() == 0 {
                // fully-empty matrix (every row empty) still validates
                assert_eq!(*a.indptr.last().unwrap(), 0);
                return;
            }
            // pick a row with >= 2 entries and swap two columns: unsorted
            if let Some(r) = (0..a.nrows).find(|&r| a.row_nnz(r) >= 2) {
                let mut bad = a.clone();
                let lo = bad.indptr[r] as usize;
                bad.indices.swap(lo, lo + 1);
                assert!(bad.validate().is_err(), "unsorted row accepted");
                // duplicate column index (equal neighbours) also rejected
                let mut dup = a.clone();
                dup.indices[lo + 1] = dup.indices[lo];
                assert!(dup.validate().is_err(), "duplicate column accepted");
            }
            // out-of-bounds column
            let mut oob = a.clone();
            let k = rng.gen_range(oob.nnz());
            oob.indices[k] = oob.ncols as u32 + rng.gen_range(5) as u32;
            assert!(oob.validate().is_err(), "oob column accepted");
            // non-monotone indptr
            let mut mono = a.clone();
            mono.indptr[0] = mono.indptr[a.nrows].saturating_add(1);
            assert!(mono.validate().is_err(), "broken indptr accepted");
        });
    }

    #[test]
    fn validate_accepts_empty_rows_everywhere() {
        // an interleaving of empty and non-empty rows is structurally valid
        let mut c = Coo::new(5, 4);
        c.push(1, 2, 1.0);
        c.push(3, 0, -2.0);
        let m = c.to_csr();
        assert!(m.validate().is_ok());
        assert_eq!(m.row_nnz(0), 0);
        assert_eq!(m.row_nnz(4), 0);
        let z = Csr::zeros(6, 6);
        assert!(z.validate().is_ok());
    }

    #[test]
    fn row_block_extraction() {
        let a = small();
        let blk = a.row_block(&[1]);
        assert_eq!(blk.nrows, 1);
        assert_eq!(blk.ncols, 3);
        assert_eq!(blk.row(0), (&[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn cols_used_sorted_distinct() {
        let a = small();
        assert_eq!(a.cols_used(), vec![0, 1, 2]);
        let blk = a.row_block(&[1]);
        assert_eq!(blk.cols_used(), vec![1]);
    }

    #[test]
    fn sgd_update_touches_only_nonzeros() {
        let mut a = small();
        let before_nnz = a.nnz();
        a.sgd_update(&[1.0, 1.0], &[1.0, 1.0, 1.0], 0.5);
        assert_eq!(a.nnz(), before_nnz);
        // W(0,0) = 1 - 0.5*1*1 = 0.5 ; W(0,2) = 2 - 0.5 = 1.5 ; W(1,1) = 2.5
        assert_eq!(a.row(0).1, &[0.5, 1.5]);
        assert_eq!(a.row(1).1, &[2.5]);
    }

    #[test]
    fn outer_grad_then_apply_matches_sgd_update() {
        prop::check(|rng| {
            let (nr, nc) = (1 + rng.gen_range(20), 1 + rng.gen_range(20));
            let a = random_csr(rng, nr, nc, 0.3);
            let delta: Vec<f32> = (0..nr).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let x: Vec<f32> = (0..nc).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut g = Vec::new();
            a.outer_grad(&delta, &x, &mut g);
            assert_eq!(g.len(), a.nnz(), "one gradient entry per stored nonzero");
            let mut via_grad = a.clone();
            via_grad.apply_grad(&g, 0.3);
            let mut direct = a.clone();
            direct.sgd_update(&delta, &x, 0.3);
            for (u, v) in via_grad.vals.iter().zip(direct.vals.iter()) {
                assert!((u - v).abs() < 1e-6, "{u} vs {v}");
            }
        });
    }

    #[test]
    fn validate_accepts_good_rejects_bad() {
        let a = small();
        assert!(a.validate().is_ok());
        let mut bad = a.clone();
        bad.indices[0] = 99; // out of bounds (also breaks sort)
        assert!(bad.validate().is_err());
    }

    #[test]
    fn row_partition_reassembles() {
        // splitting rows across blocks loses nothing: spmv(full) == concat of block spmvs
        prop::check(|rng| {
            let (nr, nc) = (2 + rng.gen_range(20), 1 + rng.gen_range(20));
            let a = random_csr(rng, nr, nc, 0.3);
            let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let perm = rng.permutation(a.nrows);
            let cut = rng.gen_range(a.nrows);
            let (r1, r2) = perm.split_at(cut.max(1).min(a.nrows - 1));
            let b1 = a.row_block(r1);
            let b2 = a.row_block(r2);
            let mut y = vec![0.0; a.nrows];
            a.spmv(&x, &mut y);
            let mut y1 = vec![0.0; b1.nrows];
            b1.spmv(&x, &mut y1);
            let mut y2 = vec![0.0; b2.nrows];
            b2.spmv(&x, &mut y2);
            for (i, &r) in r1.iter().enumerate() {
                assert!((y[r as usize] - y1[i]).abs() < 1e-5);
            }
            for (i, &r) in r2.iter().enumerate() {
                assert!((y[r as usize] - y2[i]).abs() < 1e-5);
            }
        });
    }
}
