//! Coordinate-format sparse matrix (builder format).

use super::csr::Csr;

/// Coordinate (triplet) sparse matrix. The natural builder format: push
/// entries in any order, then convert to CSR.
#[derive(Debug, Clone, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    /// Empty builder for an `nrows × ncols` matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Empty builder with the triplet arrays reserved for `nnz` entries.
    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Append one entry (any order; duplicates are summed at conversion).
    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.nrows && c < self.ncols, "entry out of bounds");
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Entries pushed so far (duplicates still counted separately).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Convert to CSR. Duplicate (r,c) entries are summed. Column indices
    /// within each row come out sorted.
    pub fn to_csr(&self) -> Csr {
        // counting sort by row
        let mut counts = vec![0u32; self.nrows + 1];
        for &r in &self.rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut cursor = counts.clone();
        for i in 0..self.nnz() {
            let r = self.rows[i] as usize;
            let at = cursor[r] as usize;
            indices[at] = self.cols[i];
            vals[at] = self.vals[i];
            cursor[r] += 1;
        }
        // sort within each row, merge duplicates
        let mut out_indptr = vec![0u32; self.nrows + 1];
        let mut out_indices = Vec::with_capacity(self.nnz());
        let mut out_vals = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            let lo = counts[r] as usize;
            let hi = counts[r + 1] as usize;
            let mut row: Vec<(u32, f32)> = indices[lo..hi]
                .iter()
                .cloned()
                .zip(vals[lo..hi].iter().cloned())
                .collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            for (c, v) in row {
                if let Some(last) = out_indices.last() {
                    if *last == c && out_indices.len() > out_indptr[r] as usize {
                        *out_vals.last_mut().unwrap() += v;
                        continue;
                    }
                }
                out_indices.push(c);
                out_vals.push(v);
            }
            out_indptr[r + 1] = out_indices.len() as u32;
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: out_indptr,
            indices: out_indices,
            vals: out_vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_convert() {
        let mut c = Coo::new(3, 4);
        c.push(0, 1, 1.0);
        c.push(2, 3, 2.0);
        c.push(0, 0, 3.0);
        let m = c.to_csr();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[0u32, 1u32][..], &[3.0f32, 1.0f32][..]));
        assert_eq!(m.row(1), (&[][..], &[][..]));
        assert_eq!(m.row(2), (&[3u32][..], &[2.0f32][..]));
    }

    #[test]
    fn duplicates_summed() {
        let mut c = Coo::new(2, 2);
        c.push(1, 1, 1.5);
        c.push(1, 1, 2.5);
        let m = c.to_csr();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(1), (&[1u32][..], &[4.0f32][..]));
    }

    #[test]
    fn empty_matrix() {
        let c = Coo::new(5, 5);
        let m = c.to_csr();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.indptr.len(), 6);
    }
}
