//! Sparse matrix / network text I/O in the Graph Challenge TSV style
//! (one `row \t col \t value` triple per line, 1-based indices), plus the
//! **streaming CSR builder** used wherever a large matrix is assembled
//! row-by-row without a COO intermediate.

use super::csr::Csr;
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Streaming row-by-row CSR builder: entries are appended **in row order**
/// straight into the final `indptr`/`indices`/`vals` arrays, so no COO (or
/// any other per-entry intermediate) copy of the matrix ever exists. Peak
/// resident memory is the finished CSR plus one caller-owned row scratch —
/// building a multi-million-edge RadixNet layer through this path does not
/// double peak RSS the way [`Coo`](super::Coo) +
/// [`Coo::to_csr`](super::Coo::to_csr) does, where the triplet arrays and
/// the CSR output live simultaneously.
///
/// With [`CsrStream::with_nnz_capacity`] the entry arrays are reserved
/// exactly once up front, so pushing up to the declared capacity never
/// reallocates (verified by `stream_no_realloc_at_declared_capacity` in
/// the tests).
#[derive(Debug, Clone)]
pub struct CsrStream {
    nrows: usize,
    ncols: usize,
    indptr: Vec<u32>,
    indices: Vec<u32>,
    vals: Vec<f32>,
}

impl CsrStream {
    /// Start a builder for an `nrows × ncols` matrix with no preallocation.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self::with_nnz_capacity(nrows, ncols, 0)
    }

    /// Start a builder with the entry arrays reserved for `nnz` entries —
    /// the peak-RSS-friendly constructor when the entry count is known in
    /// advance (RadixNet layers have exactly `n · r_s` entries).
    pub fn with_nnz_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        let mut indptr = Vec::with_capacity(nrows + 1);
        indptr.push(0);
        Self {
            nrows,
            ncols,
            indptr,
            indices: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Rows appended so far.
    pub fn rows_pushed(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Entries appended so far.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Current capacity of the entry arrays (the smaller of the two — the
    /// figure the no-reallocation guarantee is measured against).
    pub fn nnz_capacity(&self) -> usize {
        self.indices.capacity().min(self.vals.capacity())
    }

    /// Append the next row. `cols` must be strictly ascending and in
    /// bounds; use [`CsrStream::push_row_unsorted`] when the caller
    /// assembles rows in arbitrary column order.
    pub fn push_row(&mut self, cols: &[u32], vals: &[f32]) -> Result<()> {
        if cols.len() != vals.len() {
            bail!("CsrStream: {} cols vs {} vals", cols.len(), vals.len());
        }
        for (i, &c) in cols.iter().enumerate() {
            if i > 0 && cols[i - 1] >= c {
                bail!("CsrStream: cols not strictly ascending at position {i}");
            }
        }
        self.append_row(cols.len(), |s| {
            s.indices.extend_from_slice(cols);
            s.vals.extend_from_slice(vals);
        })
    }

    /// Append the next row from an unsorted `(col, val)` scratch: sorts by
    /// column in place, sums duplicate columns (the
    /// [`Coo::to_csr`](super::Coo::to_csr) semantics), then appends. The
    /// scratch is caller-owned so one allocation serves every row.
    pub fn push_row_unsorted(&mut self, row: &mut Vec<(u32, f32)>) -> Result<()> {
        row.sort_unstable_by_key(|&(c, _)| c);
        row.dedup_by(|cur, prev| {
            if cur.0 == prev.0 {
                prev.1 += cur.1;
                true
            } else {
                false
            }
        });
        self.append_row(row.len(), |s| {
            s.indices.extend(row.iter().map(|&(c, _)| c));
            s.vals.extend(row.iter().map(|&(_, v)| v));
        })
    }

    fn append_row(&mut self, len: usize, fill: impl FnOnce(&mut Self)) -> Result<()> {
        if self.rows_pushed() == self.nrows {
            bail!("CsrStream: more than {} rows pushed", self.nrows);
        }
        if self.nnz() + len > u32::MAX as usize {
            bail!("CsrStream: entry count overflows u32 indptr");
        }
        let before = self.indices.len();
        fill(self);
        if let Some(&c) = self.indices[before..].iter().max() {
            if c as usize >= self.ncols {
                self.indices.truncate(before);
                self.vals.truncate(before);
                bail!("CsrStream: col {c} out of bounds (ncols {})", self.ncols);
            }
        }
        self.indptr.push(self.indices.len() as u32);
        Ok(())
    }

    /// Finish the build: any rows not yet pushed become empty rows, and
    /// the arrays are handed to the returned [`Csr`] without copying.
    pub fn finish(mut self) -> Csr {
        let nnz = self.indices.len() as u32;
        self.indptr.resize(self.nrows + 1, nnz);
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: self.indptr,
            indices: self.indices,
            vals: self.vals,
        }
    }
}

/// Write a CSR matrix as 1-based TSV triples.
pub fn write_tsv(m: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    for r in 0..m.nrows {
        let (cols, vals) = m.row(r);
        for (c, v) in cols.iter().zip(vals.iter()) {
            writeln!(w, "{}\t{}\t{}", r + 1, c + 1, v)?;
        }
    }
    Ok(())
}

/// Read 1-based TSV triples into a CSR with given dimensions. Duplicate
/// `(row, col)` entries are summed; columns come out sorted per row.
///
/// Delegates to [`read_tsv_streamed`], so peak RSS is the finished CSR
/// plus one row scratch — no COO copy of the file is ever built.
pub fn read_tsv(path: &Path, nrows: usize, ncols: usize) -> Result<Csr> {
    read_tsv_streamed(path, nrows, ncols)
}

/// Streaming two-pass TSV reader: pass 1 counts entries per row, pass 2
/// scatters each entry into its final slot, then every row is sorted (and
/// duplicate columns summed) with one small per-row scratch, compacting
/// the arrays in place. Unlike the historical COO path the triplets are
/// never materialized wholesale.
pub fn read_tsv_streamed(path: &Path, nrows: usize, ncols: usize) -> Result<Csr> {
    // pass 1: entries per row
    let mut indptr = vec![0u32; nrows + 1];
    for_each_triple(path, nrows, ncols, &mut |r, _c, _v| indptr[r + 1] += 1)?;
    for i in 0..nrows {
        indptr[i + 1] += indptr[i];
    }
    let nnz = indptr[nrows] as usize;
    // pass 2: scatter into final slots (a concurrent edit of the file
    // between passes at worst trips the cursor bounds check and panics)
    let mut indices = vec![0u32; nnz];
    let mut vals = vec![0f32; nnz];
    let mut cursor = indptr.clone();
    for_each_triple(path, nrows, ncols, &mut |r, c, v| {
        let at = cursor[r] as usize;
        indices[at] = c as u32;
        vals[at] = v;
        cursor[r] += 1;
    })?;
    // per-row sort + duplicate merge, compacting left (never grows)
    let mut scratch: Vec<(u32, f32)> = Vec::new();
    let mut out_indptr = vec![0u32; nrows + 1];
    let mut write = 0usize;
    for r in 0..nrows {
        let (lo, hi) = (indptr[r] as usize, indptr[r + 1] as usize);
        scratch.clear();
        scratch.extend(
            indices[lo..hi]
                .iter()
                .copied()
                .zip(vals[lo..hi].iter().copied()),
        );
        scratch.sort_unstable_by_key(|&(c, _)| c);
        let row_start = write;
        for &(c, v) in &scratch {
            if write > row_start && indices[write - 1] == c {
                vals[write - 1] += v;
            } else {
                indices[write] = c;
                vals[write] = v;
                write += 1;
            }
        }
        out_indptr[r + 1] = write as u32;
    }
    indices.truncate(write);
    vals.truncate(write);
    Ok(Csr {
        nrows,
        ncols,
        indptr: out_indptr,
        indices,
        vals,
    })
}

/// Parse the 1-based TSV triples of `path`, invoking `f(row, col, value)`
/// with 0-based indices per entry. Shared by the two passes of
/// [`read_tsv_streamed`].
fn for_each_triple(
    path: &Path,
    nrows: usize,
    ncols: usize,
    f: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_ascii_whitespace();
        let (r, c, v) = match (it.next(), it.next(), it.next()) {
            (Some(r), Some(c), Some(v)) => (r, c, v),
            _ => bail!("{path:?}:{}: malformed triple", lineno + 1),
        };
        let r: usize = r.parse().with_context(|| format!("line {}", lineno + 1))?;
        let c: usize = c.parse().with_context(|| format!("line {}", lineno + 1))?;
        let v: f32 = v.parse().with_context(|| format!("line {}", lineno + 1))?;
        if r == 0 || c == 0 || r > nrows || c > ncols {
            bail!("{path:?}:{}: index out of bounds ({r},{c})", lineno + 1);
        }
        f(r - 1, c - 1, v);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn roundtrip() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 2, 1.5);
        coo.push(2, 0, -2.0);
        let m = coo.to_csr();
        let dir = std::env::temp_dir().join("spdnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.tsv");
        write_tsv(&m, &p).unwrap();
        let m2 = read_tsv(&p, 3, 3).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join("spdnn_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tsv");
        std::fs::write(&p, "5\t1\t1.0\n").unwrap();
        assert!(read_tsv(&p, 3, 3).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("spdnn_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.tsv");
        std::fs::write(&p, "# header\n\n1\t1\t3.0\n").unwrap();
        let m = read_tsv(&p, 2, 2).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0), (&[0u32][..], &[3.0f32][..]));
    }

    #[test]
    fn streamed_reader_matches_coo_reference() {
        // scrambled rows, duplicate entries, comments — the streamed
        // two-pass reader must agree exactly with the COO build
        let dir = std::env::temp_dir().join("spdnn_io_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("scrambled.tsv");
        let triples = [
            (3usize, 1usize, 0.5f32),
            (1, 4, -1.0),
            (3, 1, 0.25),
            (2, 2, 7.0),
            (1, 1, 2.0),
            (3, 4, 1.0),
        ];
        let mut text = String::from("# scrambled\n");
        for (r, c, v) in triples {
            text.push_str(&format!("{r}\t{c}\t{v}\n"));
        }
        std::fs::write(&p, text).unwrap();
        let mut coo = Coo::new(4, 4);
        for (r, c, v) in triples {
            coo.push(r - 1, c - 1, v);
        }
        let streamed = read_tsv_streamed(&p, 4, 4).unwrap();
        assert_eq!(streamed, coo.to_csr());
        streamed.validate().unwrap();
    }

    #[test]
    fn stream_builds_csr_with_trailing_empty_rows() {
        let mut s = CsrStream::new(4, 5);
        s.push_row(&[1, 3], &[1.0, 2.0]).unwrap();
        s.push_row(&[], &[]).unwrap();
        s.push_row(&[0], &[-1.0]).unwrap();
        let m = s.finish(); // row 3 never pushed → empty
        m.validate().unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[1u32, 3][..], &[1.0f32, 2.0][..]));
        assert_eq!(m.row(1), (&[][..], &[][..]));
        assert_eq!(m.row(2), (&[0u32][..], &[-1.0f32][..]));
        assert_eq!(m.row(3), (&[][..], &[][..]));
    }

    #[test]
    fn stream_rejects_bad_rows() {
        let mut s = CsrStream::new(2, 3);
        assert!(s.push_row(&[2, 1], &[1.0, 1.0]).is_err()); // not ascending
        assert!(s.push_row(&[1, 1], &[1.0, 1.0]).is_err()); // duplicate col
        assert!(s.push_row(&[3], &[1.0]).is_err()); // col out of bounds
        assert!(s.push_row(&[0], &[1.0, 2.0]).is_err()); // len mismatch
        assert_eq!(s.nnz(), 0); // failed pushes leave no residue
        s.push_row(&[0], &[1.0]).unwrap();
        s.push_row(&[2], &[2.0]).unwrap();
        assert!(s.push_row(&[0], &[1.0]).is_err()); // too many rows
        assert_eq!(s.finish().nnz(), 2);
    }

    #[test]
    fn stream_unsorted_row_sorts_and_merges() {
        let mut s = CsrStream::new(1, 8);
        let mut row = vec![(3u32, 1.0f32), (1, 2.0), (3, 0.5), (6, -1.0)];
        s.push_row_unsorted(&mut row).unwrap();
        let m = s.finish();
        assert_eq!(m.row(0), (&[1u32, 3, 6][..], &[2.0f32, 1.5, -1.0][..]));
    }

    #[test]
    fn stream_no_realloc_at_declared_capacity() {
        // the peak-RSS contract: reserving the exact nnz up front means
        // the entry arrays never grow during the build
        let (nrows, ncols, per_row) = (64usize, 64usize, 8usize);
        let mut s = CsrStream::with_nnz_capacity(nrows, ncols, nrows * per_row);
        let cap = s.nnz_capacity();
        assert!(cap >= nrows * per_row);
        for r in 0..nrows {
            let cols: Vec<u32> = (0..per_row).map(|t| ((r + t * 7) % ncols) as u32).collect();
            let mut row: Vec<(u32, f32)> =
                cols.iter().map(|&c| (c, c as f32 + 0.5)).collect();
            s.push_row_unsorted(&mut row).unwrap();
        }
        assert_eq!(s.nnz(), nrows * per_row);
        assert_eq!(s.nnz_capacity(), cap, "entry arrays reallocated");
        let m = s.finish();
        m.validate().unwrap();
        assert_eq!(m.nnz(), nrows * per_row);
    }
}
