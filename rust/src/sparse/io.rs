//! Sparse matrix / network text I/O in the Graph Challenge TSV style:
//! one `row \t col \t value` triple per line, 1-based indices.

use super::coo::Coo;
use super::csr::Csr;
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write a CSR matrix as 1-based TSV triples.
pub fn write_tsv(m: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    for r in 0..m.nrows {
        let (cols, vals) = m.row(r);
        for (c, v) in cols.iter().zip(vals.iter()) {
            writeln!(w, "{}\t{}\t{}", r + 1, c + 1, v)?;
        }
    }
    Ok(())
}

/// Read 1-based TSV triples into a CSR with given dimensions.
pub fn read_tsv(path: &Path, nrows: usize, ncols: usize) -> Result<Csr> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = std::io::BufReader::new(f);
    let mut coo = Coo::new(nrows, ncols);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_ascii_whitespace();
        let (r, c, v) = match (it.next(), it.next(), it.next()) {
            (Some(r), Some(c), Some(v)) => (r, c, v),
            _ => bail!("{path:?}:{}: malformed triple", lineno + 1),
        };
        let r: usize = r.parse().with_context(|| format!("line {}", lineno + 1))?;
        let c: usize = c.parse().with_context(|| format!("line {}", lineno + 1))?;
        let v: f32 = v.parse().with_context(|| format!("line {}", lineno + 1))?;
        if r == 0 || c == 0 || r > nrows || c > ncols {
            bail!("{path:?}:{}: index out of bounds ({r},{c})", lineno + 1);
        }
        coo.push(r - 1, c - 1, v);
    }
    Ok(coo.to_csr())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 2, 1.5);
        coo.push(2, 0, -2.0);
        let m = coo.to_csr();
        let dir = std::env::temp_dir().join("spdnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.tsv");
        write_tsv(&m, &p).unwrap();
        let m2 = read_tsv(&p, 3, 3).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join("spdnn_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tsv");
        std::fs::write(&p, "5\t1\t1.0\n").unwrap();
        assert!(read_tsv(&p, 3, 3).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("spdnn_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.tsv");
        std::fs::write(&p, "# header\n\n1\t1\t3.0\n").unwrap();
        let m = read_tsv(&p, 2, 2).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0), (&[0u32][..], &[3.0f32][..]));
    }
}
