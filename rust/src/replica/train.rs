//! Replica-group training drivers: hybrid data×model parallelism.
//!
//! `R` replica groups each run the existing model-parallel minibatch SGD
//! engine (blocking ≡ overlap ≡ pipelined) on their **own** minibatch
//! shard over a private intra-group fabric; at the update window every
//! rank defers its weight update ([`RankState::begin_collect`]), ring
//! all-reduces the per-layer flat gradients with its same-rank peers in
//! the other groups ([`GradAllReduce`]) over the inter-group fabric, and
//! applies the group-averaged result (`eta / R`). Per-row partitioning
//! keeps gradient ownership aligned with rank ownership, so the exchange
//! is purely rank-local — no gradient ever crosses ranks.
//!
//! Every group starts from the same weights and applies bit-identical
//! all-reduced updates (see [`crate::replica::allreduce`]'s determinism
//! contract), so the groups' models never diverge; the driver merges
//! group 0's row blocks and that IS the global model.
//!
//! One step consumes `R` consecutive minibatches (batch `b` each) —
//! semantically one effective batch of `R·b` samples whose gradient is
//! the mean of the `R` shard gradients. [`replica_serial_reference`]
//! reproduces exactly that semantics on one thread for the equivalence
//! tests.

use super::allreduce::GradAllReduce;
use crate::comm::{fabric_with, Codec, Endpoint, FabricStats};
use crate::coordinator::{ExecMode, RankState};
use crate::dnn::SparseNet;
use crate::obs::{TraceMode, Tracer};
use crate::partition::{CommPlan, DnnPartition};
use crate::runtime::parallel::{run_groups, FaultScope};
use crate::util::PhaseTimer;

/// Configuration of a replica-group training run.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaConfig {
    /// Data-parallel replica groups R (1 = plain model parallelism).
    pub groups: usize,
    /// Minibatch size per group per step.
    pub batch: usize,
    /// Learning rate (applied as `eta / R` to the summed gradient).
    pub eta: f32,
    pub epochs: usize,
    /// Intra-group execution engine.
    pub mode: ExecMode,
    /// Wire codec of the cross-group gradient all-reduce (lossy codecs
    /// get EF-SGD error feedback automatically).
    pub codec: Codec,
    /// Which fabrics the `SPDNN_FAULT` chaos plan arms.
    pub scope: FaultScope,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            groups: 1,
            batch: 1,
            eta: 0.1,
            epochs: 1,
            mode: ExecMode::Overlap,
            codec: Codec::F32,
            scope: FaultScope::Env,
        }
    }
}

/// Result of a replica-group training run.
pub struct ReplicaTrainRun {
    /// The trained model (bit-identical across groups; group 0 merged).
    pub net: SparseNet,
    /// Per-step losses, averaged over the replica groups.
    pub losses: Vec<f32>,
    /// Per-phase timers summed over every thread of every group.
    pub timer: PhaseTimer,
    /// Intra-group fabric counters, indexed `[group][rank]`.
    pub intra: Vec<Vec<FabricStats>>,
    /// Inter-group fabric counters, indexed `[group][rank]` — all-reduce
    /// traffic and nothing else.
    pub inter: Vec<Vec<FabricStats>>,
}

/// Train with `cfg.groups` replica groups of `part.nparts` ranks each.
/// Panics if the partition is invalid for the model or the dataset has
/// fewer than `groups` batches per epoch.
pub fn train_replicas(
    net: &SparseNet,
    part: &DnnPartition,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    cfg: &ReplicaConfig,
) -> ReplicaTrainRun {
    part.validate(&net.layers).expect("invalid partition");
    let plan = CommPlan::build(&net.layers, part);
    train_replicas_with_plan(net, part, &plan, inputs, targets, cfg)
}

/// [`train_replicas`] over a caller-provided plan (codec-aware drivers
/// configure the intra-group wire codecs on it first).
pub fn train_replicas_with_plan(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    cfg: &ReplicaConfig,
) -> ReplicaTrainRun {
    train_replicas_traced(net, part, plan, inputs, targets, cfg, TraceMode::from_env()).0
}

/// [`train_replicas_with_plan`] with an explicit [`TraceMode`], returning
/// the flight recorders (indexed `[group][rank]`) alongside the run — the
/// allreduce span taxonomy (`allreduce.fold`/`scatter`/`gather`, category
/// `alr`) lands in these.
pub fn train_replicas_traced(
    net: &SparseNet,
    part: &DnnPartition,
    plan: &CommPlan,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    cfg: &ReplicaConfig,
    trace: TraceMode,
) -> (ReplicaTrainRun, Vec<Vec<Tracer>>) {
    assert_eq!(inputs.len(), targets.len());
    let (groups, b) = (cfg.groups, cfg.batch);
    assert!(groups >= 1, "need at least one replica group");
    let nparts = part.nparts;
    let nbatches = inputs.len() / b;
    assert!(
        nbatches >= groups,
        "dataset has {nbatches} batches of {b}, need one per replica group ({groups})"
    );
    // each step consumes `groups` consecutive batches, one per group; a
    // trailing remainder of fewer than `groups` batches is skipped
    let steps_per_epoch = nbatches / groups;
    let steps = steps_per_epoch * cfg.epochs;
    let n0 = net.input_dim();
    let nl = net.output_dim();

    let pack = |vecs: &[Vec<f32>], dim: usize, lo: usize| -> Vec<f32> {
        let mut out = vec![0f32; dim * b];
        for (j, v) in vecs[lo..lo + b].iter().enumerate() {
            for i in 0..dim {
                out[i * b + j] = v[i];
            }
        }
        out
    };
    let xbatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(inputs, n0, i * b)).collect();
    let ybatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(targets, nl, i * b)).collect();

    let run = run_groups(groups, nparts, cfg.scope, |g, j, intra, inter| {
        let mut state = RankState::build_traced(net, part, plan, j as u32, cfg.mode, trace);
        state.begin_collect();
        let depth = state.depth();
        let mut ar = GradAllReduce::new(groups, g, cfg.codec, depth);
        let scale = cfg.eta / groups as f32;
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..cfg.epochs {
            for step in 0..steps_per_epoch {
                let idx = step * groups + g;
                let loss = state.train_step_minibatch(
                    intra,
                    plan,
                    &xbatches[idx],
                    &ybatches[idx],
                    b,
                    cfg.eta,
                );
                let mut grads = state.take_step_grads();
                for (k, gk) in grads.iter_mut().enumerate() {
                    ar.all_reduce_layer(inter, &mut state.tracer, k, gk);
                }
                for (k, gk) in grads.iter().enumerate() {
                    state.apply_layer_grad(k, gk, scale);
                }
                state.restore_grad_bufs(grads);
                losses.push(loss);
            }
        }
        (state, losses)
    })
    .unwrap_or_else(|f| panic!("replica training failed: {f}"));

    let timer = run.merged_timer(|(state, _)| &state.timer);
    let mut out = net.clone();
    let mut losses = vec![0f32; steps];
    let mut tracers: Vec<Vec<Tracer>> = Vec::with_capacity(groups);
    for (g, grp) in run.outputs.into_iter().enumerate() {
        let mut grp_tracers = Vec::with_capacity(nparts);
        for (mut state, local) in grp {
            grp_tracers.push(std::mem::take(&mut state.tracer));
            // all groups hold bit-identical weights; merge group 0's
            if g == 0 {
                state.merge_into(&mut out);
            }
            for (i, l) in local.into_iter().enumerate() {
                losses[i] += l;
            }
        }
        tracers.push(grp_tracers);
    }
    // per-rank partial losses summed to per-group losses above; average
    // the groups into the one effective-batch loss per step
    for l in &mut losses {
        *l /= groups as f32;
    }
    (
        ReplicaTrainRun {
            net: out,
            losses,
            timer,
            intra: run.intra,
            inter: run.inter,
        },
        tracers,
    )
}

/// Single-threaded reference of the replica semantics: one effective step
/// = the mean of `groups` consecutive shard gradients (batch `b` each,
/// group order), applied once with `eta / groups`. Runs the blocking
/// engine on one rank in collect mode — the replica drivers must match
/// this to float-reassociation tolerance for any R × k × engine × F32.
pub fn replica_serial_reference(
    net: &SparseNet,
    inputs: &[Vec<f32>],
    targets: &[Vec<f32>],
    b: usize,
    eta: f32,
    epochs: usize,
    groups: usize,
) -> (SparseNet, Vec<f32>) {
    use crate::partition::random::random_partition;
    let part = random_partition(&net.layers, 1, 0);
    let plan = CommPlan::build(&net.layers, &part);
    let mut eps = fabric_with(1, None, None);
    let mut ep: Endpoint = eps.pop().expect("one endpoint");
    let mut state = RankState::build_traced(net, &part, &plan, 0, ExecMode::Blocking, TraceMode::Off);
    state.begin_collect();
    let depth = state.depth();

    let n0 = net.input_dim();
    let nl = net.output_dim();
    let nbatches = inputs.len() / b;
    assert!(nbatches >= groups);
    let steps_per_epoch = nbatches / groups;
    let pack = |vecs: &[Vec<f32>], dim: usize, lo: usize| -> Vec<f32> {
        let mut out = vec![0f32; dim * b];
        for (j, v) in vecs[lo..lo + b].iter().enumerate() {
            for i in 0..dim {
                out[i * b + j] = v[i];
            }
        }
        out
    };
    let xbatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(inputs, n0, i * b)).collect();
    let ybatches: Vec<Vec<f32>> = (0..nbatches).map(|i| pack(targets, nl, i * b)).collect();

    let mut losses = Vec::with_capacity(steps_per_epoch * epochs);
    let mut sum: Vec<Vec<f32>> = (0..depth).map(|k| vec![0f32; state.grad_len(k)]).collect();
    for _ in 0..epochs {
        for step in 0..steps_per_epoch {
            for s in sum.iter_mut() {
                s.iter_mut().for_each(|v| *v = 0.0);
            }
            let mut loss = 0f32;
            for g in 0..groups {
                let idx = step * groups + g;
                loss +=
                    state.train_step_minibatch(&mut ep, &plan, &xbatches[idx], &ybatches[idx], b, eta);
                let grads = state.take_step_grads();
                for (k, gk) in grads.iter().enumerate() {
                    for (s, v) in sum[k].iter_mut().zip(gk.iter()) {
                        *s += v;
                    }
                }
                state.restore_grad_bufs(grads);
            }
            let scale = eta / groups as f32;
            for (k, s) in sum.iter().enumerate() {
                state.apply_layer_grad(k, s, scale);
            }
            losses.push(loss / groups as f32);
        }
    }
    let mut out = net.clone();
    state.merge_into(&mut out);
    (out, losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::minibatch::train_minibatch_with_plan;
    use crate::partition::random::random_partition;
    use crate::radixnet::{generate, RadixNetConfig};
    use crate::replica::allreduce::predicted_wire_words;
    use crate::util::Rng;

    fn small_net() -> SparseNet {
        let cfg = RadixNetConfig {
            radices: vec![4, 4],
            layers: 4,
            seed: 17,
            ..RadixNetConfig::default()
        };
        generate(&cfg)
    }

    fn dataset(n: usize, dim: usize, out: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut rng = Rng::new(5);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 }).collect())
            .collect();
        let targets: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let mut y = vec![0f32; out];
                y[i % out] = 1.0;
                y
            })
            .collect();
        (inputs, targets)
    }

    #[test]
    fn one_group_matches_the_minibatch_driver() {
        // R = 1 is plain model parallelism: same batches, same order; the
        // only difference is deferred-update apply (≤ 1-ulp reassociation
        // per weight per step) and an all-reduce that degenerates to the
        // residual fold.
        let net = small_net();
        let (inputs, targets) = dataset(8, 16, 16);
        let part = random_partition(&net.layers, 2, 7);
        let plan = CommPlan::build(&net.layers, &part);
        let cfg = ReplicaConfig {
            groups: 1,
            batch: 2,
            eta: 0.3,
            epochs: 2,
            mode: ExecMode::Overlap,
            codec: Codec::F32,
            scope: FaultScope::Off,
        };
        let a = train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &cfg);
        let b = train_minibatch_with_plan(&net, &part, &plan, &inputs, &targets, 2, 0.3, 2);
        assert_eq!(a.losses.len(), b.losses.len());
        for (x, y) in a.losses.iter().zip(b.losses.iter()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
        for k in 0..net.depth() {
            for (u, v) in a.net.layers[k].vals.iter().zip(b.net.layers[k].vals.iter()) {
                assert!((u - v).abs() < 1e-5);
            }
            for (u, v) in a.net.biases[k].iter().zip(b.net.biases[k].iter()) {
                assert!((u - v).abs() < 1e-5);
            }
        }
        // R = 1: no inter-group traffic at all
        assert!(a.inter[0].iter().all(|st| st.sent_msgs == 0));
    }

    #[test]
    fn two_groups_match_the_serial_reference_on_every_engine() {
        let net = small_net();
        let (inputs, targets) = dataset(8, 16, 16);
        let (expect_net, expect_losses) =
            replica_serial_reference(&net, &inputs, &targets, 2, 0.4, 2, 2);
        for mode in [ExecMode::Blocking, ExecMode::Overlap, ExecMode::pipelined()] {
            let part = random_partition(&net.layers, 2, 11);
            let cfg = ReplicaConfig {
                groups: 2,
                batch: 2,
                eta: 0.4,
                epochs: 2,
                mode,
                codec: Codec::F32,
                scope: FaultScope::Off,
            };
            let run = train_replicas(&net, &part, &inputs, &targets, &cfg);
            assert_eq!(run.losses.len(), expect_losses.len());
            for (a, e) in run.losses.iter().zip(expect_losses.iter()) {
                assert!((a - e).abs() < 1e-4, "{mode:?}: loss {a} vs {e}");
            }
            for k in 0..net.depth() {
                for (a, e) in run.net.layers[k].vals.iter().zip(expect_net.layers[k].vals.iter()) {
                    assert!((a - e).abs() < 1e-4, "{mode:?} layer {k}: {a} vs {e}");
                }
                for (a, e) in run.net.biases[k].iter().zip(expect_net.biases[k].iter()) {
                    assert!((a - e).abs() < 1e-4, "{mode:?} layer {k} bias");
                }
            }
        }
    }

    #[test]
    fn inter_group_wire_words_match_the_prediction() {
        // the live R004 cross-check: every thread's inter-fabric counter
        // equals steps × Σ_layers predicted_wire_words of its gradient
        let net = small_net();
        let (inputs, targets) = dataset(8, 16, 16);
        let part = random_partition(&net.layers, 2, 3);
        let plan = CommPlan::build(&net.layers, &part);
        for codec in [Codec::F32, Codec::int8()] {
            let cfg = ReplicaConfig {
                groups: 2,
                batch: 2,
                eta: 0.2,
                epochs: 3,
                mode: ExecMode::Overlap,
                codec,
                scope: FaultScope::Off,
            };
            let run = train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &cfg);
            let steps = (8 / 2 / 2) * 3; // nbatches / groups × epochs
            for j in 0..2usize {
                let state =
                    RankState::build_traced(&net, &part, &plan, j as u32, cfg.mode, TraceMode::Off);
                for g in 0..2usize {
                    let expect: u64 = (0..state.depth())
                        .map(|k| predicted_wire_words(g, 2, state.grad_len(k), codec, false))
                        .sum::<u64>()
                        * steps as u64;
                    assert_eq!(
                        run.inter[g][j].sent_words, expect,
                        "{codec:?} group {g} rank {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_ef_training_reduces_loss() {
        let net = small_net();
        let (inputs, targets) = dataset(8, 16, 16);
        let part = random_partition(&net.layers, 2, 9);
        let cfg = ReplicaConfig {
            groups: 2,
            batch: 2,
            eta: 0.5,
            epochs: 20,
            mode: ExecMode::Overlap,
            codec: Codec::int8(),
            scope: FaultScope::Off,
        };
        let run = train_replicas(&net, &part, &inputs, &targets, &cfg);
        let first = run.losses[0];
        let last = *run.losses.last().unwrap();
        assert!(last < first * 0.8, "int8+EF loss {first} -> {last}");
    }
}
