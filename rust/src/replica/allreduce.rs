//! Ring all-reduce of flat layer gradients across replica groups, with
//! error-feedback (EF-SGD) residual compression.
//!
//! Runs over one **inter-group** endpoint per rank thread (see
//! [`crate::runtime::parallel::run_groups`]): the `R` same-rank threads
//! of the `R` replica groups form one ring, and each rank's ring reduces
//! that rank's own gradient slice — per-row partitioning already aligns
//! gradient ownership with rank ownership, so no cross-rank traffic is
//! ever needed here.
//!
//! **Determinism contract.** Replicas must apply *bit-identical* updates
//! or their weights drift apart silently. Two mechanisms guarantee it:
//!
//! 1. in the allgather phase the segment owner encodes its fully-reduced
//!    segment exactly **once** and the encoded bytes travel the ring
//!    verbatim ([`Endpoint::send_wire_payload`]) — every group, the owner
//!    included, uses the *decoded* values, so a lossy codec can never
//!    diverge the replicas;
//! 2. in the reduce-scatter phase each segment's partial sum accumulates
//!    along a fixed ring chain, so the summation order is a function of
//!    the segment id alone.
//!
//! **Error feedback.** Every lossy encode leaves its quantization error
//! `raw − decode(encode(raw))` in the *encoding group's* per-layer
//! residual. At the next step [`GradAllReduce::all_reduce_layer`] folds
//! the carried residual into the fresh gradient before exchanging it —
//! the EF-SGD recipe that keeps compressed SGD converging at SGD rates.
//! Under [`Codec::F32`] every encode is lossless, the residual stays
//! zero, and the all-reduce is exact.
//!
//! [`Endpoint::send_wire_payload`]: crate::comm::fabric::Endpoint::send_wire_payload

use super::topology::{
    gather_recv_seg, gather_send_seg, owned_seg, scatter_recv_seg, scatter_send_seg, seg_bounds,
};
use crate::comm::{Codec, Endpoint, Phase};
use crate::obs::{Tracer, NO_CHUNK};

/// Per-thread state of the cross-group gradient exchange: the ring
/// geometry plus one EF residual vector per layer, living as long as the
/// training loop so residuals carry across steps.
pub struct GradAllReduce {
    /// Replica-group count R (ring length).
    pub groups: usize,
    /// This thread's group id — its rank on the inter-group fabric.
    pub group: usize,
    /// Wire codec of the gradient exchange (independent of the
    /// activation/delta codecs of the intra-group plan).
    pub codec: Codec,
    /// EF residual per layer, sized lazily to the layer's flat gradient
    /// length on first use; all zeros under a lossless codec.
    residual: Vec<Vec<f32>>,
}

impl GradAllReduce {
    /// A fresh exchange state for a `depth`-layer model.
    pub fn new(groups: usize, group: usize, codec: Codec, depth: usize) -> Self {
        assert!(group < groups, "group id out of range");
        Self {
            groups,
            group,
            codec,
            residual: (0..depth).map(|_| Vec::new()).collect(),
        }
    }

    /// Read access to a layer's EF residual (testing / diagnostics).
    pub fn residual(&self, k: usize) -> &[f32] {
        &self.residual[k]
    }

    /// Fold the carried residual into `g`, then ring-all-reduce `g` in
    /// place across the replica groups. On return every group holds the
    /// **identical** summed gradient (the unaveraged Σ over groups —
    /// apply with `eta / R`), and this group's residual holds the
    /// quantization errors of every encode it performed this step.
    ///
    /// `R = 1` degenerates to the residual fold alone (a no-op under a
    /// lossless codec): zero messages, zero encodes.
    pub fn all_reduce_layer(
        &mut self,
        ep: &mut Endpoint,
        tracer: &mut Tracer,
        k: usize,
        g: &mut [f32],
    ) {
        let r = self.groups;
        let m = g.len();
        let e = &mut self.residual[k];
        if e.len() != m {
            assert!(e.is_empty(), "layer {k} gradient length changed mid-run");
            e.resize(m, 0.0);
        }
        let sp = tracer.start();
        for (gi, ei) in g.iter_mut().zip(e.iter_mut()) {
            *gi += *ei;
            *ei = 0.0;
        }
        tracer.end(sp, "allreduce.fold", "alr", k as u32, NO_CHUNK, 0);
        if r == 1 {
            return;
        }
        let me = self.group;
        let next = ((me + 1) % r) as u32;
        let prev = ((me + r - 1) % r) as u32;
        let kk = k as u32;
        // Checked-F32 still decodes bit-exactly, so EF bookkeeping is
        // skipped for F32 regardless of the envelope.
        let lossless = self.codec == Codec::F32;

        // Phase 1 — reduce-scatter: R−1 hops, each accumulating one more
        // partial sum; afterwards this group owns segment (me+1) mod R.
        let sp = tracer.start();
        let mut moved = 0u64;
        for t in 0..r - 1 {
            let s_send = scatter_send_seg(me, r, t);
            let (lo, hi) = seg_bounds(m, r, s_send);
            let wire = ep.encode_wire(self.codec, &g[lo..hi]);
            if !lossless {
                let dec = ep.decode_wire(self.codec, &wire);
                for (i, d) in dec.iter().enumerate() {
                    e[lo + i] += g[lo + i] - d;
                }
                ep.recycle(dec);
            }
            moved += 4 * wire.len() as u64;
            ep.send_wire_payload(next, kk, Phase::Forward, t as u32, s_send as u32, wire, hi - lo);

            let s_recv = scatter_recv_seg(me, r, t);
            let (lo, hi) = seg_bounds(m, r, s_recv);
            let (_, payload) =
                ep.recv_any(kk, Phase::Forward, &[(prev, t as u32, s_recv as u32)]);
            let dec = ep.decode_payload(self.codec, payload);
            debug_assert_eq!(dec.len(), hi - lo);
            for (i, d) in dec.iter().enumerate() {
                g[lo + i] += d;
            }
            ep.recycle(dec);
        }
        tracer.end(sp, "allreduce.scatter", "alr", kk, NO_CHUNK, moved);

        // Phase 2 — allgather: encode the owned segment ONCE, then every
        // hop forwards received bytes verbatim, so all groups decode
        // identical values.
        let sp = tracer.start();
        let mut moved = 0u64;
        {
            let s_own = owned_seg(me, r);
            debug_assert_eq!(gather_send_seg(me, r, 0), s_own);
            let (lo, hi) = seg_bounds(m, r, s_own);
            let wire = ep.encode_wire(self.codec, &g[lo..hi]);
            if !lossless {
                let dec = ep.decode_wire(self.codec, &wire);
                for (i, d) in dec.iter().enumerate() {
                    e[lo + i] += g[lo + i] - d;
                }
                // the owner applies the decoded values too — replicas
                // must end the step with bit-identical gradients
                g[lo..hi].copy_from_slice(&dec);
                ep.recycle(dec);
            }
            moved += 4 * wire.len() as u64;
            ep.send_wire_payload(next, kk, Phase::Backward, 0, s_own as u32, wire, hi - lo);
        }
        for t in 0..r - 1 {
            let s_recv = gather_recv_seg(me, r, t);
            let (lo, hi) = seg_bounds(m, r, s_recv);
            let (_, payload) =
                ep.recv_any(kk, Phase::Backward, &[(prev, t as u32, s_recv as u32)]);
            let dec = ep.decode_wire(self.codec, &payload);
            debug_assert_eq!(dec.len(), hi - lo);
            g[lo..hi].copy_from_slice(&dec);
            ep.recycle(dec);
            if t + 1 < r - 1 {
                debug_assert_eq!(gather_send_seg(me, r, t + 1), s_recv);
                moved += 4 * payload.len() as u64;
                ep.send_wire_payload(
                    next,
                    kk,
                    Phase::Backward,
                    (t + 1) as u32,
                    s_recv as u32,
                    payload,
                    hi - lo,
                );
            } else {
                ep.recycle(payload);
            }
        }
        tracer.end(sp, "allreduce.gather", "alr", kk, NO_CHUNK, moved);
    }
}

/// Exact wire words group `me` sends per step for one all-reduce of a
/// length-`m` gradient: the reduce-scatter encodes plus the allgather
/// sends (own segment + verbatim forwards). The live inter-fabric
/// counters must match this prediction times the step count — the R004
/// cross-check of [`crate::analysis::check_replica`].
pub fn predicted_wire_words(me: usize, groups: usize, m: usize, codec: Codec, checked: bool) -> u64 {
    if groups == 1 {
        return 0;
    }
    let ww = |len: usize| -> u64 {
        if checked {
            codec.checked_wire_words(len) as u64
        } else {
            codec.wire_words(len) as u64
        }
    };
    let mut words = 0u64;
    for t in 0..groups - 1 {
        let (lo, hi) = seg_bounds(m, groups, scatter_send_seg(me, groups, t));
        words += ww(hi - lo);
        let (lo, hi) = seg_bounds(m, groups, gather_send_seg(me, groups, t));
        words += ww(hi - lo);
    }
    words
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceMode;
    use crate::runtime::parallel::run_ranks;

    /// All-reduce one vector per "group" over a plain fabric; returns the
    /// per-group results plus each group's residual.
    fn ring(groups: usize, codec: Codec, inputs: Vec<Vec<f32>>) -> Vec<(Vec<f32>, Vec<f32>)> {
        let run = run_ranks(groups, |g, ep| {
            let mut tracer = Tracer::new(TraceMode::Off, g as u32);
            let mut ar = GradAllReduce::new(groups, g, codec, 1);
            let mut grad = inputs[g].clone();
            ar.all_reduce_layer(ep, &mut tracer, 0, &mut grad);
            (grad, ar.residual(0).to_vec())
        })
        .expect("ring must not deadlock");
        run.outputs
    }

    #[test]
    fn f32_ring_is_exact_and_identical_across_groups() {
        // integer-valued entries: every summation order is exact, so the
        // result must equal the plain sum bit-for-bit
        for groups in [1usize, 2, 3, 4, 5] {
            for m in [0usize, 1, 2, 5, 37, 256] {
                let inputs: Vec<Vec<f32>> = (0..groups)
                    .map(|g| (0..m).map(|i| ((g * 31 + i * 7) % 23) as f32 - 11.0).collect())
                    .collect();
                let expect: Vec<f32> = (0..m)
                    .map(|i| inputs.iter().map(|v| v[i]).sum::<f32>())
                    .collect();
                let outs = ring(groups, Codec::F32, inputs);
                for (g, (grad, resid)) in outs.iter().enumerate() {
                    assert_eq!(grad, &expect, "R={groups} m={m} group {g}");
                    assert!(resid.iter().all(|&x| x == 0.0), "F32 residual must stay 0");
                }
            }
        }
    }

    #[test]
    fn lossy_ring_keeps_groups_bit_identical_and_accounts_errors() {
        let groups = 4;
        let m = 100;
        let inputs: Vec<Vec<f32>> = (0..groups)
            .map(|g| {
                let mut rng = crate::util::Rng::new(11 + g as u64);
                (0..m).map(|_| rng.gen_f32_range(-2.0, 2.0)).collect()
            })
            .collect();
        let expect: Vec<f32> = (0..m)
            .map(|i| inputs.iter().map(|v| v[i]).sum::<f32>())
            .collect();
        for codec in [Codec::F16, Codec::int8(), Codec::Int8 { group: 16 }] {
            let outs = ring(groups, codec, inputs.clone());
            let first = &outs[0].0;
            for (g, (grad, _)) in outs.iter().enumerate() {
                for (a, b) in grad.iter().zip(first.iter()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{codec:?} group {g}: replicas diverged"
                    );
                }
                // lossy, but bounded: int8/f16 on O(1) sums of 4 terms
                for (a, b) in grad.iter().zip(expect.iter()) {
                    assert!((a - b).abs() < 0.5, "{codec:?}: {a} vs {b}");
                }
            }
            // EF bookkeeping: every group encoded something, so some
            // residual mass must exist (random floats never quantize
            // exactly), and folding it next step must recover the loss:
            // residual ≈ pre-encode − decoded contribution.
            let any_residual = outs
                .iter()
                .any(|(_, r)| r.iter().any(|&x| x != 0.0));
            assert!(any_residual, "{codec:?}: lossy encode left no residual");
        }
    }

    #[test]
    fn residual_folds_into_next_step() {
        // two steps with the same gradient: step 2's fold must add step
        // 1's residual before exchanging.
        let groups = 2;
        let m = 40;
        let run = run_ranks(groups, |g, ep| {
            let mut tracer = Tracer::new(TraceMode::Off, g as u32);
            let mut ar = GradAllReduce::new(groups, g, Codec::int8(), 1);
            let mut rng = crate::util::Rng::new(5 + g as u64);
            let base: Vec<f32> = (0..m).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
            let mut g1 = base.clone();
            ar.all_reduce_layer(ep, &mut tracer, 0, &mut g1);
            let resid_after_1 = ar.residual(0).to_vec();
            let mut g2 = base.clone();
            ar.all_reduce_layer(ep, &mut tracer, 0, &mut g2);
            (base, g1, resid_after_1, g2)
        })
        .expect("ring must not deadlock");
        let (_, g1, resid, g2) = &run.outputs[0];
        assert!(resid.iter().any(|&x| x != 0.0));
        // the second step exchanged base + residual, so its result must
        // differ from a plain repeat wherever the residual had mass
        assert!(
            g1.iter().zip(g2.iter()).any(|(a, b)| a != b),
            "residual fold had no effect"
        );
    }

    #[test]
    fn predicted_wire_words_match_live_counters() {
        for groups in [2usize, 3, 4] {
            for m in [5usize, 64, 101] {
                for codec in [Codec::F32, Codec::F16, Codec::int8()] {
                    let inputs: Vec<Vec<f32>> =
                        (0..groups).map(|g| vec![g as f32 * 0.5; m]).collect();
                    let run = run_ranks(groups, |g, ep| {
                        let mut tracer = Tracer::new(TraceMode::Off, g as u32);
                        let mut ar = GradAllReduce::new(groups, g, codec, 1);
                        let mut grad = inputs[g].clone();
                        ar.all_reduce_layer(ep, &mut tracer, 0, &mut grad);
                        ep.sent_words
                    })
                    .expect("ring must not deadlock");
                    for (g, &words) in run.outputs.iter().enumerate() {
                        assert_eq!(
                            words,
                            predicted_wire_words(g, groups, m, codec, false),
                            "R={groups} m={m} {codec:?} group {g}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn int8_ring_shrinks_wire_bytes_vs_f32() {
        let (groups, m) = (2usize, 4096usize);
        let f32_words: u64 = (0..groups)
            .map(|g| predicted_wire_words(g, groups, m, Codec::F32, false))
            .sum();
        let int8_words: u64 = (0..groups)
            .map(|g| predicted_wire_words(g, groups, m, Codec::int8(), false))
            .sum();
        assert!(
            (int8_words as f64) < 0.35 * f32_words as f64,
            "int8 ring must stay under the 0.35× wire bar: {int8_words} vs {f32_words}"
        );
    }
}
