//! Replica-group training subsystem: hybrid data×model parallelism.
//!
//! The paper's engines partition the **model** across ranks; this module
//! adds the orthogonal **data** axis. `R` replica groups each hold a full
//! copy of the row-partitioned model and run one of the existing engines
//! (blocking / overlap / pipelined) on their own minibatch shard over a
//! private intra-group fabric; at each step's update window the groups
//! ring-all-reduce their per-layer flat gradients over `k` inter-group
//! fabrics (one per rank index — gradient ownership is row-aligned, so
//! rank `j` only ever exchanges with the other groups' rank `j`) and
//! apply the group-averaged update. Compressed exchanges (f16 / int8 via
//! [`crate::comm::Codec`]) carry an EF-SGD error-feedback residual per
//! (group, layer), folded into the next step's payload.
//!
//! - [`topology`]: segment ranges + the two-phase hop schedule, shared by
//!   the live engine and the static `R0xx` verifier;
//! - [`allreduce`]: the [`GradAllReduce`] engine and its wire-accounting
//!   prediction;
//! - [`train`]: the replica-aware training drivers and the single-thread
//!   reference semantics.
//!
//! See `docs/TRAINING.md` for the topology diagrams and the EF-SGD
//! residual contract.

pub mod allreduce;
pub mod topology;
pub mod train;

pub use allreduce::{predicted_wire_words, GradAllReduce};
pub use topology::{
    gather_recv_seg, gather_send_seg, owned_seg, owner_of_seg, replicas_from_env, scatter_recv_seg,
    scatter_send_seg, seg_bounds, REPLICAS_ENV,
};
pub use train::{
    replica_serial_reference, train_replicas, train_replicas_traced, train_replicas_with_plan,
    ReplicaConfig, ReplicaTrainRun,
};
