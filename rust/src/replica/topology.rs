//! Replica-group ring topology: segment ranges and the hop schedule of
//! the gradient all-reduce.
//!
//! A length-`m` flat gradient is split into `R` contiguous segments
//! ([`seg_bounds`]); the ring all-reduce moves them in two phases of
//! `R − 1` hops each, every hop sending one segment to the next group and
//! receiving one from the previous group:
//!
//! - **reduce-scatter** (tagged [`Phase::Forward`]): at hop `t` group `g`
//!   sends segment `(g − t) mod R` and accumulates the received segment
//!   `(g − t − 1) mod R` into its running partial sum. After `R − 1` hops
//!   group `g` holds the complete sum of segment [`owned_seg`]`(g) =
//!   (g + 1) mod R`.
//! - **allgather** (tagged [`Phase::Backward`]): the owner encodes its
//!   fully-reduced segment once and the bytes travel the ring verbatim —
//!   at hop `t` group `g` sends segment `(g + 1 − t) mod R` and receives
//!   `(g − t) mod R`.
//!
//! Every hop each group posts exactly one send and one matching receive
//! with deterministic `(layer, phase, transfer = hop, chunk = segment)`
//! tags: a **perfect matching**, so the schedule is deadlock-free by
//! construction. The static verifier
//! ([`crate::analysis::check_replica`]) re-derives this property
//! combinatorially from the same functions the live engine executes.
//!
//! [`Phase::Forward`]: crate::comm::Phase::Forward
//! [`Phase::Backward`]: crate::comm::Phase::Backward

/// Environment variable selecting the replica-group count for CLI
/// drivers (`SPDNN_REPLICAS`, default 1 = plain model parallelism).
pub const REPLICAS_ENV: &str = "SPDNN_REPLICAS";

/// Replica-group count from the `SPDNN_REPLICAS` environment contract:
/// a positive integer, anything unset/unparsable falls back to 1.
pub fn replicas_from_env() -> usize {
    std::env::var(REPLICAS_ENV)
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&r| r >= 1)
        .unwrap_or(1)
}

/// Half-open range `[lo, hi)` of segment `seg` of a length-`m` gradient
/// split into `groups` contiguous segments. Segments are balanced to
/// within one element, disjoint, and cover `[0, m)` exactly; segments may
/// be empty when `m < groups`.
pub fn seg_bounds(m: usize, groups: usize, seg: usize) -> (usize, usize) {
    debug_assert!(seg < groups);
    (seg * m / groups, (seg + 1) * m / groups)
}

/// The segment group `me` owns (holds fully reduced) after the
/// reduce-scatter phase.
pub fn owned_seg(me: usize, groups: usize) -> usize {
    (me + 1) % groups
}

/// The group that owns `seg` after the reduce-scatter phase — inverse of
/// [`owned_seg`].
pub fn owner_of_seg(seg: usize, groups: usize) -> usize {
    (seg + groups - 1) % groups
}

/// Segment group `me` sends at reduce-scatter hop `hop ∈ [0, R−1)`.
pub fn scatter_send_seg(me: usize, groups: usize, hop: usize) -> usize {
    (me + groups - hop % groups) % groups
}

/// Segment group `me` receives (and accumulates) at reduce-scatter hop
/// `hop` — what its ring predecessor sends at the same hop.
pub fn scatter_recv_seg(me: usize, groups: usize, hop: usize) -> usize {
    scatter_send_seg((me + groups - 1) % groups, groups, hop)
}

/// Segment group `me` sends at allgather hop `hop ∈ [0, R−1)`: its own
/// segment at hop 0, then each received segment forwarded verbatim.
pub fn gather_send_seg(me: usize, groups: usize, hop: usize) -> usize {
    (me + 1 + groups - hop % groups) % groups
}

/// Segment group `me` receives at allgather hop `hop` — what its ring
/// predecessor sends at the same hop.
pub fn gather_recv_seg(me: usize, groups: usize, hop: usize) -> usize {
    gather_send_seg((me + groups - 1) % groups, groups, hop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_partition_the_gradient() {
        for groups in 1..=6 {
            for m in [0usize, 1, 2, 3, 5, 7, 64, 1000] {
                let mut covered = 0usize;
                for s in 0..groups {
                    let (lo, hi) = seg_bounds(m, groups, s);
                    assert_eq!(lo, covered, "R={groups} m={m} seg {s} not contiguous");
                    assert!(hi >= lo);
                    // balanced to within one element
                    assert!(hi - lo <= m / groups + 1);
                    covered = hi;
                }
                assert_eq!(covered, m, "R={groups} m={m} segments must cover [0, m)");
            }
        }
    }

    #[test]
    fn every_hop_is_a_perfect_matching() {
        // At each hop of each phase, what group g sends to g+1 is exactly
        // what g+1 expects from g — the tag-level deadlock-freedom
        // argument the live engine relies on.
        for groups in 2..=6 {
            for hop in 0..groups - 1 {
                for me in 0..groups {
                    let next = (me + 1) % groups;
                    assert_eq!(
                        scatter_send_seg(me, groups, hop),
                        scatter_recv_seg(next, groups, hop),
                        "R={groups} hop {hop} scatter mismatch at {me}->{next}"
                    );
                    assert_eq!(
                        gather_send_seg(me, groups, hop),
                        gather_recv_seg(next, groups, hop),
                        "R={groups} hop {hop} gather mismatch at {me}->{next}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_accumulates_each_segment_fully() {
        // Track which groups' contributions each segment has absorbed;
        // after R−1 hops the owner must hold all R contributions.
        for groups in 1..=6 {
            // holder[s] = set of groups whose contribution the current
            // holder of segment s has absorbed (bitmask)
            let mut absorbed: Vec<u64> = (0..groups).map(|s| 1 << owner_init(s, groups, 0)).collect();
            // at hop t, segment s moves from scatter_send to the next
            // group, which adds its own contribution
            for hop in 0..groups.saturating_sub(1) {
                for me in 0..groups {
                    let s = scatter_send_seg(me, groups, hop);
                    let recv = (me + 1) % groups;
                    // only the current holder of s sends it at this hop
                    if owner_init(s, groups, hop) == me {
                        absorbed[s] |= 1 << recv;
                    }
                }
            }
            for s in 0..groups {
                assert_eq!(
                    absorbed[s].count_ones() as usize,
                    groups,
                    "R={groups} segment {s} missing contributions"
                );
                assert_eq!(owner_init(s, groups, groups - 1), owner_of_seg(s, groups));
            }
        }
    }

    /// The group holding (the running partial sum of) segment `s` at the
    /// START of reduce-scatter hop `hop`: the sender chain starts at
    /// group `s` and advances one group per hop.
    fn owner_init(s: usize, groups: usize, hop: usize) -> usize {
        (s + hop) % groups
    }

    #[test]
    fn allgather_delivers_every_segment_everywhere() {
        for groups in 2..=6 {
            // have[g] = bitmask of segments group g holds post-scatter
            let mut have: Vec<u64> = (0..groups).map(|g| 1 << owned_seg(g, groups)).collect();
            for hop in 0..groups - 1 {
                // snapshot: all sends of a hop happen "simultaneously"
                let sends: Vec<usize> =
                    (0..groups).map(|me| gather_send_seg(me, groups, hop)).collect();
                for me in 0..groups {
                    let next = (me + 1) % groups;
                    assert!(
                        have[me] & (1 << sends[me]) != 0,
                        "R={groups} hop {hop}: group {me} forwards segment {} it does not hold",
                        sends[me]
                    );
                    have[next] |= 1 << sends[me];
                }
            }
            for (g, &mask) in have.iter().enumerate() {
                assert_eq!(
                    mask.count_ones() as usize,
                    groups,
                    "R={groups} group {g} missing segments after allgather"
                );
            }
        }
    }

    #[test]
    fn env_contract_defaults_to_one() {
        std::env::remove_var(REPLICAS_ENV);
        assert_eq!(replicas_from_env(), 1);
        std::env::set_var(REPLICAS_ENV, "4");
        assert_eq!(replicas_from_env(), 4);
        std::env::set_var(REPLICAS_ENV, "0");
        assert_eq!(replicas_from_env(), 1, "zero groups is not a thing");
        std::env::set_var(REPLICAS_ENV, "bogus");
        assert_eq!(replicas_from_env(), 1);
        std::env::remove_var(REPLICAS_ENV);
    }
}
