//! PJRT-backed layer engine: runs the rank-local layer blocks through the
//! AOT artifacts — the "three layers compose" proof on the serving path.
//!
//! The artifacts are compiled for a fixed row-block shape `m×k` (one per
//! variant, emitted by aot.py). Row blocks whose local row count is below
//! `m` are zero-padded; the padded outputs are sliced away. The sparse
//! block is densified (dense-with-zeros is the masked TPU form the L1
//! kernel expects).

use super::pjrt::PjrtRuntime;
use super::{bwd_artifact, fwd_artifact, fwd_batch_artifact};
use crate::ensure;
use crate::sparse::Csr;
use crate::util::error::Result;
use std::path::Path;

/// Executes σ(Wx+b) / Wᵀδ blocks of a fixed padded shape via PJRT.
pub struct PjrtLayerEngine {
    rt: PjrtRuntime,
    /// Padded rows per block.
    pub m: usize,
    /// Columns (global layer width).
    pub k: usize,
    /// Batch width of the batched artifact (0 = not loaded).
    pub batch: usize,
}

impl PjrtLayerEngine {
    /// Load the fwd/bwd artifacts for shape m×k from `dir` (and the
    /// batched forward if `batch > 0`).
    pub fn load(dir: &Path, m: usize, k: usize, batch: usize) -> Result<Self> {
        let mut rt = PjrtRuntime::new()?;
        rt.load("fwd", &dir.join(fwd_artifact(m, k)))?;
        rt.load("bwd", &dir.join(bwd_artifact(m, k)))?;
        if batch > 0 {
            rt.load("fwd_batch", &dir.join(fwd_batch_artifact(m, k, batch)))?;
        }
        Ok(Self { rt, m, k, batch })
    }

    /// Densify a row block to the padded `m×k` row-major buffer.
    pub fn densify(&self, blk: &Csr) -> Result<Vec<f32>> {
        ensure!(blk.nrows <= self.m, "block rows {} > padded {}", blk.nrows, self.m);
        ensure!(blk.ncols == self.k, "block cols {} != {}", blk.ncols, self.k);
        let mut dense = vec![0f32; self.m * self.k];
        for r in 0..blk.nrows {
            let (cols, vals) = blk.row(r);
            for (c, v) in cols.iter().zip(vals.iter()) {
                dense[r * self.k + *c as usize] = *v;
            }
        }
        Ok(dense)
    }

    /// σ(W_blk · x + b) for the local rows; returns `blk.nrows` outputs.
    pub fn forward(&self, blk: &Csr, x: &[f32], bias: &[f32]) -> Result<Vec<f32>> {
        ensure!(x.len() == self.k, "x len {} != {}", x.len(), self.k);
        let dense = self.densify(blk)?;
        let mut b = vec![0f32; self.m];
        b[..bias.len()].copy_from_slice(bias);
        let out = self.rt.exec_f32(
            "fwd",
            &[
                (&dense, &[self.m as i64, self.k as i64]),
                (x, &[self.k as i64]),
                (&b, &[self.m as i64]),
            ],
        )?;
        Ok(out[..blk.nrows].to_vec())
    }

    /// W_blkᵀ · δ (full-width s vector of length k).
    pub fn backward(&self, blk: &Csr, delta: &[f32]) -> Result<Vec<f32>> {
        ensure!(delta.len() == blk.nrows);
        let dense = self.densify(blk)?;
        let mut d = vec![0f32; self.m];
        d[..delta.len()].copy_from_slice(delta);
        self.rt.exec_f32(
            "bwd",
            &[
                (&dense, &[self.m as i64, self.k as i64]),
                (&d, &[self.m as i64]),
            ],
        )
    }

    /// Batched forward σ(W_blk · X + b): X is `[k × batch]` row-major;
    /// returns `[blk.nrows × batch]` row-major.
    pub fn forward_batch(&self, blk: &Csr, x: &[f32], bias: &[f32]) -> Result<Vec<f32>> {
        ensure!(self.batch > 0, "batched artifact not loaded");
        ensure!(x.len() == self.k * self.batch);
        let dense = self.densify(blk)?;
        let mut b = vec![0f32; self.m];
        b[..bias.len()].copy_from_slice(bias);
        let out = self.rt.exec_f32(
            "fwd_batch",
            &[
                (&dense, &[self.m as i64, self.k as i64]),
                (x, &[self.k as i64, self.batch as i64]),
                (&b, &[self.m as i64]),
            ],
        )?;
        Ok(out[..blk.nrows * self.batch].to_vec())
    }
}
