//! Vendored API stand-in for the external `xla` crate.
//!
//! The `pjrt` feature historically required hand-declaring a vendored
//! `xla` checkout in `Cargo.toml` before the crate would even compile,
//! which meant `cargo build --all-features` was permanently broken in any
//! environment without that checkout (CI included). This module keeps the
//! feature **compiling** everywhere: it mirrors exactly the slice of the
//! `xla` crate surface that [`super::pjrt`] and [`super::engine`] consume,
//! with a CPU client that constructs successfully and reports itself as a
//! stub, and a compile path that fails with a `pjrt stub` error instead
//! of executing anything.
//!
//! Swapping in a real PJRT backend is a two-line change: declare the
//! vendored crate in `Cargo.toml` (`xla = { path = "../vendor/xla" }`)
//! and repoint the `use super::xla_stub as xla;` alias in
//! `runtime/pjrt.rs` at the real crate. Everything downstream — the
//! runtime wrapper, the layer engine, the integration tests — is written
//! against this shared surface and skips itself at runtime while
//! [`IS_STUB`] is true.

/// `true` for this shim; the integration tests consult it (through
/// [`super::pjrt::PjrtRuntime::vendored_stub`]) to skip execution paths
/// that need a real PJRT client.
pub const IS_STUB: bool = true;

/// Error type matching the real crate's `Debug`-formatted usage.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn stub_err(what: &str) -> XlaError {
    XlaError(format!(
        "pjrt stub: {what} requires a real vendored `xla` crate (see rust/src/runtime/xla_stub.rs)"
    ))
}

/// Stand-in PJRT client. Construction **succeeds** — callers probe the
/// platform and cache the client long before any HLO exists, and the
/// wrapper's own unit tests assert the CPU client comes up.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "spdnn-xla-stub (cpu)".to_string()
    }

    /// Compilation is where the stub draws the line: there is no XLA
    /// behind it, so every compile fails with a typed `pjrt stub` error.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(stub_err("compiling HLO"))
    }
}

/// Parsed HLO module. The stub validates that the artifact file exists
/// and is readable (so missing-artifact errors stay distinguishable from
/// stub-compile errors) and retains the text for debugging.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self, XlaError> {
        std::fs::read_to_string(path)
            .map(|text| Self { text })
            .map_err(|e| XlaError(format!("read {path}: {e}")))
    }
}

/// Computation handle built from a proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Compiled executable. Unreachable through the stub client (compile
/// always fails), but the execute path must typecheck for the wrapper.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(stub_err("executing"))
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(stub_err("fetching a device buffer"))
    }
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Host literal: flat f32 payload plus dims (the stub only ever carries
/// f32, which is the only element type the wrapper uses).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Self {
        Self {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self, XlaError> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(XlaError(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Self {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(self) -> Result<Self, XlaError> {
        Ok(self)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_comes_up_but_refuses_to_compile() {
        let c = PjRtClient::cpu().expect("stub client");
        assert!(c.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let err = c.compile(&comp).err().expect("stub must not compile");
        assert!(err.0.contains("pjrt stub"), "{err:?}");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn missing_artifact_is_a_read_error() {
        let err = HloModuleProto::from_text_file("/nonexistent/artifact.hlo.txt")
            .err()
            .expect("missing file");
        assert!(err.0.contains("read"), "{err:?}");
    }
}
