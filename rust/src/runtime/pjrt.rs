//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times (pattern from /opt/xla-example).
//!
//! By default the feature builds against the vendored API stand-in
//! ([`super::xla_stub`]), which keeps `cargo build --all-features`
//! compiling everywhere: the CPU client comes up, but `load` fails with a
//! `pjrt stub` error instead of compiling HLO. To run against a real
//! PJRT, vendor the `xla` crate in `Cargo.toml` and repoint the alias
//! below; [`PjrtRuntime::vendored_stub`] tells callers (and the
//! integration tests) which backend they got.

use super::xla_stub as xla;
use crate::format_err;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A PJRT CPU client plus a cache of compiled executables keyed by name.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| format_err!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            exes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// `true` when the build is backed by the vendored no-op stub rather
    /// than a real `xla` crate — compile/execute paths will fail with
    /// `pjrt stub` errors and execution tests should skip themselves.
    pub fn vendored_stub() -> bool {
        xla::IS_STUB
    }

    /// Load + compile an HLO text artifact under `key`. No-op if already
    /// loaded.
    pub fn load(&mut self, key: &str, path: &Path) -> Result<()> {
        if self.exes.contains_key(key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| format_err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format_err!("compile {key}: {e:?}"))?;
        self.exes.insert(key.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.exes.contains_key(key)
    }

    /// Execute `key` with f32 tensor arguments (`(data, dims)` pairs).
    /// Artifacts are lowered with `return_tuple=True` and a single output,
    /// so the result is the flattened f32 payload of tuple element 0.
    pub fn exec_f32(&self, key: &str, args: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let exe = self
            .exes
            .get(key)
            .ok_or_else(|| format_err!("executable {key} not loaded"))?;
        let mut literals = Vec::with_capacity(args.len());
        for (data, dims) in args {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| format_err!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format_err!("execute {key}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("fetch result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format_err!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| format_err!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require `make artifacts` to have produced the demo
    // artifact; they are exercised end-to-end in rust/tests/pjrt_runtime.rs
    // which builds its own artifacts. Here we only check client creation.
    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::new().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
        assert!(!rt.is_loaded("nope"));
    }

    #[test]
    fn exec_unloaded_key_errors() {
        let rt = PjrtRuntime::new().unwrap();
        assert!(rt.exec_f32("missing", &[]).is_err());
    }
}
